"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency (see the ``test`` extra in
``pyproject.toml``); the whole module is skipped when it is absent so the
tier-1 suite stays green on minimal installs.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import collectives as C
from repro.core.engine import Engine
from repro.core.protocols import ProtocolModel
from repro.core.verify import check_program
from repro.kernels import ref

SLOW = settings(max_examples=20, deadline=None)
FAST = settings(max_examples=50, deadline=None)


# ----------------------------------------------------------------- engine
@FAST
@given(st.lists(st.tuples(st.floats(0, 1e6), st.integers(0, 99)),
                min_size=1, max_size=60))
def test_engine_fires_in_time_order(events):
    e = Engine()
    fired = []
    for delay, tag in events:
        e.schedule(delay, lambda t=tag: fired.append((e.now, t)))
    e.run()
    times = [t for t, _ in fired]
    assert times == sorted(times)
    assert len(fired) == len(events)


@FAST
@given(st.lists(st.floats(0, 1000), min_size=1, max_size=30),
       st.floats(0.1, 100))
def test_engine_until_boundary(delays, until):
    e = Engine()
    fired = []
    for d in delays:
        e.schedule(d, lambda d=d: fired.append(d))
    e.run(until_ns=until)
    eps = 1e-6
    assert all(d <= until + eps for d in fired)
    assert e.pending == sum(1 for d in delays if d > until + eps)


# ------------------------------------------------------------- collectives
@SLOW
@given(st.integers(2, 6), st.integers(1, 3), st.integers(8, 200),
       st.sampled_from(["put", "get"]), st.integers(0, 5))
def test_ring_all_gather_always_correct(n, nwg, size, proto, seed):
    check_program(C.ring_all_gather(n, size, nwg, proto), seed=seed)


@SLOW
@given(st.integers(2, 6), st.integers(1, 3), st.integers(8, 150),
       st.sampled_from(["put", "get"]), st.integers(0, 5))
def test_direct_reduce_scatter_always_correct(n, nwg, size, proto, seed):
    check_program(C.direct_reduce_scatter(n, size, nwg, proto), seed=seed)


@SLOW
@given(st.integers(2, 5), st.integers(1, 2), st.integers(10, 120),
       st.integers(0, 7))
def test_ring_all_reduce_always_correct(n, nwg, size, seed):
    check_program(C.ring_all_reduce(n, size, nwg, "put"), seed=seed)


@SLOW
@given(st.sampled_from([2, 4, 8]), st.integers(16, 120), st.integers(0, 5))
def test_hd_all_reduce_always_correct(n, size, seed):
    check_program(C.halving_doubling_all_reduce(n, size, 2), seed=seed)


# ------------------------------------------------------- reservation ledger
@FAST
@given(st.integers(2, 5), st.lists(st.tuples(st.integers(0, 4000),
                                             st.integers(32, 512)),
                                   min_size=1, max_size=24),
       st.booleans())
def test_ledger_clock_monotone_and_timing_neutral(nhops, sends, star):
    """Channel clocks on random line/star fabrics: the threshold query is
    monotone in ``need``, chaining never reorders FIFO service
    (``order_violations == 0``), and delivery times are bit-identical with
    the ledger on or off."""
    from repro.core.network.fabric import DATA, Fabric

    def run(ledger):
        e = Engine()
        fab = Fabric(e, ledger=ledger, min_msg_bytes=32)
        if star:
            hub = fab.add_node("hub")
            srcs = [fab.add_node(f"s{i}") for i in range(nhops)]
            dst = fab.add_node("d")
            for sn in srcs:
                fab.add_link(sn, hub, 2.0, 30.0)
            fab.add_link(hub, dst, 2.0, 30.0)
            routes = [fab.route(sn, dst) for sn in srcs]
        else:
            nodes = [fab.add_node(f"n{i}") for i in range(nhops + 1)]
            for u, v in zip(nodes, nodes[1:]):
                fab.add_link(u, v, 2.0, 30.0)
            routes = [fab.route(nodes[0], nodes[-1])]
        got = []
        # per-head non-decreasing injection ticks (the send_at contract)
        t_by_head = {}
        for i, (dt, size) in enumerate(sends):
            ri = i % len(routes)
            route = routes[ri]
            head = id(route[0])
            at = max(t_by_head.get(head, 0), dt * 1000)
            t_by_head[head] = at
            fab.send_at(route, size, DATA,
                        lambda f, ri=ri: got.append((f.eta_ps, ri)),
                        at_ps=at)
            if ledger and route[0].led:
                # monotone threshold: a proof at a larger need implies
                # every smaller one
                probe = e.now_ps + 50_000
                if fab.clock_ge_ps(route[-1], probe):
                    assert fab.clock_ge_ps(route[-1], probe // 2 + 1)
        e.run()
        assert fab.order_violations == 0
        return got

    assert run(True) == run(False)


# -------------------------------------------------------------- protocols
@FAST
@given(st.floats(10, 10_000), st.floats(1, 2000))
def test_protocol_crossover_monotone_in_alpha(alpha, beta):
    m1 = ProtocolModel(alpha_ns=alpha, beta_GBps=beta)
    m2 = ProtocolModel(alpha_ns=alpha * 2, beta_GBps=beta)
    assert m2.crossover_bytes() >= m1.crossover_bytes()
    # LL wins below the crossover, Simple above it
    small = max(1, int(m1.crossover_bytes() * 0.5))
    assert m1.t_ll_ns(small) < m1.t_simple_ns(small)
    big = int(m1.crossover_bytes() * 16) + 1024
    assert m1.t_simple_ns(big) < m1.t_ll_ns(big)


# ----------------------------------------------------------------- kernels
@SLOW
@given(st.integers(1, 2), st.sampled_from([1, 2, 4]), st.sampled_from([2, 4]),
       st.integers(0, 3))
def test_attention_softmax_rows_sum_to_one(b, kh, g, seed):
    """Attention output must be a convex combination of V rows: with V = 1
    the output is exactly 1."""
    key = jax.random.PRNGKey(seed)
    h = kh * g
    q = jax.random.normal(key, (b, h, 32, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kh, 32, 16))
    v = jnp.ones((b, kh, 32, 16))
    out = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)


@SLOW
@given(st.integers(0, 5))
def test_wkv6_zero_decay_is_cumulative_outer_products(seed):
    """w == 1 (logw == 0), u == 0: y_t = r_t . (sum_{s<t} k_s v_s^T)."""
    key = jax.random.PRNGKey(seed)
    B, H, T, N = 1, 1, 12, 8
    r = jax.random.normal(key, (B, H, T, N))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, T, N))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, T, N))
    logw = jnp.zeros((B, H, T, N))
    u = jnp.zeros((H, N))
    got = np.asarray(ref.wkv6_ref(r, k, v, logw, u))[0, 0]
    S = np.zeros((N, N))
    rn, kn, vn = (np.asarray(a)[0, 0] for a in (r, k, v))
    for t in range(T):
        want = rn[t] @ S
        np.testing.assert_allclose(got[t], want, rtol=2e-4, atol=2e-4)
        S += np.outer(kn[t], vn[t])


@SLOW
@given(st.integers(0, 5))
def test_rg_lru_zero_gate_preserves_state(seed):
    """a == 1, b == 0: h stays at h0 forever."""
    key = jax.random.PRNGKey(seed)
    B, T, R = 1, 16, 8
    a = jnp.ones((B, T, R))
    b = jnp.zeros((B, T, R))
    h0 = jax.random.normal(key, (B, R))
    hs = np.asarray(ref.rg_lru_ref(a, b, h0))
    for t in range(T):
        np.testing.assert_allclose(hs[0, t], np.asarray(h0)[0], rtol=1e-6)
