"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency (see the ``test`` extra in
``pyproject.toml``); the whole module is skipped when it is absent so the
tier-1 suite stays green on minimal installs.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import collectives as C
from repro.core.engine import Engine
from repro.core.protocols import ProtocolModel
from repro.core.verify import check_program
from repro.kernels import ref

SLOW = settings(max_examples=20, deadline=None)
FAST = settings(max_examples=50, deadline=None)


# ----------------------------------------------------------------- engine
@FAST
@given(st.lists(st.tuples(st.floats(0, 1e6), st.integers(0, 99)),
                min_size=1, max_size=60))
def test_engine_fires_in_time_order(events):
    e = Engine()
    fired = []
    for delay, tag in events:
        e.schedule(delay, lambda t=tag: fired.append((e.now, t)))
    e.run()
    times = [t for t, _ in fired]
    assert times == sorted(times)
    assert len(fired) == len(events)


@FAST
@given(st.lists(st.floats(0, 1000), min_size=1, max_size=30),
       st.floats(0.1, 100))
def test_engine_until_boundary(delays, until):
    e = Engine()
    fired = []
    for d in delays:
        e.schedule(d, lambda d=d: fired.append(d))
    e.run(until_ns=until)
    eps = 1e-6
    assert all(d <= until + eps for d in fired)
    assert e.pending == sum(1 for d in delays if d > until + eps)


# ------------------------------------------------------------- collectives
@SLOW
@given(st.integers(2, 6), st.integers(1, 3), st.integers(8, 200),
       st.sampled_from(["put", "get"]), st.integers(0, 5))
def test_ring_all_gather_always_correct(n, nwg, size, proto, seed):
    check_program(C.ring_all_gather(n, size, nwg, proto), seed=seed)


@SLOW
@given(st.integers(2, 6), st.integers(1, 3), st.integers(8, 150),
       st.sampled_from(["put", "get"]), st.integers(0, 5))
def test_direct_reduce_scatter_always_correct(n, nwg, size, proto, seed):
    check_program(C.direct_reduce_scatter(n, size, nwg, proto), seed=seed)


@SLOW
@given(st.integers(2, 5), st.integers(1, 2), st.integers(10, 120),
       st.integers(0, 7))
def test_ring_all_reduce_always_correct(n, nwg, size, seed):
    check_program(C.ring_all_reduce(n, size, nwg, "put"), seed=seed)


@SLOW
@given(st.sampled_from([2, 4, 8]), st.integers(16, 120), st.integers(0, 5))
def test_hd_all_reduce_always_correct(n, size, seed):
    check_program(C.halving_doubling_all_reduce(n, size, 2), seed=seed)


# -------------------------------------------------------------- protocols
@FAST
@given(st.floats(10, 10_000), st.floats(1, 2000))
def test_protocol_crossover_monotone_in_alpha(alpha, beta):
    m1 = ProtocolModel(alpha_ns=alpha, beta_GBps=beta)
    m2 = ProtocolModel(alpha_ns=alpha * 2, beta_GBps=beta)
    assert m2.crossover_bytes() >= m1.crossover_bytes()
    # LL wins below the crossover, Simple above it
    small = max(1, int(m1.crossover_bytes() * 0.5))
    assert m1.t_ll_ns(small) < m1.t_simple_ns(small)
    big = int(m1.crossover_bytes() * 16) + 1024
    assert m1.t_simple_ns(big) < m1.t_ll_ns(big)


# ----------------------------------------------------------------- kernels
@SLOW
@given(st.integers(1, 2), st.sampled_from([1, 2, 4]), st.sampled_from([2, 4]),
       st.integers(0, 3))
def test_attention_softmax_rows_sum_to_one(b, kh, g, seed):
    """Attention output must be a convex combination of V rows: with V = 1
    the output is exactly 1."""
    key = jax.random.PRNGKey(seed)
    h = kh * g
    q = jax.random.normal(key, (b, h, 32, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kh, 32, 16))
    v = jnp.ones((b, kh, 32, 16))
    out = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)


@SLOW
@given(st.integers(0, 5))
def test_wkv6_zero_decay_is_cumulative_outer_products(seed):
    """w == 1 (logw == 0), u == 0: y_t = r_t . (sum_{s<t} k_s v_s^T)."""
    key = jax.random.PRNGKey(seed)
    B, H, T, N = 1, 1, 12, 8
    r = jax.random.normal(key, (B, H, T, N))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, T, N))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, T, N))
    logw = jnp.zeros((B, H, T, N))
    u = jnp.zeros((H, N))
    got = np.asarray(ref.wkv6_ref(r, k, v, logw, u))[0, 0]
    S = np.zeros((N, N))
    rn, kn, vn = (np.asarray(a)[0, 0] for a in (r, k, v))
    for t in range(T):
        want = rn[t] @ S
        np.testing.assert_allclose(got[t], want, rtol=2e-4, atol=2e-4)
        S += np.outer(kn[t], vn[t])


@SLOW
@given(st.integers(0, 5))
def test_rg_lru_zero_gate_preserves_state(seed):
    """a == 1, b == 0: h stays at h0 forever."""
    key = jax.random.PRNGKey(seed)
    B, T, R = 1, 16, 8
    a = jnp.ones((B, T, R))
    b = jnp.zeros((B, T, R))
    h0 = jax.random.normal(key, (B, R))
    hs = np.asarray(ref.rg_lru_ref(a, b, h0))
    for t in range(T):
        np.testing.assert_allclose(hs[0, t], np.asarray(h0)[0], rtol=1e-6)
