"""Per-link reservation ledgers (ISSUE 4 tentpole): parity and regressions.

The ledger (``NocConfig.fabric_ledger`` / ``Fabric(ledger=...)``) lets the
fast path chain flights through every interior hop whose channel clock it
beats.  The contract is the same as every other fast path: *identical*
simulated timing — ``time_ns`` and per-rank completion times bit-exact with
the ledger on or off, across scale-up wirings and collectives — certified
by the per-link FIFO monitor (``order_violations == 0``); only the
heap-event count may differ.
"""

import pytest

from repro.core import collectives as C
from repro.core.backends import simulate
from repro.core.cluster import Cluster, NocConfig
from repro.core.engine import Engine
from repro.core.infragraph.blueprints import torus2d_fabric
from repro.core.network.fabric import CONTROL, DATA, Fabric
from repro.core.system import simulate_collective

SMALL = dict(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
             io_ports=4)


def run_ledger_pair(prog_fn, nranks, *, topology="switch", mode="coalesce",
                    **sim_kw):
    out = {}
    for led in ("on", "off"):
        cluster = Cluster(nranks, noc=NocConfig(fabric_mode=mode,
                                                fabric_ledger=led, **SMALL),
                          topology=topology)
        r = simulate_collective(prog_fn(), cluster=cluster, **sim_kw)
        out[led] = (r, cluster)
    return out


# ---------------------------------------------------------------------------
# parity: ledger on == ledger off, across wirings x collectives x nworkgroups
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", ["switch", "ring"])
@pytest.mark.parametrize("gen,args,kw", [
    (C.ring_all_reduce, (4, 8192, 1, "put"), {}),
    (C.ring_all_reduce, (4, 8192, 2, "put"), {}),
    (C.ring_all_gather, (4, 8192, 1, "get"), {}),
    (C.ring_all_gather, (4, 4096, 2, "get"), {}),
    (C.direct_reduce_scatter, (4, 8192, 1, "get"), {}),
    (C.direct_reduce_scatter, (4, 4096, 2, "get"), {}),
    (C.halving_doubling_all_reduce, (4, 8192, 2), {}),
])
def test_ledger_parity_cluster_wirings(topology, gen, args, kw):
    res = run_ledger_pair(lambda: gen(*args), args[0], topology=topology,
                          **kw)
    r_on, c_on = res["on"]
    r_off, c_off = res["off"]
    assert r_on.time_ns == r_off.time_ns
    assert r_on.per_rank_done_ns == r_off.per_rank_done_ns
    assert c_on.fabric.order_violations == 0
    assert c_off.fabric.order_violations == 0


def test_ledger_parity_all_to_all_switch():
    res = run_ledger_pair(lambda: C.direct_all_to_all(4, 8192, 2, "put"), 4,
                          unroll=8)
    assert res["on"][0].time_ns == res["off"][0].time_ns
    assert res["on"][1].fabric.order_violations == 0


def test_ledger_tie_break_bit_exact_all_to_all_ring():
    """all_to_all over the ring wiring lands symmetric flights on shared
    transit links at the *same integer-picosecond tick*.  Same-tick service
    order used to be heap insertion order — tie-resolution noise no fast
    path preserved.  With the deterministic route tie-break key
    (``fabric.Route``), every mode resolves ties identically: this is now a
    hard bit-exact guarantee across classic/exact/coalesce × ledger."""
    vals = set()
    for mode in ("classic", "exact", "coalesce"):
        for led in ("on", "off"):
            cluster = Cluster(4, noc=NocConfig(fabric_mode=mode,
                                               fabric_ledger=led, **SMALL),
                              topology="ring")
            r = simulate_collective(C.direct_all_to_all(4, 8192, 2, "put"),
                                    cluster=cluster, unroll=8)
            assert cluster.fabric.order_violations == 0
            vals.add((r.time_ns, tuple(r.per_rank_done_ns)))
    assert len(vals) == 1, f"tie-break must make all modes agree: {vals}"


@pytest.mark.parametrize("gen,args", [
    (C.ring_all_reduce, (4, 8192, 1, "put")),
    (C.ring_all_gather, (4, 8192, 2, "get")),
    (C.halving_doubling_all_reduce, (4, 4096, 2)),
])
def test_ledger_parity_torus_wiring(gen, args):
    """Torus scale-up built from InfraGraph edges (to_cluster) must be
    ledger-parity too — the ledger census is wired at warm_routes time for
    graph-built topologies as well."""
    times = {}
    for led in ("on", "off"):
        noc = NocConfig(fabric_ledger=led, **SMALL)
        r = simulate(gen(*args), torus2d_fabric(2, 2), fidelity="fine",
                     noc=noc)
        times[led] = (r.time_ns, tuple(r.per_rank_done_ns))
    assert times["on"] == times["off"]


def test_ledger_parity_exact_mode():
    res = run_ledger_pair(lambda: C.ring_all_reduce(4, 16384, 1, "put"), 4,
                          mode="exact")
    assert res["on"][0].time_ns == res["off"][0].time_ns
    assert res["on"][1].fabric.order_violations == 0


def test_ledger_reduces_events_on_tracked_shape():
    """The point of the ledger: strictly fewer heap events on the tracked
    workload shape (small-scale replica of the benchmark)."""
    res = run_ledger_pair(lambda: C.ring_all_reduce(4, 32768, 1, "put"), 4)
    assert res["on"][0].events < res["off"][0].events
    assert res["on"][0].time_ns == res["off"][0].time_ns


# ---------------------------------------------------------------------------
# regression: add_link must reset the feeder/ledger census (ISSUE 4 s.1)
# ---------------------------------------------------------------------------

def test_add_link_resets_feeder_census():
    eng = Engine()
    fab = Fabric(eng)
    a, b, c = fab.add_node("a"), fab.add_node("b"), fab.add_node("c")
    fab.add_link(a, b, 1.0, 10.0)
    l_bc = fab.add_link(b, c, 1.0, 10.0)
    route = fab.route(a, c)
    # census formed: b->c is sole-fed by a->b, a->b is a marked route head
    assert l_bc._sole_feed is route[0]
    assert l_bc._feeders == [route[0]]
    assert route[0]._inj_fed
    # topology mutation: a second way into b makes the old conclusion stale
    d = fab.add_node("d")
    l_db = fab.add_link(d, b, 1.0, 10.0)
    assert l_bc._sole_feed is None, \
        "census must reset when the route space is invalidated"
    assert l_bc._feeders == [] and not route[0]._inj_fed
    # re-registered routes rebuild it — now genuinely multi-fed
    r1 = fab.route(a, c)
    r2 = fab.route(d, c)
    assert r1[-1] is r2[-1]
    assert r1[-1]._sole_feed is False
    assert set(r1[-1]._feeders) == {r1[0], l_db}


def test_add_link_after_traffic_stays_certified():
    """Wire, route, run traffic; then mutate and run more — the monitor
    must stay clean because the census was rebuilt, not inherited."""
    eng = Engine()
    fab = Fabric(eng)
    nodes = [fab.add_node(f"n{i}") for i in range(4)]
    for u, v in zip(nodes, nodes[1:]):
        fab.add_bidi(u, v, 1.0, 20.0)
    got = []
    for _ in range(8):
        fab.send(fab.route(nodes[0], nodes[3]), 128, DATA,
                 lambda f: got.append(eng.now_ps))
    eng.run()
    # mutate: shortcut link changes the shortest path and the feeder sets
    fab.add_link(nodes[0], nodes[2], 1.0, 5.0)
    for _ in range(8):
        fab.send(fab.route(nodes[0], nodes[3]), 128, DATA,
                 lambda f: got.append(eng.now_ps))
    eng.run()
    assert len(got) == 16 and got == sorted(got)
    assert fab.order_violations == 0


# ---------------------------------------------------------------------------
# regression: empty-route deliveries (ISSUE 4 s.2)
# ---------------------------------------------------------------------------

def test_send_at_empty_route_stamps_eta():
    """send_at(route=[], eager=False) used to deliver with eta_ps == -1."""
    eng = Engine()
    fab = Fabric(eng)
    fab.add_node("a")
    seen = []
    fab.send_at([], 64, CONTROL, lambda f: seen.append((f.eta_ps, eng.now_ps)),
                at_ps=1234)
    eng.run()
    assert seen == [(1234, 1234)]


def test_send_at_empty_route_eager_runs_inline():
    eng = Engine()
    fab = Fabric(eng)
    fab.add_node("a")
    seen = []
    fab.send_at([], 64, CONTROL, lambda f: seen.append(f.eta_ps),
                at_ps=777, eager=True)
    assert seen == [777], "eager empty-route delivery must not need an event"
    assert eng.pending == 0


def test_send_empty_route_honors_eager():
    eng = Engine()
    fab = Fabric(eng)
    fab.add_node("a")
    seen = []
    fab.send([], 64, CONTROL, lambda f: seen.append(f.eta_ps), eager=True)
    assert seen == [0], "send() used to ignore eager for empty routes"
    assert eng.pending == 0
    # non-eager still goes through the event queue for causality
    fab.send([], 64, CONTROL, lambda f: seen.append(f.eta_ps))
    assert seen == [0] and eng.pending == 1
    eng.run()
    assert seen == [0, 0]


# ---------------------------------------------------------------------------
# channel-clock unit behavior
# ---------------------------------------------------------------------------

def test_chan_clock_threshold_is_monotone_in_need():
    """clock >= n2 must imply clock >= n1 for n1 <= n2 (the threshold query
    is a lower-bound proof, so it is monotone by construction)."""
    from repro.core.mscclpp import lower_program

    cluster = Cluster(2, noc=NocConfig(**SMALL))
    fab = cluster.fabric
    eng = cluster.engine
    for k in lower_program(C.ring_all_reduce(2, 4096, 1, "put")):
        cluster.dispatch(k)
    cluster.seal()
    # step the engine and probe links as traffic flows
    for _ in range(40):
        eng.run(max_events=50)
        if not eng.pending:
            break
        for link in fab.links[:: max(1, len(fab.links) // 7)]:
            if not link.led:
                continue
            base = eng.now_ps
            for delta in (2_000, 20_000, 200_000):
                if fab.clock_ge_ps(link, base + delta):
                    assert fab.clock_ge_ps(link, base + delta // 2), \
                        "threshold query must be monotone in need"
    assert fab.order_violations == 0
