"""Sweep harness tests (ISSUE 10): grid expansion, canonical content
hashing (cross-process stability), the content-addressed cache with
resume semantics, crash/timeout fault isolation, tier escalation, and the
JSONL row schema."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core.backends import AnalyticConfig, CoarseConfig, FineConfig
from repro.core.canonical import (canonical_json, combine_hashes,
                                  content_hash, hash_of)
from repro.core.chakra import ExecutionTrace
from repro.core.collectives import ring_all_gather, ring_all_reduce
from repro.core.infragraph.blueprints import single_tier_fabric
from repro.sweep import (Escalation, PointSpec, SweepSpec, SweepRunner,
                         run_sweep, select_pareto, select_top_k)
from repro.sweep.store import (ResultStore, existing_keys, read_jsonl,
                               validate_jsonl, validate_row)

sys.path.insert(0, os.path.dirname(__file__))
import sweep_specs  # noqa: E402  (registers test_faulty / test_tiny)

KiB = 1 << 10


# ---------------------------------------------------------------------------
# canonical hashing
# ---------------------------------------------------------------------------

def test_program_content_hash_stable_and_semantic():
    a = ring_all_gather(4, 8 * KiB, 2)
    b = ring_all_gather(4, 8 * KiB, 2)
    c = ring_all_gather(4, 16 * KiB, 2)
    assert a.content_hash() == b.content_hash()
    assert a.content_hash() != c.content_hash()
    # JSON round trip preserves the hash
    from repro.core.mscclpp import Program
    assert Program.from_json(a.to_json()).content_hash() == a.content_hash()


def test_trace_content_hash_ignores_runtime_fields():
    et = ExecutionTrace(num_ranks=2)
    n0 = et.comp(0, "a", flops=10.0)
    et.comp(0, "b", flops=5.0, deps=[n0])
    h = et.content_hash()
    for n in et.nodes:
        n.start_ns, n.end_ns = 123.0, 456.0     # runtime-only mutation
    assert et.content_hash() == h
    assert ExecutionTrace.from_json(et.to_json()).content_hash() == h


def test_infra_and_config_hashes_semantic():
    i1 = single_tier_fabric(4, link_GBps=50.0)
    i2 = single_tier_fabric(4, link_GBps=50.0)
    i3 = single_tier_fabric(4, link_GBps=100.0)
    assert i1.content_hash() == i2.content_hash() != i3.content_hash()
    assert FineConfig().content_hash() == FineConfig().content_hash()
    assert FineConfig().content_hash() != \
        FineConfig(coll_workgroups=2).content_hash()
    assert AnalyticConfig().content_hash() != CoarseConfig().content_hash()


def test_content_hash_cross_process_stable():
    """The cache key must not depend on PYTHONHASHSEED or process state."""
    snippet = textwrap.dedent("""
        from repro.core.collectives import ring_all_reduce
        from repro.core.backends import FineConfig
        from repro.core.infragraph.blueprints import single_tier_fabric
        print(ring_all_reduce(4, 4096, 1).content_hash())
        print(FineConfig(coll_workgroups=2).content_hash())
        print(single_tier_fabric(4, link_GBps=25.0).content_hash())
    """)
    outs = []
    for seed in ("0", "12345"):
        env = dict(os.environ, PYTHONHASHSEED=seed, JAX_PLATFORMS="cpu")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src"),
             env.get("PYTHONPATH", "")])
        outs.append(subprocess.run(
            [sys.executable, "-c", snippet], env=env, text=True,
            capture_output=True, check=True).stdout)
    assert outs[0] == outs[1]
    assert outs[0] == (ring_all_reduce(4, 4096, 1).content_hash() + "\n"
                       + FineConfig(coll_workgroups=2).content_hash() + "\n"
                       + single_tier_fabric(4,
                                            link_GBps=25.0).content_hash()
                       + "\n")


def test_canonical_json_rejects_unknown_and_sorts():
    assert canonical_json({"b": 1, "a": [2, True]}) == '{"a":[2,true],"b":1}'
    with pytest.raises(TypeError):
        canonical_json(object())
    assert combine_hashes(a="x", b="y") != combine_hashes(a="y", b="x")
    assert hash_of(None) == "none"


# ---------------------------------------------------------------------------
# grid + escalation selectors
# ---------------------------------------------------------------------------

def test_grid_cross_product_order():
    spec = SweepSpec(name="g", axes={"x": (1, 2), "y": ("a", "b")},
                     build=lambda c, t: PointSpec(workload=None))
    assert spec.grid() == [{"x": 1, "y": "a"}, {"x": 1, "y": "b"},
                          {"x": 2, "y": "a"}, {"x": 2, "y": "b"}]


def test_spec_validation():
    with pytest.raises(ValueError):
        SweepSpec(name="bad", axes={"x": (1,)})          # no build/run_point
    with pytest.raises(ValueError):
        SweepSpec(name="bad", axes={"x": (1,)},
                  build=lambda c, t: None, tiers=("nope",))
    with pytest.raises(ValueError):
        Escalation(mode="best")
    with pytest.raises(ValueError):
        Escalation(objectives=("time_ns",))              # missing min:/max:


def test_select_top_k_and_pareto():
    rows = [{"time_ns": 30, "events": 1}, {"time_ns": 10, "events": 9},
            {"time_ns": 20, "events": 2}, {"time_ns": 40, "events": 0}]
    top = select_top_k(rows, 2, "min:time_ns")
    assert [r["time_ns"] for r in top] == [10, 20]
    top = select_top_k(rows, 1, "max:events")
    assert top[0]["events"] == 9
    front = select_pareto(rows, ("min:time_ns", "min:events"))
    assert sorted(r["time_ns"] for r in front) == [10, 20, 30, 40]
    front = select_pareto(rows, ("min:time_ns",))
    assert [r["time_ns"] for r in front] == [10]
    # rows missing the objective are excluded, not fatal
    assert select_top_k([{"x": 1}], 3, "min:time_ns") == []


def test_point_key_reflects_content_not_spelling():
    spec = sweep_specs.tiny
    k1, prov = spec.fingerprint({"shard_KiB": 2}, "analytic")
    k2, _ = spec.fingerprint({"shard_KiB": 2}, "analytic")
    k3, _ = spec.fingerprint({"shard_KiB": 4}, "analytic")
    assert k1 == k2 != k3
    assert len(k1) == 64
    assert set(prov) == {"sweep", "version", "tier", "workload", "infra",
                         "config", "run_kw"}


# ---------------------------------------------------------------------------
# store + schema
# ---------------------------------------------------------------------------

def test_result_store_roundtrip(tmp_path):
    store = ResultStore(tmp_path / "cache")
    key = "ab" + "0" * 62
    assert store.get(key) is None and key not in store
    store.put(key, {"status": "ok", "time_ns": 5})
    assert store.get(key) == {"status": "ok", "time_ns": 5}
    assert key in store
    # corrupt entries read as a miss
    (tmp_path / "cache" / "ab" / f"{key}.json").write_text("{not json")
    assert store.get(key) is None


def test_validate_row_schema():
    good = {"sweep": "s", "key": "a" * 64, "tier": "analytic",
            "point": {"x": 1}, "status": "ok", "cached": False,
            "attempts": 1, "point_wall_s": 0.1, "provenance": {},
            "time_ns": 12}
    assert validate_row(good) == []
    assert validate_row(dict(good, time_ns=12.5)) == []
    for broken in (dict(good, status="meh"),
                   dict(good, key="short"),
                   dict(good, status="error"),          # no traceback
                   dict(good, status="timeout"),        # no timeout_s
                   {k: v for k, v in good.items() if k != "point"}):
        assert validate_row(broken), broken
    assert validate_row(dict(good, status="error", error="tb")) == []
    assert validate_row(dict(good, status="timeout", timeout_s=3.0)) == []


# ---------------------------------------------------------------------------
# runner: inline, cache, resume
# ---------------------------------------------------------------------------

def test_inline_run_cache_and_resume(tmp_path):
    out = tmp_path / "tiny.jsonl"
    cache = tmp_path / "cache"
    res = run_sweep(sweep_specs.tiny, jobs=0, out=out, cache=cache,
                    progress=False)
    assert [r["status"] for r in res.rows] == ["ok"] * 4
    assert all(not r["cached"] for r in res.rows)
    first = {r["key"]: r["time_ns"] for r in res.rows}
    n_lines = len(out.read_text().splitlines())
    assert n_lines == 4

    # resume with the same JSONL: zero new rows, identical results
    res2 = run_sweep(sweep_specs.tiny, jobs=0, out=out, cache=cache,
                     progress=False)
    assert len(out.read_text().splitlines()) == n_lines, \
        "resume must not append duplicate rows"
    assert {r["key"]: r["time_ns"] for r in res2.rows} == first

    # fresh JSONL, warm cache: rows replay bit-identically, marked cached
    out2 = tmp_path / "tiny2.jsonl"
    res3 = run_sweep(sweep_specs.tiny, jobs=0, out=out2, cache=cache,
                     progress=False)
    assert all(r["cached"] for r in res3.rows)
    assert {r["key"]: r["time_ns"] for r in res3.rows} == first
    assert validate_jsonl(out2) == {}

    # --fresh ignores both and recomputes (restarting the stream)
    res4 = run_sweep(sweep_specs.tiny, jobs=0, out=out2, cache=cache,
                     fresh=True, progress=False)
    assert all(not r["cached"] for r in res4.rows)
    assert {r["key"]: r["time_ns"] for r in res4.rows} == first
    assert len(out2.read_text().splitlines()) == 4


def test_inline_error_rows_dont_kill_run(tmp_path):
    res = run_sweep(sweep_specs.faulty, jobs=0, out=tmp_path / "f.jsonl",
                    progress=False,
                    points=[{"i": 0, "behavior": "ok"},
                            {"i": 1, "behavior": "raise"},
                            {"i": 4, "behavior": "ok"}])
    assert [r["status"] for r in res.rows] == ["ok", "error", "ok"]
    assert "ValueError: injected failure" in res.rows[1]["error"]
    assert validate_jsonl(tmp_path / "f.jsonl") == {}


# ---------------------------------------------------------------------------
# runner: process pool fault isolation
# ---------------------------------------------------------------------------

def test_worker_crash_timeout_isolation(tmp_path):
    """A dead worker fails one point, never the run; a hung worker gets a
    timeout row; deterministic raises are not retried."""
    out = tmp_path / "faulty.jsonl"
    res = run_sweep(sweep_specs.faulty, jobs=2, out=out, timeout_s=3.0,
                    retries=1, progress=False)
    by_behavior = {r["point"]["behavior"]: r for r in res.rows}
    assert by_behavior["ok"]["status"] == "ok"
    assert res.rows[0]["time_ns"] == 1000 and res.rows[4]["time_ns"] == 1004

    assert by_behavior["raise"]["status"] == "error"
    assert "ValueError: injected failure" in by_behavior["raise"]["error"]
    assert by_behavior["raise"]["attempts"] == 1, \
        "Python exceptions are deterministic and must not be retried"

    assert by_behavior["crash"]["status"] == "error"
    assert "exit code 42" in by_behavior["crash"]["error"]
    assert by_behavior["crash"]["attempts"] == 2, \
        "a crashed worker is retried once (retries=1) before failing"

    assert by_behavior["sleep"]["status"] == "timeout"
    assert by_behavior["sleep"]["timeout_s"] == 3.0

    assert validate_jsonl(out) == {}
    assert len(res.rows) == 5, "the sweep itself must complete"


def test_process_results_match_inline(tmp_path):
    res_p = run_sweep(sweep_specs.tiny, jobs=2, out=tmp_path / "p.jsonl",
                      use_cache=False, progress=False)
    res_i = run_sweep(sweep_specs.tiny, jobs=0, out=tmp_path / "i.jsonl",
                      use_cache=False, progress=False)
    assert [(r["key"], r["time_ns"]) for r in res_p.rows] == \
        [(r["key"], r["time_ns"]) for r in res_i.rows]


# ---------------------------------------------------------------------------
# escalation
# ---------------------------------------------------------------------------

def test_escalation_runs_final_tier_on_survivors(tmp_path):
    spec = SweepSpec(
        name="test_escalate",
        axes={"shard_KiB": (1, 2, 4, 8)},
        build=sweep_specs._tiny_build,
        escalate=Escalation(prefilter="analytic", final="coarse",
                            mode="top_k", k=2,
                            objectives=("min:time_ns",)),
    )
    from repro.sweep import register_sweep
    register_sweep(spec)
    res = run_sweep(spec, jobs=0, out=tmp_path / "esc.jsonl",
                    use_cache=False, progress=False)
    pre = [r for r in res.rows if r["tier"] == "analytic"]
    fin = [r for r in res.rows if r["tier"] == "coarse"]
    assert len(pre) == 4 and len(fin) == 2
    # survivors are the k fastest prefilter points
    fastest = sorted(pre, key=lambda r: r["time_ns"])[:2]
    assert {json.dumps(r["point"], sort_keys=True) for r in fin} == \
        {json.dumps(r["point"], sort_keys=True) for r in fastest}
    # escalated rows are bit-identical to a direct simulate() call
    from repro.core.backends import simulate
    for r in fin:
        ps = sweep_specs._tiny_build(r["point"], "coarse")
        direct = simulate(ps.workload, fidelity="coarse", check="off")
        assert direct.time_ns == r["time_ns"]


def test_tier_override_disables_escalation(tmp_path):
    res = run_sweep(sweep_specs.tiny, jobs=0, tier="analytic",
                    out=tmp_path / "t.jsonl", use_cache=False,
                    progress=False)
    assert {r["tier"] for r in res.rows} == {"analytic"}
    assert len(res.rows) == 4


# ---------------------------------------------------------------------------
# registry + store helpers
# ---------------------------------------------------------------------------

def test_registry_resolve_and_discover():
    from repro.sweep import registry
    assert registry.resolve("test_tiny") is sweep_specs.tiny
    registry.discover(include_benchmarks=False)
    assert "demo_dse" in registry.SWEEPS and "demo_smoke" in registry.SWEEPS
    with pytest.raises(KeyError):
        registry.resolve("no_such_sweep")


def test_read_jsonl_skips_truncated_tail(tmp_path):
    p = tmp_path / "x.jsonl"
    p.write_text('{"key": "a"}\n{"key": "b"}\n{"key": "c", "tr')
    assert [r["key"] for r in read_jsonl(p)] == ["a", "b"]
    assert existing_keys(p) == {"a", "b"}
    assert existing_keys(Path(tmp_path / "missing.jsonl")) == set()
