"""Sweep fixtures importable by runner worker processes.

The runner's children re-import the declaring module to rebuild points, so
fault-injection specs can't live inline in a test function — they live
here, registered at import time, with behavior selected per point by a
``behavior`` coordinate:

* ``ok``    — return a tiny deterministic row;
* ``raise`` — raise ValueError (a deterministic Python failure: the
  runner must record an error row and NOT retry);
* ``crash`` — ``os._exit(42)`` (an infrastructure death: the runner must
  retry, then record an error row naming the exit code);
* ``sleep`` — block far past any test timeout (the runner must terminate
  the child and record a timeout row).
"""

from __future__ import annotations

import os
import time

from repro.core.backends import AnalyticConfig
from repro.core.collectives import ring_all_gather
from repro.sweep import PointSpec, SweepSpec, register_sweep

KiB = 1 << 10


def _faulty_run_point(coords: dict, tier: str) -> dict:
    behavior = coords["behavior"]
    if behavior == "raise":
        raise ValueError("injected failure")
    if behavior == "crash":
        os._exit(42)
    if behavior == "sleep":
        time.sleep(300)
    return {"time_ns": 1000 + coords["i"], "events": 1}


faulty = register_sweep(SweepSpec(
    name="test_faulty",
    points=[
        {"i": 0, "behavior": "ok"},
        {"i": 1, "behavior": "raise"},
        {"i": 2, "behavior": "crash"},
        {"i": 3, "behavior": "sleep"},
        {"i": 4, "behavior": "ok"},
    ],
    run_point=_faulty_run_point,
    timeout_s=3.0,
    retries=1,
))


def _tiny_build(coords: dict, tier: str) -> PointSpec:
    prog = ring_all_gather(2, coords["shard_KiB"] * KiB, 1)
    cfg = AnalyticConfig() if tier == "analytic" else None
    return PointSpec(workload=prog, config=cfg)


tiny = register_sweep(SweepSpec(
    name="test_tiny",
    axes={"shard_KiB": (1, 2, 4, 8)},
    build=_tiny_build,
    tiers=("analytic",),
))
