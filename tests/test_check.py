"""The static workload verifier: seeded bugs must fire the right rule at
the right location, and every built-in generator must verify clean.

Detection tests mutate a known-good program — drop a signal, swap a
semaphore id, shrink a put — and assert the corresponding rule and
``(rank, wg, op_index)``.  The no-false-positive sweep runs every
generator in :mod:`repro.core.collectives` across rank counts and
workgroup splits (the same sweep CI runs via ``python -m repro.check
--collectives``).
"""

import copy
import json
import warnings

import pytest

from repro.core import collectives as C
from repro.core.chakra import ExecutionTrace
from repro.core.check import (CheckError, CheckWarning, check_infrastructure,
                              check_program, check_trace, check_workload)
from repro.core.check.cli import builtin_collective_reports, main as check_cli
from repro.core.infragraph import single_tier_fabric
from repro.core.mscclpp import CollOp, Program
from repro.core.verify import DeadlockError, execute


def find_op(prog: Program, kind: str, rank=None):
    """First (rank, wg, i, op) matching ``kind`` (optionally on ``rank``)."""
    for r, wgs in enumerate(prog.gpus):
        if rank is not None and r != rank:
            continue
        for w, ops in enumerate(wgs):
            for i, o in enumerate(ops):
                if o.op == kind:
                    return r, w, i, o
    raise AssertionError(f"no {kind} op in {prog.name}")


def rules(report):
    return {d.rule for d in report.diagnostics}


# ------------------------------------------------------------ clean sweep
def test_every_builtin_generator_verifies_clean():
    """The acceptance bar: zero diagnostics on all builtin collectives,
    across rank counts, workgroup splits, and protocols."""
    reports = builtin_collective_reports()
    dirty = [(label, rep.format()) for label, rep in reports if not rep.clean]
    assert not dirty, "false positives:\n" + "\n".join(
        f"{label}:\n{text}" for label, text in dirty)
    assert len(reports) > 100    # the sweep actually swept


def test_clean_program_report_shape():
    rep = check_program(C.ring_all_reduce(4, 48, 2, "put"))
    assert rep.ok and rep.clean
    assert rep.errors == [] and rep.warnings == []
    parsed = json.loads(rep.to_json())
    assert parsed["errors"] == 0 and parsed["diagnostics"] == []


# --------------------------------------------------------------- deadlock
def test_dropped_signal_reports_undersignal_at_wait():
    prog = C.ring_all_gather(4, 64, 1, "put")
    r, w, i, sig = find_op(prog, "signal", rank=2)
    target = sig.remote_rank
    del prog.gpus[r][w][i]
    rep = check_program(prog)
    assert not rep.ok
    under = rep.by_rule("DL-UNDERSIGNAL")
    assert under, rep.format()
    # the starved wait is on the dropped signal's target rank
    assert all(d.severity == "error" for d in under)
    assert any(d.loc.rank == target for d in under), rep.format()


def test_swapped_sem_ids_report_deadlock():
    """Exchange two semaphore ids on one rank's signals: its peers wake
    in the wrong order / never, and the checker must find the hang."""
    prog = C.ring_all_gather(4, 64, 1, "put")
    sems = sorted({o.sem for o in prog.gpus[1][0] if o.op == "signal"})
    assert len(sems) >= 2
    a, b = sems[0], sems[1]
    for o in prog.gpus[1][0]:
        if o.op == "signal":
            o.sem = b if o.sem == a else (a if o.sem == b else o.sem)
    rep = check_program(prog)
    assert not rep.ok
    assert rules(rep) & {"DL-CYCLE", "DL-UNDERSIGNAL", "DL-STUCK"}, \
        rep.format()


def test_circular_wait_reports_cycle_with_witness():
    """Two ranks, each signaling only *after* its wait: classic cycle."""
    buffers = {"input": 8, "output": 16}
    gpus = []
    for r in range(2):
        peer = 1 - r
        gpus.append([[
            CollOp("wait", sem=0, expected=1),
            CollOp("signal", remote_rank=peer, sem=0),
        ]])
    prog = Program("circular", "all_gather", 2, buffers, gpus)
    rep = check_program(prog)
    cyc = rep.by_rule("DL-CYCLE")
    assert cyc, rep.format()
    witness = cyc[0].witness
    assert witness and len(witness["cycle"]) >= 2


def test_barrier_arity_mismatch_reported():
    """wg0 runs 2 barriers, wg1 runs 1: rank can never retire the second."""
    buffers = {"input": 8, "output": 8}
    gpus = [[[CollOp("barrier"), CollOp("barrier")],
             [CollOp("barrier")]]]
    prog = Program("lopsided", "all_gather", 1, buffers, gpus)
    rep = check_program(prog)
    assert "DL-BARRIER-ARITY" in rules(rep), rep.format()


def test_static_and_dynamic_deadlock_agree_on_halving_doubling():
    """Regression for the seed's halving-doubling dropped-signal bug: the
    same mutation must be caught statically (DL rule, no execution) and
    dynamically (DeadlockError with blocked-cursor context)."""
    prog = C.halving_doubling_all_reduce(4, 64, 2)
    r, w, i, _ = find_op(prog, "signal")
    del prog.gpus[r][w][i]

    rep = check_program(prog)
    assert not rep.ok
    assert rules(rep) & {"DL-UNDERSIGNAL", "DL-CYCLE", "DL-STUCK"}, \
        rep.format()
    static_cursors = {d.loc.cursor for d in rep.errors
                      if d.rule.startswith("DL-")}

    with pytest.raises(DeadlockError) as exc:
        execute(prog, seed=7)
    blocked = exc.value.blocked
    assert blocked, "DeadlockError must carry blocked-cursor context"
    for b in blocked:
        assert {"rank", "wg", "pc", "op"} <= set(b)
        if b["op"] == "wait":
            assert b["have"] < b["expected"]
    # at least one dynamically-stuck cursor was named statically
    dynamic_cursors = {(b["rank"], b["wg"], b["pc"]) for b in blocked}
    assert static_cursors & dynamic_cursors, \
        (sorted(static_cursors), sorted(dynamic_cursors))
    assert exc.value.semaphores is not None


# ------------------------------------------------------------------ races
def test_dropped_wait_reports_race():
    prog = C.ring_reduce_scatter(4, 48, 1, "put")
    r, w, i, _ = find_op(prog, "wait", rank=0)
    del prog.gpus[r][w][i]
    rep = check_program(prog)
    race = [d for d in rep.diagnostics if d.rule.startswith("RACE-")]
    assert race, rep.format()
    d = race[0]
    assert d.severity == "error"
    assert d.witness["buffer"] == "scratch"
    # witness names both access sites
    assert d.witness["a"] and d.witness["b"]


def test_overlapping_unordered_puts_report_ww_race():
    """Two ranks write the same remote interval with no ordering."""
    buffers = {"input": 16, "output": 16}
    gpus = [
        [[CollOp("put", src_buf="input", src_off=0, dst_buf="output",
                 dst_off=0, size=16, remote_rank=2)]],
        [[CollOp("put", src_buf="input", src_off=0, dst_buf="output",
                 dst_off=8, size=8, remote_rank=2)]],
        [[]],
    ]
    prog = Program("ww_race", "all_to_all", 3, buffers, gpus)
    rep = check_program(prog)
    ww = rep.by_rule("RACE-WW")
    assert ww, rep.format()
    lo, hi = ww[0].witness["overlap"]
    assert (lo, hi) == (8, 16)


def test_read_read_overlap_is_not_a_race():
    """Two ranks *get* from the same remote interval: no diagnostic."""
    buffers = {"input": 16, "output": 16}
    gpus = [
        [[]],
        [[CollOp("get", src_buf="input", src_off=0, dst_buf="output",
                 dst_off=0, size=16, remote_rank=0)]],
        [[CollOp("get", src_buf="input", src_off=0, dst_buf="output",
                 dst_off=0, size=16, remote_rank=0)]],
    ]
    # "broadcast" keeps the output-coverage pass out of the way: this
    # test is about the race pass alone
    prog = Program("rr_ok", "broadcast", 3, buffers, gpus)
    rep = check_program(prog)
    assert not any(d.rule.startswith("RACE-") for d in rep.diagnostics), \
        rep.format()
    assert rep.clean


# --------------------------------------------------- bounds and coverage
def test_oob_transfer_reports_buf_oob_not_raise():
    prog = C.ring_all_gather(4, 64, 1, "put")
    r, w, i, o = find_op(prog, "put", rank=2)
    o.size = 10 ** 6
    rep = check_program(prog)     # reports, never raises
    oob = rep.by_rule("BUF-OOB")
    assert oob and oob[0].loc.cursor == (r, w, i), rep.format()


def test_unknown_buffer_reported():
    prog = C.ring_all_gather(2, 32, 1, "put")
    r, w, i, o = find_op(prog, "copy")
    o.src_buf = "ghost"
    rep = check_program(prog)
    assert "BUF-UNKNOWN" in rules(rep)


def test_truncated_all_gather_reports_coverage_gap():
    prog = C.ring_all_gather(4, 64, 1, "put")
    r, w, i, o = find_op(prog, "put", rank=1)
    o.size -= 8
    rep = check_program(prog)
    cov = rep.by_rule("COV-OUTPUT")
    assert cov, rep.format()
    assert "never written" in cov[0].message


# --------------------------------------------------- Program.validate()
def test_validate_rejects_oob_and_unknown_buffers():
    prog = C.ring_all_gather(4, 64, 1, "put")
    bad = copy.deepcopy(prog)
    find_op(bad, "put")[3].src_off = 10 ** 9
    with pytest.raises(ValueError, match="outside buffer"):
        bad.validate()
    bad = copy.deepcopy(prog)
    find_op(bad, "copy")[3].dst_buf = "nope"
    with pytest.raises(ValueError, match="unknown buffer"):
        bad.validate()


def test_validate_rejects_nonpositive_sizes_and_bad_reduce_ranks():
    prog = C.ring_all_reduce(4, 48, 1, "put")
    bad = copy.deepcopy(prog)
    find_op(bad, "reduce")[3].size = 0
    with pytest.raises(ValueError, match="size > 0"):
        bad.validate()
    bad = copy.deepcopy(prog)
    op = find_op(bad, "reduce")[3]
    buf, off, _ = op.srcs[0]
    op.srcs[0] = (buf, off, 99)
    with pytest.raises(ValueError, match="src rank 99"):
        bad.validate()


def test_validate_rejects_rank_count_mismatch_and_bad_sems():
    prog = C.ring_all_gather(4, 64, 1, "put")
    bad = copy.deepcopy(prog)
    bad.num_ranks = 5
    with pytest.raises(ValueError, match="gpu entries"):
        bad.validate()
    bad = copy.deepcopy(prog)
    find_op(bad, "wait")[3].sem = -2
    with pytest.raises(ValueError, match="sem >= 0"):
        bad.validate()
    bad = copy.deepcopy(prog)
    find_op(bad, "signal")[3].remote_rank = 17
    with pytest.raises(ValueError, match="remote_rank 17"):
        bad.validate()


# ------------------------------------------------------------ trace lint
def _trace_with_coll(n=4, kind="all_gather", algo="ring"):
    et = ExecutionTrace(num_ranks=n)
    comp = {r: et.comp(r, f"c{r}", flops=1e6) for r in range(n)}
    et.coll(0, kind, 4096, algo,
            deps_by_rank={r: [comp[r]] for r in range(n)})
    return et


def test_clean_trace_verifies_clean():
    assert check_trace(_trace_with_coll()).clean


def test_trace_cycle_reported_and_rejected():
    et = _trace_with_coll()
    a = et.comp(0, "x", flops=1)
    b = et.comp(0, "y", flops=1, deps=[a])
    a.deps.append(b.nid)
    rep = check_trace(et, deep=False)
    cyc = rep.by_rule("TR-CYCLE")
    assert cyc and cyc[0].witness["cycle"]
    with pytest.raises(ValueError, match="dependency cycle"):
        et.validate()


def test_trace_dangling_dep_and_missing_rank_reported():
    et = ExecutionTrace(num_ranks=3)
    n0 = et.comp(0, "a", flops=1)
    n0.deps.append(999)
    et.coll(0, "all_gather", 1024, "ring", deps_by_rank={})
    # drop rank 2's half of the collective
    et.nodes = [n for n in et.nodes
                if not (n.kind == "coll" and n.rank == 2)]
    rep = check_trace(et, deep=False)
    assert {"TR-DANGLING", "TR-COLL"} <= rules(rep), rep.format()


def test_trace_deep_check_surfaces_generator_failure():
    """halving_doubling cannot be generated for 3 ranks: the deep check
    reports it instead of blowing up at simulate() time."""
    et = _trace_with_coll(n=3, algo="halving_doubling")
    rep = check_trace(et, deep=True)
    assert not rep.ok
    assert any("cannot be generated" in d.message
               for d in rep.by_rule("TR-COLL")), rep.format()


# ------------------------------------------------------------ infra lint
def test_infra_zero_bandwidth_link_reported():
    infra = single_tier_fabric(2, link_GBps=0.0)
    rep = check_infrastructure(infra)
    assert any(d.severity == "error" for d in rep.by_rule("IG-LINK-BW"))


def test_infra_capacity_below_rank_count_reported():
    infra = single_tier_fabric(2)
    rep = check_infrastructure(infra, num_ranks=64)
    cap = rep.by_rule("IG-CAPACITY")
    assert cap and cap[0].witness["num_ranks"] == 64


def test_infra_clean_fabric_is_clean():
    assert check_infrastructure(single_tier_fabric(4), num_ranks=4).clean


def test_check_workload_merges_infra_without_poisoning_cache():
    prog = C.ring_all_gather(2, 32, 1, "put")
    bad_infra = single_tier_fabric(2, link_GBps=0.0)
    merged = check_workload(prog, bad_infra)
    assert "IG-LINK-BW" in rules(merged)
    # a second check of the same program alone must come back clean
    assert check_workload(prog).clean


# ------------------------------------------------- simulate() integration
def test_simulate_default_warns_on_buggy_program():
    from repro.core.backends import simulate
    prog = C.ring_all_gather(4, 64, 1, "put")
    find_op(prog, "put", rank=1)[3].size -= 8
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        simulate(prog, fidelity="analytic")
    assert any(issubclass(w.category, CheckWarning) for w in caught)


def test_simulate_check_error_raises_with_report():
    from repro.core.backends import simulate
    prog = C.ring_all_gather(4, 64, 1, "put")
    r, w, i, _ = find_op(prog, "signal", rank=2)
    del prog.gpus[r][w][i]
    with pytest.raises(CheckError) as exc:
        simulate(prog, fidelity="analytic", check="error")
    assert exc.value.report.errors
    assert "DL-" in exc.value.report.errors[0].rule


def test_simulate_check_off_and_clean_are_silent():
    from repro.core.backends import simulate
    prog = C.ring_all_gather(4, 64, 1, "put")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        simulate(prog, fidelity="analytic")                  # clean: silent
        bad = copy.deepcopy(prog)
        find_op(bad, "put", rank=1)[3].size -= 8
        simulate(bad, fidelity="analytic", check="off")      # off: silent
    with pytest.raises(ValueError, match="choose 'off'"):
        simulate(prog, fidelity="analytic", check="loud")


def test_simulate_checks_traces_too():
    from repro.core.backends import simulate
    et = _trace_with_coll(n=3, algo="halving_doubling")
    with pytest.raises(CheckError):
        simulate(et, fidelity="analytic", check="error")


# -------------------------------------------------------------------- CLI
def test_cli_clean_program_exits_zero(tmp_path, capsys):
    path = tmp_path / "prog.json"
    path.write_text(C.ring_all_gather(4, 64, 1, "put").to_json())
    assert check_cli([str(path)]) == 0
    assert "0 error(s)" in capsys.readouterr().out


def test_cli_buggy_program_exits_one_with_location(tmp_path, capsys):
    prog = C.ring_all_gather(4, 64, 1, "put")
    r, w, i, _ = find_op(prog, "signal", rank=2)
    del prog.gpus[r][w][i]
    path = tmp_path / "bad.json"
    path.write_text(prog.to_json())
    assert check_cli([str(path)]) == 1
    out = capsys.readouterr().out
    assert "DL-" in out and "rank" in out


def test_cli_json_mode_and_trace_and_infra(tmp_path, capsys):
    ppath = tmp_path / "p.json"
    ppath.write_text(C.ring_all_gather(2, 32, 1, "put").to_json())
    tpath = tmp_path / "t.json"
    tpath.write_text(_trace_with_coll().to_json())
    ipath = tmp_path / "i.json"
    ipath.write_text(single_tier_fabric(2).to_json())
    assert check_cli(["--json", str(ppath), str(tpath), str(ipath)]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert len(payload) == 3
    assert all(entry["errors"] == 0 for entry in payload)


def test_cli_unreadable_file_exits_two(tmp_path, capsys):
    path = tmp_path / "garbage.json"
    path.write_text("{\"what\": 1}")
    assert check_cli([str(path)]) == 2


def test_cli_collectives_sweep_is_clean(capsys):
    assert check_cli(["--collectives"]) == 0
    assert "0 error(s), 0 warning(s)" in capsys.readouterr().out


# ---------------------------------------------------- property mutations
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    GENS = [
        lambda n, nwg: C.ring_all_gather(n, 32 * n * nwg, nwg, "put"),
        lambda n, nwg: C.ring_reduce_scatter(n, 32 * n * nwg, nwg, "put"),
        lambda n, nwg: C.ring_all_reduce(n, 32 * n * nwg, nwg, "put"),
        lambda n, nwg: C.direct_all_gather(n, 32 * n * nwg, nwg, "get"),
    ]

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, len(GENS) - 1), st.integers(2, 6),
           st.integers(1, 2), st.integers(0, 10 ** 6))
    def test_dropping_any_signal_is_always_caught(gi, n, nwg, pick):
        prog = GENS[gi](n, nwg)
        sigs = [(r, w, i) for r, wgs in enumerate(prog.gpus)
                for w, ops in enumerate(wgs)
                for i, o in enumerate(ops) if o.op == "signal"]
        if not sigs:
            return
        r, w, i = sigs[pick % len(sigs)]
        del prog.gpus[r][w][i]
        rep = check_program(prog)
        assert not rep.ok, rep.format()
        assert any(d.rule.startswith("DL-") for d in rep.errors)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, len(GENS) - 1), st.integers(2, 6),
           st.integers(0, 10 ** 6), st.integers(1, 31))
    def test_shrinking_any_put_is_always_caught(gi, n, pick, shrink):
        prog = GENS[gi](n, 1)
        puts = [(r, w, i) for r, wgs in enumerate(prog.gpus)
                for w, ops in enumerate(wgs)
                for i, o in enumerate(ops)
                if o.op in ("put", "get") and o.dst_buf == "output"]
        if not puts:
            return
        r, w, i = puts[pick % len(puts)]
        o = prog.gpus[r][w][i]
        if o.size <= shrink:
            return
        o.size -= shrink
        rep = check_program(prog)
        assert not rep.clean, rep.format()
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_mutations():
        pass
