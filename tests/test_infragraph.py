"""InfraGraph representation, blueprints, translators, visualizer."""

import json

import pytest

from repro.core.engine import Engine
from repro.core.infragraph import (Infrastructure, clos_fat_tree_fabric,
                                   generic_gpu_device, single_tier_fabric,
                                   summary, switch_device, to_dot, to_fabric,
                                   to_simple_topology, torus2d_fabric,
                                   tpu_pod_fabric, tpu_v5e_device)
from repro.core.network.fabric import DATA


def test_generic_gpu_expands_to_papers_endpoint_census():
    dev = generic_gpu_device()  # paper §5.1 full size
    infra = Infrastructure("one_gpu")
    infra.add(dev, "gpu", 1)
    g = infra.expand()
    assert len(g.nodes_of_kind("cu")) == 128
    assert len(g.nodes_of_kind("hbm")) == 32
    assert len(g.nodes_of_kind("io")) == 32
    assert len(g.nodes_of_kind("router")) == 32
    assert g.connected()


def test_fq_naming_convention():
    infra = single_tier_fabric(num_hosts=2)
    g = infra.expand()
    assert "switch.0.port.0" in g.nodes
    assert "host.1.nic.0" in g.nodes
    # paper's edge example shape: (switch.0.asic.0, switch.0.port.0, link)
    assert ("switch.0.port.0", "switch.0.asic.0") in g.edges


def test_single_tier_paths_cross_the_switch():
    infra = single_tier_fabric(num_hosts=4)
    g = infra.expand()
    p = g.path("host.0.gpu.0", "host.3.gpu.0")
    assert any(n.startswith("switch.0") for n in p)


def test_clos_fabric_structure_and_connectivity():
    infra = clos_fat_tree_fabric(num_hosts=8, switch_ports=4)
    g = infra.expand()
    # 8 hosts / (4/2 per leaf) = 4 leaves, spine count = ports/2 = 2
    assert len({n.split(".")[1] for n in g.nodes if n.startswith("leaf.")}) == 4
    assert len({n.split(".")[1] for n in g.nodes if n.startswith("spine.")}) == 2
    assert g.connected()
    # host0 -> host7 must traverse leaf and spine tiers
    p = g.path("host.0.gpu.0", "host.7.gpu.0")
    assert any(n.startswith("spine.") for n in p)


def test_torus_wraps():
    infra = torus2d_fabric(4, 4)
    g = infra.expand()
    assert g.connected()
    # wraparound: chip (0,0) to chip (3,0) is one hop through the -x link
    p = g.path("chip.0.core.0", "chip.12.core.0")
    # path: core -> ici port -> ici port -> core = 4 nodes
    assert len(p) <= 5


def test_json_round_trip():
    infra = clos_fat_tree_fabric(num_hosts=4, switch_ports=4)
    infra2 = Infrastructure.from_json(infra.to_json())
    g1, g2 = infra.expand(), infra2.expand()
    assert set(g1.nodes) == set(g2.nodes)
    assert set(g1.edges) == set(g2.edges)


def test_translator_to_fabric_moves_a_message():
    infra = single_tier_fabric(num_hosts=2)
    fab, g = to_fabric(infra)
    done = {}
    route = fab.route(fab.node("host.0.gpu.0"), fab.node("host.1.gpu.0"))
    fab.send(route, 4096, DATA, lambda f: done.setdefault("t", fab.engine.now))
    fab.engine.run()
    assert "t" in done and done["t"] > 0


def test_translator_pattern_detection():
    t1 = to_simple_topology(single_tier_fabric(num_hosts=4))
    assert t1.dims[0][3] == "switch" and t1.num_gpus == 4
    t2 = to_simple_topology(clos_fat_tree_fabric(num_hosts=8, switch_ports=4))
    assert len(t2.dims) == 2 and t2.num_gpus == 8
    t3 = to_simple_topology(torus2d_fabric(4, 4))
    assert [d[3] for d in t3.dims] == ["ring", "ring"] and t3.num_gpus == 16


def test_multi_pod_tpu_fabric():
    infra = tpu_pod_fabric(pods=2, dim_x=4, dim_y=4)
    g = infra.expand()
    assert len(g.nodes_of_kind("core")) == 32
    assert g.connected()
    # cross-pod path must use the DCN tier
    p = g.path("chip.0.core.0", "chip.31.core.0")
    assert any(n.startswith("dcn.") for n in p)


def test_visualizer_outputs():
    infra = clos_fat_tree_fabric(num_hosts=4, switch_ports=4)
    dot = to_dot(infra)
    assert dot.startswith("digraph") and "leaf.0" in dot
    s = summary(infra)
    assert "connected=True" in s


def test_bad_fabric_edge_raises():
    infra = single_tier_fabric(num_hosts=2)
    infra.edges.append((("host", 9, "nic", 0), ("switch", 0, "port", 0),
                        "eth"))
    with pytest.raises(KeyError):
        infra.expand()
