"""InfraGraph representation, blueprints, translators, visualizer."""


import pytest

from repro.core.infragraph import (Infrastructure, clos_fat_tree_fabric,
                                   generic_gpu_device, single_tier_fabric,
                                   summary, switch_device, to_dot, to_fabric,
                                   to_simple_topology, torus2d_fabric,
                                   tpu_pod_fabric)
from repro.core.network.fabric import DATA


def test_generic_gpu_expands_to_papers_endpoint_census():
    dev = generic_gpu_device()  # paper §5.1 full size
    infra = Infrastructure("one_gpu")
    infra.add(dev, "gpu", 1)
    g = infra.expand()
    assert len(g.nodes_of_kind("cu")) == 128
    assert len(g.nodes_of_kind("hbm")) == 32
    assert len(g.nodes_of_kind("io")) == 32
    assert len(g.nodes_of_kind("router")) == 32
    assert g.connected()


def test_fq_naming_convention():
    infra = single_tier_fabric(num_hosts=2)
    g = infra.expand()
    assert "switch.0.port.0" in g.nodes
    assert "host.1.nic.0" in g.nodes
    # paper's edge example shape: (switch.0.asic.0, switch.0.port.0, link)
    assert ("switch.0.port.0", "switch.0.asic.0") in g.edges


def test_single_tier_paths_cross_the_switch():
    infra = single_tier_fabric(num_hosts=4)
    g = infra.expand()
    p = g.path("host.0.gpu.0", "host.3.gpu.0")
    assert any(n.startswith("switch.0") for n in p)


def test_clos_fabric_structure_and_connectivity():
    infra = clos_fat_tree_fabric(num_hosts=8, switch_ports=4)
    g = infra.expand()
    # 8 hosts / (4/2 per leaf) = 4 leaves, spine count = ports/2 = 2
    assert len({n.split(".")[1] for n in g.nodes if n.startswith("leaf.")}) == 4
    assert len({n.split(".")[1] for n in g.nodes if n.startswith("spine.")}) == 2
    assert g.connected()
    # host0 -> host7 must traverse leaf and spine tiers
    p = g.path("host.0.gpu.0", "host.7.gpu.0")
    assert any(n.startswith("spine.") for n in p)


def test_torus_wraps():
    infra = torus2d_fabric(4, 4)
    g = infra.expand()
    assert g.connected()
    # wraparound: chip (0,0) to chip (3,0) is one hop through the -x link
    p = g.path("chip.0.core.0", "chip.12.core.0")
    # path: core -> ici port -> ici port -> core = 4 nodes
    assert len(p) <= 5


def test_json_round_trip():
    infra = clos_fat_tree_fabric(num_hosts=4, switch_ports=4)
    infra2 = Infrastructure.from_json(infra.to_json())
    g1, g2 = infra.expand(), infra2.expand()
    assert set(g1.nodes) == set(g2.nodes)
    assert set(g1.edges) == set(g2.edges)


def test_translator_to_fabric_moves_a_message():
    infra = single_tier_fabric(num_hosts=2)
    fab, g = to_fabric(infra)
    done = {}
    route = fab.route(fab.node("host.0.gpu.0"), fab.node("host.1.gpu.0"))
    fab.send(route, 4096, DATA, lambda f: done.setdefault("t", fab.engine.now))
    fab.engine.run()
    assert "t" in done and done["t"] > 0


def test_translator_pattern_detection():
    t1 = to_simple_topology(single_tier_fabric(num_hosts=4))
    assert t1.dims[0][3] == "switch" and t1.num_gpus == 4
    t2 = to_simple_topology(clos_fat_tree_fabric(num_hosts=8, switch_ports=4))
    assert len(t2.dims) == 2 and t2.num_gpus == 8
    t3 = to_simple_topology(torus2d_fabric(4, 4))
    assert [d[3] for d in t3.dims] == ["ring", "ring"] and t3.num_gpus == 16


def test_multi_pod_tpu_fabric():
    infra = tpu_pod_fabric(pods=2, dim_x=4, dim_y=4)
    g = infra.expand()
    assert len(g.nodes_of_kind("core")) == 32
    assert g.connected()
    # cross-pod path must use the DCN tier
    p = g.path("chip.0.core.0", "chip.31.core.0")
    assert any(n.startswith("dcn.") for n in p)


def test_visualizer_outputs():
    infra = clos_fat_tree_fabric(num_hosts=4, switch_ports=4)
    dot = to_dot(infra)
    assert dot.startswith("digraph") and "leaf.0" in dot
    s = summary(infra)
    assert "connected=True" in s


def test_bad_fabric_edge_raises():
    infra = single_tier_fabric(num_hosts=2)
    infra.edges.append((("host", 9, "nic", 0), ("switch", 0, "port", 0),
                        "eth"))
    with pytest.raises(KeyError):
        infra.expand()


# ---------------------------------------------------------------------------
# to_cluster: InfraGraph-native fine-grained wiring
# ---------------------------------------------------------------------------

from repro.core.cluster import NocConfig
from repro.core.infragraph import to_cluster
from repro.core.infragraph.blueprints import ring_fabric


def _noc():
    return NocConfig(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
                     io_ports=4)


def _scaleup_links(cluster):
    """Links added from InfraGraph edges (named with their link type)."""
    return [l for l in cluster.fabric.links if ":" in l.name]


def test_to_cluster_switch_wiring_from_graph():
    infra = single_tier_fabric(num_hosts=4, link_GBps=50.0, link_lat_ns=777.0)
    cl = to_cluster(infra, noc=_noc())
    assert len(cl.gpus) == 4
    # the switch device's ports/asic became fabric nodes
    assert any(n.startswith("switch.0.") for n in cl.fabric.node_names)
    # scale-up link properties come from the graph edge, NOT NocConfig
    eth = [l for l in _scaleup_links(cl) if l.name.endswith(":eth")]
    assert eth and all(l.bw == 50.0 and l.lat_ns == 777.0 for l in eth)
    assert all(l.bw != _noc().io_GBps_per_port for l in eth)


def test_to_cluster_ring_wiring_has_no_switch():
    infra = ring_fabric(4, link_GBps=42.0, link_lat_ns=900.0)
    cl = to_cluster(infra, noc=_noc())
    assert len(cl.gpus) == 4
    assert not any("switch" in n or "scaleup" in n
                   for n in cl.fabric.node_names)
    ring = [l for l in _scaleup_links(cl) if l.name.endswith(":ring")]
    assert len(ring) == 8  # 4 directed pairs
    assert all(l.bw == 42.0 and l.lat_ns == 900.0 for l in ring)


def test_to_cluster_leaf_spine_wiring():
    infra = clos_fat_tree_fabric(num_hosts=4, switch_ports=4)
    cl = to_cluster(infra, noc=_noc())
    names = cl.fabric.node_names
    assert any(n.startswith("leaf.") for n in names)
    assert any(n.startswith("spine.") for n in names)
    # a cross-leaf route must traverse a spine port
    g0 = cl.gpus[0].io_nodes[0]
    g3 = cl.gpus[3].io_nodes[0]
    path = cl.fabric.route(g0, g3)
    assert any("spine." in l.name for l in path)


def test_to_cluster_torus_wiring():
    infra = torus2d_fabric(2, 2)
    cl = to_cluster(infra, noc=_noc())
    assert len(cl.gpus) == 4
    ici = [l for l in _scaleup_links(cl) if l.name.endswith(":ici")]
    assert len(ici) == 16  # 8 bidi torus edges (x-wrap + y-wrap per chip)


def test_to_cluster_bandwidth_override_changes_collective_time():
    """Regression: graph link bandwidth must actually shape timing — a
    fatter InfraGraph fabric runs the same collective faster."""
    from repro.core.backends import simulate
    from repro.core import collectives as C
    slow = simulate(C.ring_all_reduce(4, 32768, 1, "put"),
                    ring_fabric(4, link_GBps=8.0), fidelity="fine",
                    noc=_noc())
    fast = simulate(C.ring_all_reduce(4, 32768, 1, "put"),
                    ring_fabric(4, link_GBps=64.0), fidelity="fine",
                    noc=_noc())
    assert fast.time_ns < slow.time_ns


def test_to_cluster_rejects_edgeless_multi_gpu_infra():
    infra = Infrastructure("lonely")
    from repro.core.infragraph.blueprints import simple_gpu_device
    infra.add(simple_gpu_device(), "host", 3)
    with pytest.raises(ValueError, match="no fabric edges"):
        to_cluster(infra, noc=_noc())


def _multi_gpu_host_infra(hosts=2, gpus=2):
    """Two host_device(gpus=2) servers, each GPU's NIC on its own switch
    port — the ISSUE's rank-per-component scenario."""
    from repro.core.infragraph.blueprints import host_device
    from repro.core.infragraph.graph import LinkType
    dev = host_device(gpus=gpus)
    infra = Infrastructure("mg_hosts")
    infra.add(dev, "host", hosts)
    infra.add(switch_device(hosts * gpus, 50.0), "switch", 1)
    infra.add_link_type(LinkType("eth", 50.0, 600.0))
    for h in range(hosts):
        for k in range(gpus):
            infra.connect(("host", h, "nic", k),
                          ("switch", 0, "port", h * gpus + k), "eth")
    return infra


def test_to_cluster_multi_gpu_host_maps_rank_per_component():
    infra = _multi_gpu_host_infra(hosts=2, gpus=2)
    cl = to_cluster(infra, noc=_noc())
    # one detailed GPU per endpoint *component*, not per device
    assert len(cl.gpus) == 4
    # each GPU's NIC edge lands on the matching rank's own I/O port:
    # rank order is host.0.gpu.0, host.0.gpu.1, host.1.gpu.0, host.1.gpu.1
    for h in range(2):
        for k in range(2):
            rank = h * 2 + k
            assert any(l.name == f"host.{h}.nic.{k}"
                                 f"->switch.0.port.{rank}:eth"
                       for l in cl.fabric.links)
            # the NIC aliases onto rank's own I/O port: one eth hop from
            # that port to the switch
            io = cl.gpus[rank].io_nodes[k % len(cl.gpus[rank].io_nodes)]
            route = cl.fabric.route(
                io, cl.fabric.node(f"switch.0.port.{rank}"))
            assert len(route) == 1 and route[0].lat_ns == 600.0


def test_to_cluster_multi_gpu_host_shares_bridge_intra_host():
    """The host's PCIe bridge (wired to every GPU) stays a fabric node, so
    intra-host GPU-to-GPU traffic crosses the bridge, not the switch."""
    infra = _multi_gpu_host_infra(hosts=2, gpus=2)
    cl = to_cluster(infra, noc=_noc())
    assert "host.0.bridge.0" in cl.fabric.node_names
    # intra-host: io -> bridge -> io (2 hops), never via the switch
    r = cl.fabric.route(cl.gpus[0].io_nodes[0], cl.gpus[1].io_nodes[0])
    assert any("bridge" in l.name for l in r)
    assert not any("switch" in l.name for l in r)
    # cross-host: must use the scale-out switch
    r2 = cl.fabric.route(cl.gpus[0].io_nodes[0], cl.gpus[2].io_nodes[0])
    assert any("switch" in l.name for l in r2)


def test_to_cluster_multi_gpu_host_runs_collective():
    """End-to-end: a fine-tier all-reduce over rank-per-component mapping
    completes, stays FIFO-certified, and agrees across fabric modes."""
    from repro.core import collectives as C
    from repro.core.backends import FineBackend
    times = set()
    for mode in ("exact", "coalesce"):
        noc = _noc()
        noc.fabric_mode = mode
        be = FineBackend(infra=_multi_gpu_host_infra(hosts=2, gpus=2),
                         noc=noc)
        r = be.run(C.ring_all_reduce(4, 4096, 1, "put"))
        assert r.time_ns > 0
        times.add(r.time_ns)
    assert len(times) == 1


# ---------------------------------------------------------------------------
# hierarchical multi-host blueprints (ISSUE 9)
# ---------------------------------------------------------------------------

from repro.core.infragraph import hierarchical_fabric  # noqa: E402


def test_hierarchical_fabric_leafspine_structure():
    infra = hierarchical_fabric(hosts=4, gpus_per_host=4)
    g = infra.expand()
    assert len(g.nodes_of_kind("gpu")) == 16
    names = set(g.nodes)
    assert any(n.startswith("leaf.") for n in names)
    assert any(n.startswith("spine.") for n in names)
    # one scale-up bridge per host
    assert sum(1 for n in names if ".bridge." in n) == 4


def test_hierarchical_fabric_switch_and_single_host():
    sw = hierarchical_fabric(hosts=2, gpus_per_host=2, scaleout="switch")
    assert any(n.startswith("switch.") for n in sw.expand().nodes)
    solo = hierarchical_fabric(hosts=1, gpus_per_host=4)
    names = set(solo.expand().nodes)
    assert not any("leaf" in n or "spine" in n or "switch" in n
                   for n in names)
    with pytest.raises(ValueError):
        hierarchical_fabric(hosts=2, gpus_per_host=2, scaleout="mesh")


def test_hierarchical_to_cluster_tiers():
    """Per-tier link types survive translation: intra-host routes cross
    the scale-up bridge, inter-host routes leave via NIC -> leaf/spine."""
    from repro.core.cluster import NocConfig
    infra = hierarchical_fabric(hosts=2, gpus_per_host=2)
    cl = to_cluster(infra, noc=NocConfig(mesh_x=2, mesh_y=1,
                                         cus_per_router=1, mem_channels=2,
                                         io_ports=2))
    assert len(cl.gpus) == 4
    intra = cl.fabric.route(cl.gpus[0].io_nodes[0], cl.gpus[1].io_nodes[0])
    assert any("bridge" in l.name for l in intra)
    assert not any("leaf" in l.name or "spine" in l.name for l in intra)
    inter = cl.fabric.route(cl.gpus[0].io_nodes[0], cl.gpus[2].io_nodes[0])
    assert any("leaf" in l.name for l in inter)
