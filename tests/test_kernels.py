"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
sweeping shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rg_lru import rg_lru_scan
from repro.kernels.rwkv6_wkv import wkv6


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.5).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------- attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Kh,T,S,D", [
    (1, 4, 4, 128, 128, 64),       # MHA square
    (2, 4, 2, 128, 256, 64),       # GQA, kv longer (cross-ish)
    (1, 8, 1, 256, 256, 128),      # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, H, Kh, T, S, D, dtype, causal):
    if causal and T != S:
        pytest.skip("causal requires aligned positions here")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, H, T, D), dtype)
    k = _rand(ks[1], (B, Kh, S, D), dtype)
    v = _rand(ks[2], (B, Kh, S, D), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_window():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 2, 256, 64), jnp.float32)
    k = _rand(ks[1], (1, 2, 256, 64), jnp.float32)
    v = _rand(ks[2], (1, 2, 256, 64), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=64, block_q=64,
                          block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_kv_len_mask():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (1, 2, 128, 64), jnp.float32)
    k = _rand(ks[1], (1, 2, 256, 64), jnp.float32)
    v = _rand(ks[2], (1, 2, 256, 64), jnp.float32)
    got = flash_attention(q, k, v, causal=False, kv_len=200, block_q=64,
                          block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False, kv_len=200)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("blocks", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (1, 2, 128, 64), jnp.float32)
    k = _rand(ks[1], (1, 2, 128, 64), jnp.float32)
    v = _rand(ks[2], (1, 2, 128, 64), jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------- rwkv6
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,T,N,chunk", [
    (1, 2, 64, 64, 16),
    (2, 4, 96, 32, 32),
    (1, 1, 128, 64, 64),
])
def test_wkv6_kernel_matches_sequential_ref(B, H, T, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = _rand(ks[0], (B, H, T, N), dtype)
    k = _rand(ks[1], (B, H, T, N), dtype)
    v = _rand(ks[2], (B, H, T, N), dtype)
    # log decay in a realistic range (w in ~[0.6, 0.999])
    logw = (-jnp.exp(jax.random.normal(ks[3], (B, H, T, N)) - 2.0)
            ).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, N)) * 0.3).astype(jnp.float32)
    got = wkv6(r, k, v, logw.astype(dtype), u, chunk=chunk, interpret=True)
    want = ref.wkv6_ref(r, k, v, logw.astype(dtype), u)
    tol = dict(rtol=5e-3, atol=5e-3) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_wkv6_model_chunked_form_matches_sequential():
    """The model's jnp chunked formulation == the sequential oracle."""
    from repro.models.rwkv6 import wkv6_chunked
    B, H, T, N = 2, 2, 80, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r, k, v = (_rand(ks[i], (B, T, H, N), jnp.float32) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, N)) - 2.0)
    u = jnp.abs(jax.random.normal(ks[4], (H, N))) * 0.3
    state = jnp.zeros((B, H, N, N), jnp.float32)
    got, _ = wkv6_chunked(r, k, v, logw, u, state, chunk=16)
    # oracle expects (B,H,T,N)
    tr = lambda a: a.transpose(0, 2, 1, 3)
    want = ref.wkv6_ref(tr(r), tr(k), tr(v), tr(logw), u)
    np.testing.assert_allclose(np.asarray(tr(got)), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ rg_lru
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,R,bt,br", [
    (1, 128, 512, 64, 256),
    (2, 256, 256, 128, 256),
])
def test_rg_lru_kernel_matches_ref(B, T, R, bt, br, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, R))).astype(dtype)
    b = _rand(ks[1], (B, T, R), dtype)
    h0 = _rand(ks[2], (B, R), jnp.float32)
    got = rg_lru_scan(a, b, h0, block_t=bt, block_r=br, interpret=True)
    want = ref.rg_lru_ref(a, b, h0)
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else \
        dict(rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_rg_lru_matches_model_associative_scan():
    """Kernel == the model's associative-scan formulation."""
    # build equivalent a/b from a tiny param set
    B, T, R = 1, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, R)))
    b = _rand(ks[1], (B, T, R), jnp.float32)
    h0 = jnp.zeros((B, R))
    got = rg_lru_scan(a, b, h0, block_t=32, block_r=128, interpret=True)
    want = ref.rg_lru_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- ops dispatch
def test_ops_dispatch_ref_vs_interpret():
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = _rand(ks[0], (1, 128, 4, 64), jnp.float32)   # model layout (B,T,H,D)
    k = _rand(ks[1], (1, 128, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 128, 2, 64), jnp.float32)
    a = ops.attention(q, k, v, causal=True, force="ref")
    b = ops.attention(q, k, v, causal=True, block_q=64, block_k=64,
                      force="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)
