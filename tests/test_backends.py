"""Backend seam: one program + one InfraGraph through every fidelity tier.

Covers the `simulate(program, infra, fidelity=...)` entry point, result
metadata parity, the fidelity ordering the paper predicts (coarse event
counts <= fine event counts; analytic is (near) event-free), and the
InfraGraph-driven cluster wiring.
"""

import pytest

from repro.core import collectives as C
from repro.core.backends import FIDELITIES, ProgramInterpreter, simulate
from repro.core.cluster import NocConfig
from repro.core.infragraph import single_tier_fabric
from repro.core.infragraph.blueprints import ring_fabric

SMALL_NOC = dict(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
                 io_ports=4)


def small_noc(**kw):
    return NocConfig(**SMALL_NOC, **kw)


@pytest.fixture(scope="module")
def results():
    infra = single_tier_fabric(4, link_GBps=50.0)
    out = {}
    for fid in FIDELITIES:
        prog = C.ring_all_reduce(4, 16384, 1, "put")
        out[fid] = simulate(prog, infra, fidelity=fid, noc=small_noc()
                            if fid == "fine" else None) \
            if fid == "fine" else simulate(prog, infra, fidelity=fid)
    return out


def test_all_fidelities_run_and_agree_on_metadata(results):
    for fid, r in results.items():
        assert r.fidelity == fid
        assert r.collective == "all_reduce"
        assert r.nranks == 4
        assert r.moved_bytes == 16384
        assert r.time_ns > 0
        assert r.per_rank_done_ns is not None and len(r.per_rank_done_ns) == 4
        assert max(r.per_rank_done_ns) == r.time_ns


def test_fidelity_event_count_ordering(results):
    """Paper: fidelity buys detail — event counts rise with the tier."""
    assert results["analytic"].events <= results["coarse"].events
    assert results["coarse"].events < results["fine"].events


def test_fidelity_time_plausibility(results):
    """Coarser tiers skip control-path latency, so they run faster; all
    tiers stay within a couple orders of magnitude of each other."""
    fine, coarse = results["fine"], results["coarse"]
    assert coarse.time_ns < fine.time_ns
    assert fine.time_ns / coarse.time_ns < 200


def test_analytic_closed_form_is_event_free(results):
    assert results["analytic"].events == 0
    assert "analytic" in results["analytic"].program


def test_analytic_falls_back_to_interpreter_for_odd_programs():
    # a custom program whose collective kind has no closed form
    prog = C.ring_all_gather(3, 512, 1, "put")
    prog.collective = "my_custom_exchange"
    r = simulate(prog, fidelity="analytic")
    assert r.events > 0 and r.time_ns > 0


def test_unknown_fidelity_raises():
    with pytest.raises(ValueError, match="unknown fidelity"):
        simulate(C.ring_all_gather(2, 256, 1, "put"), fidelity="quantum")


def test_infra_too_small_for_program_raises():
    infra = single_tier_fabric(2)
    with pytest.raises(ValueError, match="endpoints"):
        simulate(C.ring_all_gather(4, 256, 1, "put"), infra,
                 fidelity="coarse")


def test_interpreter_is_shared_single_source():
    """`_CoarseExec` logic exists exactly once: both non-fine tiers run
    programs through the same ProgramInterpreter class."""
    import repro.core.backends.analytic as A
    import repro.core.backends.coarse as Co
    import repro.core.system as S
    assert Co.ProgramInterpreter is ProgramInterpreter
    assert A.ProgramInterpreter is ProgramInterpreter
    assert not hasattr(S, "_CoarseExec")


def test_same_infra_different_fidelity_scenario_diversity():
    """The same ring InfraGraph drives all three tiers (no hard-coded
    switch): ring wiring must shape fine-grained timing differently from a
    single-switch fabric."""
    prog = lambda: C.ring_all_reduce(4, 8192, 1, "put")
    ring = simulate(prog(), ring_fabric(4, link_GBps=34.36),
                    fidelity="fine", noc=small_noc())
    star = simulate(prog(), single_tier_fabric(4, link_GBps=34.36),
                    fidelity="fine", noc=small_noc())
    assert ring.time_ns != star.time_ns


@pytest.mark.slow
def test_backend_parity_sweep_larger():
    """Expensive sweep: metadata parity over sizes x collectives."""
    infra = single_tier_fabric(4)
    for gen, kwargs in [(C.ring_all_gather, {}), (C.ring_all_reduce, {}),
                        (C.direct_reduce_scatter, dict(protocol="get"))]:
        for size in (4096, 65536):
            rs = {}
            for fid in FIDELITIES:
                prog = gen(4, size, 2, **kwargs) if kwargs else \
                    gen(4, size, 2)
                rs[fid] = simulate(prog, infra, fidelity=fid,
                                   **({"noc": small_noc()}
                                      if fid == "fine" else {}))
            assert rs["analytic"].events <= rs["coarse"].events \
                <= rs["fine"].events
            assert len({r.moved_bytes for r in rs.values()}) == 1
