"""Bulk wavefront emission (ISSUE 2 tentpole).

The CU may emit a contiguous load/store streak as one batched request train
(``NocConfig.bulk_emission="on"``, the default) instead of one scheduling
round trip per cache line.  The contract: *identical* simulated timing —
``time_ns`` and every rank's completion time match the per-instruction path
bit for bit, certified by the per-link FIFO monitor — across scale-up
wirings and collectives; only the wall-clock/event cost may differ.
"""

import pytest

from repro.core import collectives as C
from repro.core.cluster import Cluster, NocConfig
from repro.core.engine import Engine
from repro.core.instructions import LOAD, REDUCE, STORE, WAITCNT, entry_of
from repro.core.network.fabric import (DATA, Fabric, Flight, MODE_COALESCE,
                                       MODE_EXACT)
from repro.core.operations import (FusedReduceOp, LoadOp, MemcpyOp,
                                   OpContext, StoreOp)
from repro.core.instructions import MemRef, Space
from repro.core.system import simulate_collective

SMALL = dict(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
             io_ports=4)


def run_bulk_pair(prog_fn, nranks, *, topology="switch", mode="coalesce",
                  **sim_kw):
    out = {}
    for bulk in ("on", "off"):
        cluster = Cluster(nranks, noc=NocConfig(fabric_mode=mode,
                                                bulk_emission=bulk, **SMALL),
                          topology=topology)
        r = simulate_collective(prog_fn(), cluster=cluster, **sim_kw)
        out[bulk] = (r, cluster)
    return out


# ---------------------------------------------------------------------------
# property-style parity: bulk on == bulk off, across wirings x collectives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", ["switch", "ring"])
@pytest.mark.parametrize("gen,args,kw", [
    (C.ring_all_reduce, (4, 16384, 2, "put"), {}),
    (C.ring_all_gather, (4, 8192, 1, "get"), {}),
    (C.direct_reduce_scatter, (4, 8192, 2, "get"), {}),
    (C.direct_all_to_all, (4, 8192, 1, "put"), dict(unroll=8)),
    (C.halving_doubling_all_reduce, (4, 8192, 2), {}),
])
def test_bulk_parity_cluster_wirings(topology, gen, args, kw):
    res = run_bulk_pair(lambda: gen(*args), args[0], topology=topology, **kw)
    r_on, c_on = res["on"]
    r_off, c_off = res["off"]
    assert r_on.time_ns == r_off.time_ns
    assert r_on.per_rank_done_ns == r_off.per_rank_done_ns
    assert c_on.fabric.order_violations == 0
    assert c_off.fabric.order_violations == 0


@pytest.mark.parametrize("mode", [MODE_EXACT, MODE_COALESCE])
def test_bulk_parity_torus_infragraph(mode):
    """Torus wiring from an InfraGraph (to_cluster path)."""
    from repro.core.backends import FineBackend
    from repro.core.infragraph.blueprints import torus2d_fabric
    times = {}
    for bulk in ("on", "off"):
        noc = NocConfig(fabric_mode=mode, bulk_emission=bulk, **SMALL)
        be = FineBackend(infra=torus2d_fabric(2, 2), noc=noc)
        cluster = be.make_cluster(4)
        r = be.run(C.ring_all_reduce(4, 8192, 2, "put"), cluster=cluster)
        times[bulk] = (r.time_ns, tuple(r.per_rank_done_ns))
        assert cluster.fabric.order_violations == 0
    assert times["on"] == times["off"]


def test_bulk_emission_emits_fewer_or_equal_events():
    """Bulk emission trims scheduling events (or close to: with the
    reservation ledger, single lines chain through the whole route so
    cheaply that batching them into trains — which split under the
    own-delivery cap — can cost a few percent more events at small
    scales; timing stays bit-exact either way)."""
    res = run_bulk_pair(lambda: C.ring_all_reduce(4, 32768, 1, "put"), 4)
    assert res["on"][0].events <= res["off"][0].events * 1.03
    assert res["on"][0].requests == res["off"][0].requests


# ---------------------------------------------------------------------------
# compiled instruction streams (the arena the bulk path reads)
# ---------------------------------------------------------------------------

def _hbm(gpu, addr):
    return MemRef(gpu, Space.HBM, addr)


@pytest.mark.parametrize("op", [
    LoadOp(_hbm(0, 0), 128 * 10 + 17),
    StoreOp(_hbm(1, 4096), 128 * 7),
    MemcpyOp(_hbm(0, 0), _hbm(1, 1 << 20), 128 * 9 + 5, unroll=4),
    FusedReduceOp(srcs=[_hbm(0, 0), _hbm(1, 8192)], dst=_hbm(0, 1 << 20),
                  size=128 * 6 + 64, unroll=2),
])
@pytest.mark.parametrize("wf,num_wf", [(0, 4), (3, 4), (1, 2)])
def test_compiled_stream_matches_generator_spec(op, wf, num_wf):
    """The arithmetic compilers must equal the generator specification."""
    ctx = OpContext(cache_line=128, unroll=1, reduce_cycles_per_line=2)
    want = [entry_of(i) for i in op.instructions(wf, num_wf, ctx)]
    stream = op.compile(wf, num_wf, ctx)
    assert stream.entries == want


def test_compiled_stream_runs_mark_streaks():
    """runs[i] = length of the LOAD/STORE streak starting at entry i."""
    ctx = OpContext(cache_line=128, unroll=4)
    stream = MemcpyOp(_hbm(0, 0), _hbm(0, 1 << 20), 128 * 8).compile(
        0, 1, ctx)
    kinds = [e[0] for e in stream.entries]
    assert kinds == [LOAD] * 4 + [WAITCNT] + [STORE] * 4 + \
                    [LOAD] * 4 + [WAITCNT] + [STORE] * 4
    # at the first load of each unroll group the whole group is one streak
    assert stream.runs[0] == 4
    assert stream.runs[3] == 1          # last load before the fence
    assert stream.runs[4] == 0          # the fence itself
    # group 0's stores run straight into group 1's loads (no fence between)
    assert stream.runs[5] == 4 + 4
    assert stream.runs[8] == 1 + 4      # last store + next group's 4 loads


def test_fused_reduce_compile_includes_reduce_cycles():
    ctx = OpContext(cache_line=128, reduce_cycles_per_line=3)
    stream = FusedReduceOp(srcs=[_hbm(0, 0), _hbm(0, 4096), _hbm(1, 0)],
                           dst=_hbm(0, 1 << 20), size=128 * 4).compile(0, 4, ctx)
    kinds = [e[0] for e in stream.entries]
    assert kinds == [LOAD, LOAD, LOAD, WAITCNT, REDUCE, STORE]
    reduce_entry = stream.entries[4]
    assert reduce_entry[5] == 1 * 2 * 3  # lines * (k-1) * cycles_per_line


# ---------------------------------------------------------------------------
# Fabric.inject_train: batched injection rides the coalescing machinery
# ---------------------------------------------------------------------------

def _mk_flight(size, route):
    f = Flight(size, DATA, route, lambda g: None)
    return f


def test_inject_train_matches_per_line_send_at():
    """A batched train must produce bit-identical arrivals to per-line
    ``send_at`` at the same ticks, with no FIFO violations."""
    def run(batched):
        eng = Engine()
        fab = Fabric(eng, mode=MODE_COALESCE)
        a, b, c = fab.add_node("a"), fab.add_node("b"), fab.add_node("c")
        fab.add_link(a, b, 2.0, 30.0)
        fab.add_link(b, c, 2.0, 30.0)
        route = fab.route(a, c)
        arrivals = []

        def on_arrive(f):
            arrivals.append((eng.now_ps, f.size))

        ticks = [1000 * (i + 1) for i in range(16)]
        if batched:
            flights = []
            for i in range(16):
                f = Flight(100 + i, DATA, route, on_arrive)
                flights.append(f)
            fab.inject_train(route, flights, ticks)
        else:
            for i in range(16):
                fab.send_at(route, 100 + i, DATA, on_arrive, at_ps=ticks[i])
        eng.run()
        return arrivals, eng.events_processed, fab.order_violations

    per_line, ev_line, viol_line = run(False)
    batched, ev_batch, viol_batch = run(True)
    assert batched == per_line
    assert viol_line == 0 and viol_batch == 0
    assert ev_batch <= ev_line


def test_inject_train_joins_pending_tail():
    """A second batch injected while the first train's hop event is still
    pending joins it instead of scheduling another event."""
    eng = Engine()
    fab = Fabric(eng, mode=MODE_COALESCE)
    a, b = fab.add_node("a"), fab.add_node("b")
    fab.add_link(a, b, 1.0, 500.0)
    route = fab.route(a, b)
    got = []
    flights = [Flight(64, DATA, route, lambda f: got.append(eng.now_ps))
               for _ in range(2)]
    fab.inject_train(route, flights[:1], [0])
    fab.inject_train(route, flights[1:], [10])
    # both lines ride the first train's single pending event
    tail = route[0]._tails[id(route)]
    assert len(tail.lines) == 2
    eng.run()
    assert len(got) == 2 and got == sorted(got)
