"""Distributed layer: sharding plans, checkpoint/restore + elastic remesh,
data pipeline, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ShapeConfig, get, reduced
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.distributed import hints
from repro.distributed import sharding as shard
from repro.distributed.checkpoint import CheckpointManager
from repro.models import api
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step


def test_param_specs_cover_full_llama_tree():
    cfg = get("llama3-8b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    abs_params = jax.eval_shape(
        lambda k: api.init_params(k, cfg), jax.random.PRNGKey(0))
    specs = shard.params_specs(abs_params, cfg, mesh)
    flat_p = jax.tree.leaves(abs_params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= len(p.shape)


def test_param_specs_divisible_on_production_mesh_shapes():
    """Every spec'd axis must divide the dimension it shards (16x16)."""
    for arch in ("llama3-8b", "grok-1-314b", "moonshot-v1-16b-a3b",
                 "rwkv6-7b", "recurrentgemma-9b", "gemma-2b"):
        cfg = get(arch)
        # emulate the 16x16 divisibility question without 256 devices:
        # param_spec uses _div against the REAL mesh, so build specs with a
        # fake mesh object exposing shape 16/16
        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")
        abs_params = jax.eval_shape(
            lambda k: api.init_params(k, cfg), jax.random.PRNGKey(0))

        def check(path, leaf):
            spec = shard.param_spec(
                tuple(p for p in path), leaf.shape, cfg, FakeMesh())
            for dim, ax in zip(leaf.shape[len(leaf.shape) - len(spec):]
                               if len(spec) < len(leaf.shape) else leaf.shape,
                               spec):
                pass
            # re-walk: spec aligns right-to-left with shape when stacked
            offset = len(leaf.shape) - len(spec)
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= FakeMesh.shape[a]
                dim = leaf.shape[offset + i]
                assert dim % size == 0, \
                    f"{arch} {path}: dim {dim} not divisible by {size}"
            return leaf

        shard._tree_specs_with_path(abs_params, check)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    assert hints.constrain(x, "dp", "model") is x


def test_constrain_drops_indivisible_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with hints.use_mesh(mesh):
        x = jnp.ones((3, 5))
        y = hints.constrain(x, "data", "model")  # 3 % 1 == 0 -> kept
        assert y.shape == x.shape


def test_sharded_train_step_runs_on_cpu_mesh():
    cfg = reduced(get("llama3-8b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("t", 32, 2, "train")
    with hints.use_mesh(mesh):
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        shard.state_specs(jax.eval_shape(lambda: state), cfg, mesh)
        step = jax.jit(make_train_step(cfg, AdamWConfig()))
        batch = {k: jnp.asarray(v)
                 for k, v in api.make_batch(cfg, shape).items()}
        with mesh:
            state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))


# ------------------------------------------------------------- checkpoint
def test_checkpoint_save_restore_round_trip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
            "step": jnp.asarray(7)}
    cm.save(10, tree)
    step, back = cm.restore()
    assert step == 10
    np.testing.assert_array_equal(np.asarray(back["a"]["w"]),
                                  np.arange(6.0).reshape(2, 3))


def test_checkpoint_keep_n_rotation(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, {"x": jnp.ones((2,)) * s})
    assert cm.all_steps() == [3, 4]


def test_checkpoint_partial_write_not_visible(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(5, {"x": jnp.ones((4,))})
    # simulate a crashed writer: leftover tmp dir must not surface
    os.makedirs(os.path.join(str(tmp_path), ".tmp_crashed"), exist_ok=True)
    assert cm.all_steps() == [5]


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint under one sharding, restore under another (elastic)."""
    cm = CheckpointManager(str(tmp_path))
    x = jnp.arange(16.0).reshape(4, 4)
    cm.save(1, {"w": x})
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding
    sh = {"w": NamedSharding(mesh2, P("data", None))}
    _, tree = cm.restore(shardings=sh)
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(x))
    assert tree["w"].sharding == sh["w"]


def test_checkpoint_resume_training_continues(tmp_path):
    cfg = reduced(get("gemma-2b"))
    shape = ShapeConfig("t", 32, 2, "train")
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in api.make_batch(cfg, shape).items()}
    state, _ = step(state, batch)
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, state)
    _, state2 = cm.restore()
    s1, m1 = step(state, batch)
    s2, m2 = step(state2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


# ---------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_restart_safe():
    cfg = reduced(get("llama3-8b"))
    shape = ShapeConfig("t", 16, 4, "train")
    p1 = TokenPipeline(cfg, shape, seed=3)
    b5 = p1.batch_at(5)
    p2 = TokenPipeline(cfg, shape, seed=3)
    np.testing.assert_array_equal(b5["tokens"], p2.batch_at(5)["tokens"])
    # host sharding slices the batch
    ph = TokenPipeline(cfg, shape, PipelineConfig(host_count=2, host_index=1),
                       seed=3)
    np.testing.assert_array_equal(ph.batch_at(5)["tokens"],
                                  b5["tokens"][2:])


def test_pipeline_prefetch_delivers_in_order():
    cfg = reduced(get("gemma-2b"))
    shape = ShapeConfig("t", 16, 2, "train")
    p = TokenPipeline(cfg, shape, PipelineConfig(prefetch=2), seed=1)
    p.start()
    got = [p.get()["tokens"] for _ in range(3)]
    p.stop()
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g, p.batch_at(i)["tokens"])


# ------------------------------------------------------------ compression
def test_gradient_compression_error_feedback_converges():
    """int8+EF gradient compression must still train (loss decreases)."""
    cfg = reduced(get("gemma-2b"))
    shape = ShapeConfig("t", 32, 2, "train")
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=2e-3),
                                   compress_grads=True))
    state = init_train_state(jax.random.PRNGKey(0), cfg,
                             compress_grads=True)
    batch = {k: jnp.asarray(v) for k, v in api.make_batch(cfg, shape).items()}
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_compress_int8_bounded_error():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)))
    q, s = adamw.compress_int8(g)
    back = adamw.decompress_int8(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6
