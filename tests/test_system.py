"""End-to-end behaviour tests: training driver, serving driver, and the
framework -> simulator integration."""

import json
import os
import subprocess
import sys



def _run(mod, *args, timeout=400):
    # Pin JAX to the CPU backend explicitly: without JAX_PLATFORMS the
    # subprocess probes for accelerator plugins on CPU-only CI boxes, which
    # turns a ~7 s training run into a >400 s timeout.
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    return subprocess.run(
        [sys.executable, "-m", mod, *args], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=".")


def test_train_driver_end_to_end(tmp_path):
    r = _run("repro.launch.train", "--arch", "gemma-2b", "--steps", "20",
             "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
             "--ckpt-every", "10")
    assert r.returncode == 0, r.stdout + r.stderr
    last = json.loads(r.stdout.strip().splitlines()[-1])
    assert last["improved"] is True
    # checkpoints rotated and present
    import os
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))


def test_train_driver_resume(tmp_path):
    r1 = _run("repro.launch.train", "--arch", "gemma-2b", "--steps", "10",
              "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
              "--ckpt-every", "5")
    assert r1.returncode == 0, r1.stdout + r1.stderr
    # resume for a meaningful number of steps: the driver's exit code
    # asserts the loss improved, and a 3-4 step tail is noise-dominated
    r2 = _run("repro.launch.train", "--arch", "gemma-2b", "--steps", "24",
              "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
              "--resume")
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from step 10" in r2.stdout


def test_serve_driver_end_to_end():
    r = _run("repro.launch.serve", "--arch", "gemma-2b", "--batch", "2",
             "--prompt-len", "16", "--gen", "7")
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["decode_tok_per_s"] > 0
    assert len(out["sample_tokens"]) == 8


def test_framework_to_simulator_prediction():
    """A synthetic dry-run record flows through the prediction pipeline."""
    from repro.analysis.predict import predict_cell, simulate_cell_fine
    cell = {
        "arch": "llama3-8b", "shape": "train_4k", "status": "ok",
        "roofline": {"compute_s": 1.3, "memory_s": 2.0,
                     "collective_s": 0.5},
        "collectives": {"all-gather": 2e10, "all-reduce": 3e10,
                        "reduce-scatter": 0.0, "all-to-all": 0.0,
                        "collective-permute": 0.0,
                        "total_wire_bytes": 5e10,
                        "op_counts": {"all-gather": 10, "all-reduce": 5}},
    }
    pred = predict_cell(cell)
    assert pred["step_no_overlap_s"] >= pred["step_full_overlap_s"]
    assert pred["step_full_overlap_s"] >= 2.0  # at least the compute bound
    fine = simulate_cell_fine(cell, ranks=4, layers=2)
    assert fine["sim_time_per_layer_us"] > 0
    assert fine["events"] > 0
