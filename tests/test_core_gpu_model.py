"""Unit tests for the fine-grained GPU execution model (paper §4.1, §4.4)."""

import pytest

from repro.core import (BarrierOp, Cluster, Kernel, LoadOp, MemcpyOp, MemRef,
                        NocConfig, NopOp, ReduceOp, SemaphoreAcquireOp,
                        SemaphoreReleaseOp, Space, StoreOp, Workgroup)
from repro.core.operations import OpContext
from repro.core.instructions import IKind


def hbm(gpu, addr):
    return MemRef(gpu, Space.HBM, addr)


def sem(gpu, sid):
    return MemRef(gpu, Space.SEM, sid)


def run_kernel(cluster, kernel, until=1e9):
    done = {}
    kernel.on_done = lambda k, t: done.setdefault("t", t)
    cluster.dispatch(kernel)
    cluster.run(until)
    assert "t" in done, "kernel did not complete"
    return done["t"]


def test_loadop_expansion_stripes_lines_over_wavefronts():
    ctx = OpContext(cache_line=128)
    op = LoadOp(hbm(0, 0), 128 * 10)
    ins0 = list(op.instructions(0, 4, ctx))
    ins1 = list(op.instructions(1, 4, ctx))
    ins3 = list(op.instructions(3, 4, ctx))
    assert len(ins0) == 3 and len(ins1) == 3 and len(ins3) == 2
    assert ins0[0].mem.addr == 0 and ins0[1].mem.addr == 128 * 4
    assert ins1[0].mem.addr == 128
    assert all(i.kind == IKind.LOAD for i in ins0)


def test_memcpy_unroll_groups_loads_before_fence():
    ctx = OpContext(cache_line=128, unroll=4)
    op = MemcpyOp(hbm(0, 0), hbm(0, 1 << 20), 128 * 8)
    ins = list(op.instructions(0, 1, ctx))
    kinds = [i.kind for i in ins]
    assert kinds == [IKind.LOAD] * 4 + [IKind.WAITCNT] + [IKind.STORE] * 4 + \
                    [IKind.LOAD] * 4 + [IKind.WAITCNT] + [IKind.STORE] * 4


def test_single_gpu_local_memcpy_completes():
    c = Cluster(1)
    k = Kernel([Workgroup([MemcpyOp(hbm(0, 0), hbm(0, 1 << 20), 4096)],
                          num_wavefronts=4)], gpu=0, name="memcpy")
    t = run_kernel(c, k)
    assert t > 0
    assert c.request_count == 2 * (4096 // 128)  # 32 loads + 32 stores


def test_remote_store_crosses_fabric():
    c = Cluster(2)
    k = Kernel([Workgroup([StoreOp(hbm(1, 0), 1024)], num_wavefronts=2)],
               gpu=0, name="remote_store")
    t_remote = run_kernel(c, k)
    c2 = Cluster(2)
    k2 = Kernel([Workgroup([StoreOp(hbm(0, 0), 1024)], num_wavefronts=2)],
                gpu=0, name="local_store")
    t_local = run_kernel(c2, k2)
    assert t_remote > t_local + 1000  # pays >= one 1 us scale-up traversal


def test_semaphore_orders_producer_consumer():
    """Consumer's acquire must wait for producer's release."""
    c = Cluster(2)
    times = {}

    # producer on GPU0: big local copy, then signal GPU1's semaphore 7
    prod = Kernel([Workgroup([
        MemcpyOp(hbm(0, 0), hbm(0, 1 << 20), 64 * 128),
        SemaphoreReleaseOp(sem(1, 7)),
    ], num_wavefronts=2)], gpu=0, name="producer")
    # consumer on GPU1: wait on local semaphore 7, then small load
    cons = Kernel([Workgroup([
        SemaphoreAcquireOp(sem(1, 7)),
        LoadOp(hbm(1, 0), 128),
    ], num_wavefronts=2)], gpu=1, name="consumer")

    prod.on_done = lambda k, t: times.setdefault("prod", t)
    cons.on_done = lambda k, t: times.setdefault("cons", t)
    c.dispatch(prod)
    c.dispatch(cons)
    c.run(1e9)
    assert "prod" in times and "cons" in times
    assert times["cons"] > times["prod"] - 2000  # consumer gated on producer


def test_nop_syncs_wavefronts_within_workgroup():
    c = Cluster(1)
    k = Kernel([Workgroup([
        LoadOp(hbm(0, 0), 128 * 16),
        NopOp(),
        StoreOp(hbm(0, 1 << 20), 128 * 16),
    ], num_wavefronts=4)], gpu=0)
    t = run_kernel(c, k)
    assert t > 0


def test_barrier_syncs_workgroups_within_kernel():
    c = Cluster(1)
    wgs = [Workgroup([LoadOp(hbm(0, i * 4096), 128 * (4 + 4 * i)),
                      BarrierOp(),
                      StoreOp(hbm(0, 1 << 20), 128)], num_wavefronts=2)
           for i in range(4)]
    t = run_kernel(c, Kernel(wgs, gpu=0))
    assert t > 0


def test_barrier_with_undispatched_workgroups_raises():
    noc = NocConfig(mesh_x=1, mesh_y=1, cus_per_router=1)  # 1 CU
    c = Cluster(1, noc=noc)
    wgs = [Workgroup([BarrierOp()], num_wavefronts=1) for _ in range(2)]
    c.dispatch(Kernel(wgs, gpu=0))
    with pytest.raises(RuntimeError, match="cooperative"):
        c.run(1e9)


def test_more_workgroups_than_cus_serializes():
    noc_small = NocConfig(mesh_x=1, mesh_y=1, cus_per_router=2)
    ops = lambda: [MemcpyOp(hbm(0, 0), hbm(0, 1 << 20), 128 * 64)]
    c1 = Cluster(1, noc=noc_small)
    t1 = run_kernel(c1, Kernel([Workgroup(ops(), 2) for _ in range(8)], gpu=0))
    noc_big = NocConfig(mesh_x=4, mesh_y=2, cus_per_router=1)
    c2 = Cluster(1, noc=noc_big)
    t2 = run_kernel(c2, Kernel([Workgroup(ops(), 2) for _ in range(8)], gpu=0))
    assert t1 > t2  # contention for 2 CUs vs 8 CUs


def test_reduce_occupies_cu():
    c = Cluster(1)
    k1 = Kernel([Workgroup([ReduceOp(cycles=10_000)], 1)], gpu=0)
    t1 = run_kernel(c, k1)
    c2 = Cluster(1)
    k2 = Kernel([Workgroup([ReduceOp(cycles=100_000)], 1)], gpu=0)
    t2 = run_kernel(c2, k2)
    assert t2 - t1 == pytest.approx(90_000, rel=0.01)  # 1 ns/cycle


def test_halving_doubling_fine_tier_multi_workgroup_no_deadlock():
    """Seed-bug regression (ISSUE 2): fine-tier halving-doubling all-reduce
    with nworkgroups >= 2 deadlocked on small NoCs — a wavefront whose op
    cursor advanced onto a barrier right as an instruction stream ran dry
    never registered its barrier arrival.  It must now complete, at every
    fabric mode, and agree with the coarse tier's semantics.
    """
    from repro.core import collectives as C
    from repro.core.system import (simulate_collective,
                                   simulate_collective_coarse)
    fine_times = {}
    for mode in ("classic", "exact", "coalesce"):
        noc = NocConfig(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
                        io_ports=4, fabric_mode=mode)
        c = Cluster(4, noc=noc)
        r = simulate_collective(C.halving_doubling_all_reduce(4, 4096, 2),
                                cluster=c, until_ns=1e9)
        fine_times[mode] = r.time_ns
        assert c.fabric.order_violations == 0
        assert len(r.per_rank_done_ns) == 4
    # fast paths bit-exact; classic within tie-resolution noise
    assert fine_times["exact"] == fine_times["coalesce"]
    assert fine_times["classic"] == pytest.approx(fine_times["exact"],
                                                  rel=1e-4)
    # parity with the coarse tier: same program completes there too, and
    # the fine tier (which pays control-path latency) is the slower one
    rc = simulate_collective_coarse(C.halving_doubling_all_reduce(4, 4096, 2))
    assert rc.time_ns > 0
    assert fine_times["exact"] > rc.time_ns
    # the data semantics are validated by the functional executor
    from repro.core.verify import check_program
    check_program(C.halving_doubling_all_reduce(4, 4096, 2), seed=7)


def test_deterministic_replay():
    def once():
        c = Cluster(2)
        wgs = [Workgroup([MemcpyOp(hbm(0, i * 8192), hbm(1, i * 8192), 2048),
                          SemaphoreReleaseOp(sem(1, i))], 2)
               for i in range(4)]
        return run_kernel(c, Kernel(wgs, gpu=0))
    assert once() == once()
