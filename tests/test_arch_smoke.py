"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates a REDUCED config of the same
family and runs: one forward/train step (shapes + finite loss), one
prefill + decode step, and — for autoregressive-consistency — checks that
prefill-then-decode matches a longer forward's last-token logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get, reduced, registry
from repro.models import api
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step

ARCHS = sorted(registry().keys())
SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def _smoke_batch(cfg):
    return {k: jnp.asarray(v)
            for k, v in api.make_batch(cfg, SMOKE_SHAPE, seed=1).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_is_finite(arch):
    cfg = reduced(get(arch))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    state, metrics = step(state, batch)
    loss0 = float(metrics["loss"])
    assert np.isfinite(loss0), f"{arch}: non-finite loss"
    # loss should be near ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab) < loss0 < 3.0 * np.log(cfg.vocab)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_training_reduces_loss(arch):
    cfg = reduced(get(arch))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=2e-3)))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1, f"{arch}: no learning {losses}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode_shapes(arch):
    cfg = reduced(get(arch))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    cache, logits = api.prefill(params, cfg, batch)
    assert logits.shape[0] == SMOKE_SHAPE.global_batch
    assert logits.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    # one decode step
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    pos = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        # decode needs a max-length cache: re-run prefill into a padded one
        cache2 = _padded_cache(cfg, params, batch)
        logits2, cache3 = api.decode_step(params, cfg, tok, pos, cache2)
    else:
        logits2, cache3 = api.decode_step(params, cfg, tok, pos, cache)
    assert logits2.shape == (SMOKE_SHAPE.global_batch, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all()


def _padded_cache(cfg, params, batch, max_len=64):
    """Prefill then copy the collected KV into a max_len cache."""
    cache, _ = api.prefill(params, cfg, batch)
    if cfg.family == "encdec":
        full = api.init_cache(cfg, batch["tokens"].shape[0], max_len)
        S = cache["k"].shape[2]
        for key in ("k", "v"):
            full[key] = full[key].at[:, :, :S].set(cache[key])
        full["mk"], full["mv"] = cache["mk"], cache["mv"]
        return full
    full = api.init_cache(cfg, batch["tokens"].shape[0], max_len)
    S = cache["k"].shape[2]
    return {"k": full["k"].at[:, :, :S].set(cache["k"]),
            "v": full["v"].at[:, :, :S].set(cache["v"])}


@pytest.mark.parametrize("arch", ["llama3-8b", "rwkv6-7b",
                                  "recurrentgemma-9b", "gemma-2b"])
def test_decode_consistency_with_forward(arch):
    """prefill(t[:n]) + decode(t[n]) logits == forward(t[:n+1]) last logits."""
    cfg = reduced(get(arch))
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(7)
    n = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, n + 1)),
                       dtype=jnp.int32)
    # reference: full forward on n+1 tokens
    batch_full = {"tokens": toks}
    cache_full, logits_full = api.prefill(params, cfg, batch_full)
    # prefill n, decode token n
    batch_n = {"tokens": toks[:, :n]}
    if cfg.family in ("dense", "moe"):
        cache = _padded_cache(cfg, params, batch_n, max_len=n + 8)
    else:
        cache, _ = api.prefill(params, cfg, batch_n)
    logits_dec, _ = api.decode_step(params, cfg, toks[:, n:n + 1],
                                    jnp.asarray(n, jnp.int32), cache)
    got = np.asarray(logits_dec[:, 0], dtype=np.float32)
    want = np.asarray(logits_full[:, -1], dtype=np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_param_counts_match_scale():
    """Full configs should land near their nominal parameter counts."""
    expect = {"llama3-8b": (7e9, 9.5e9),
              "phi3-medium-14b": (12e9, 16e9),
              "starcoder2-7b": (6e9, 9e9),
              "gemma-2b": (2e9, 3.3e9),
              "grok-1-314b": (2.7e11, 3.4e11),
              # the brief's 48L x 64e x 1408 config computes to ~28B total
              # (nominal "16B" assumes fewer MoE layers); brief config wins
              "moonshot-v1-16b-a3b": (2.4e10, 3.1e10),
              "rwkv6-7b": (6e9, 9e9),
              "recurrentgemma-9b": (7.5e9, 11e9)}
    for arch, (lo, hi) in expect.items():
        n = get(arch).param_count()
        assert lo < n < hi, f"{arch}: {n:.3g} params outside [{lo}, {hi}]"
