"""Lazy per-pair route registration with census epochs (ISSUE 9 tentpole).

The contract: ``NocConfig(route_policy="lazy")`` registers a (src, dst)
GPU pair's routes only when a kernel first references the pair, yet the
simulated schedule is *bit-exact* with the eager product loop — route
keys are positional (derived from the pair and line residue, not from
registration order), so the heap tie-break order is identical, and every
registration commits a census epoch that re-arms the affected links'
probe policy and refreshes their static transit floors.  The per-link
FIFO monitor certifies every run (``order_violations == 0``).
"""

import pytest

from repro.core import collectives as C
from repro.core.backends import FineConfig, simulate
from repro.core.cluster import Cluster, NocConfig
from repro.core.infragraph.blueprints import (clos_fat_tree_fabric,
                                              hierarchical_fabric,
                                              torus2d_fabric)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SMALL = dict(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
             io_ports=4)
TINY = dict(mesh_x=2, mesh_y=1, cus_per_router=1, mem_channels=2,
            io_ports=2)

KiB = 1 << 10


def _run(policy, prog_fn, nranks, topology="switch", ledger="on"):
    cluster = Cluster(nranks, noc=NocConfig(route_policy=policy,
                                            fabric_ledger=ledger, **SMALL),
                      topology=topology)
    r = simulate(prog_fn(), fidelity="fine", cluster=cluster, check="off")
    return r, cluster


def assert_parity(prog_fn, nranks, topology="switch", ledger="on"):
    r_eager, c_eager = _run("eager", prog_fn, nranks, topology, ledger)
    r_lazy, c_lazy = _run("lazy", prog_fn, nranks, topology, ledger)
    assert r_lazy.time_ns == r_eager.time_ns, \
        f"lazy registration changed the schedule ({topology}/{ledger})"
    assert r_lazy.per_rank_done_ns == r_eager.per_rank_done_ns
    assert c_eager.fabric.order_violations == 0
    assert c_lazy.fabric.order_violations == 0
    assert c_lazy.pairs_registered <= c_eager.pairs_registered
    return c_eager, c_lazy


# ---------------------------------------------------------------------------
# parity: lazy == eager, built-in topologies x collectives x ledger modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology,prog_fn,ledger", [
    ("switch", lambda: C.ring_all_reduce(4, 4 * KiB, 1, "put"), "on"),
    ("switch", lambda: C.direct_all_gather(4, 4 * KiB, 2, "put"), "auto"),
    ("ring", lambda: C.ring_all_gather(4, 4 * KiB, 1, "get"), "on"),
    ("ring", lambda: C.direct_reduce_scatter(4, 4 * KiB, 1, "get"), "off"),
])
def test_lazy_parity_fast(topology, prog_fn, ledger):
    assert_parity(prog_fn, 4, topology, ledger)


@pytest.mark.slow
@pytest.mark.parametrize("topology", ["switch", "ring"])
@pytest.mark.parametrize("ledger", ["on", "off", "auto"])
@pytest.mark.parametrize("prog_fn", [
    lambda: C.ring_all_reduce(4, 8 * KiB, 2, "put"),
    lambda: C.direct_all_gather(4, 8 * KiB, 2, "put"),
    lambda: C.direct_reduce_scatter(4, 8 * KiB, 1, "get"),
    lambda: C.direct_all_to_all(4, 8 * KiB, 1, "put"),
])
def test_lazy_parity_full_matrix(topology, ledger, prog_fn):
    assert_parity(prog_fn, 4, topology, ledger)


# ---------------------------------------------------------------------------
# parity on InfraGraph-built wirings (leaf-spine, torus, hierarchical)
# ---------------------------------------------------------------------------

def _infra_parity(infra_fn, prog_fn):
    out = {}
    for pol in ("eager", "lazy"):
        r = simulate(prog_fn(), infra_fn(), fidelity="fine",
                     config=FineConfig(noc=NocConfig(route_policy=pol,
                                                     **TINY)), check="off")
        out[pol] = (r.time_ns, tuple(r.per_rank_done_ns))
    assert out["eager"] == out["lazy"]


def test_lazy_parity_leaf_spine():
    _infra_parity(lambda: clos_fat_tree_fabric(num_hosts=4, switch_ports=4),
                  lambda: C.ring_all_gather(4, 4 * KiB, 1, "put"))


def test_lazy_parity_torus():
    _infra_parity(lambda: torus2d_fabric(2, 2),
                  lambda: C.ring_all_reduce(4, 4 * KiB, 1, "put"))


def test_lazy_parity_hierarchical():
    _infra_parity(lambda: hierarchical_fabric(hosts=2, gpus_per_host=2),
                  lambda: C.direct_all_gather(4, 4 * KiB, 1, "put"))


# ---------------------------------------------------------------------------
# lazy-registration regressions
# ---------------------------------------------------------------------------

def test_lazy_defers_registration_until_dispatch():
    cluster = Cluster(4, noc=NocConfig(route_policy="lazy", **SMALL))
    for g in cluster.gpus:
        for cu in g.cus:
            assert all(t is None for t in cu.reqtab), \
                "lazy cluster must not pre-register any pair"
    assert cluster.pairs_registered == 0
    simulate(C.ring_all_gather(4, 4 * KiB, 1, "put"), fidelity="fine",
             cluster=cluster, check="off")
    assert cluster.pairs_registered > 0


def test_lazy_registration_is_sparse_for_ring_workload():
    """A ring program touches O(n) pairs (self + next); the lazy policy
    must never fall back to the n^2 product."""
    n = 8
    cluster = Cluster(n, noc=NocConfig(route_policy="lazy", **SMALL))
    simulate(C.ring_all_gather(n, 4 * KiB, 1, "put"), fidelity="fine",
             cluster=cluster, check="off")
    assert cluster.pairs_registered <= 4 * n
    assert cluster.pairs_registered < n * n


def test_eager_registers_full_product():
    n = 4
    cluster = Cluster(n, noc=NocConfig(route_policy="eager", **SMALL))
    assert cluster.pairs_registered == n * n


def test_census_epochs_never_retroactive_for_program_runs():
    """Kernel-driven registration commits census epochs strictly before
    the new pair's first flight — the retroactive-commit counter must
    stay zero (a nonzero value means a census changed a link that already
    carried traffic, the unsafe case the FIFO monitor guards)."""
    cluster = Cluster(4, noc=NocConfig(route_policy="lazy", **SMALL))
    simulate(C.direct_all_to_all(4, 4 * KiB, 1, "put"), fidelity="fine",
             cluster=cluster, check="off")
    assert cluster.fabric.ledger_counters()["census_retro"] == 0
    assert cluster.fabric.order_violations == 0


def test_route_policy_validated():
    with pytest.raises(ValueError):
        Cluster(2, noc=NocConfig(route_policy="bogus", **SMALL))


def test_multipath_period_cap_raises():
    """Pathological io/hbm port mixes can blow the lcm multipath period;
    the cap must fail fast and name the config knob."""
    noc = NocConfig(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=3,
                    io_ports=4, max_multipath_period=4)
    with pytest.raises(ValueError, match="max_multipath_period"):
        Cluster(4, noc=noc)


def test_multipath_period_cap_allows_defaults():
    cluster = Cluster(4, noc=NocConfig(**SMALL))
    assert cluster._maxp <= NocConfig().max_multipath_period


# ---------------------------------------------------------------------------
# property: registration order can never change the schedule
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _REF_CACHE = {}

    def _reference():
        if "r" not in _REF_CACHE:
            r, c = _run("eager", lambda: C.ring_all_reduce(4, 4 * KiB, 1,
                                                           "put"), 4)
            _REF_CACHE["r"] = (r.time_ns, tuple(r.per_rank_done_ns))
        return _REF_CACHE["r"]

    @settings(max_examples=12, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3)),
                    max_size=12))
    def test_interleaved_registration_is_timing_neutral(pairs):
        """Pre-registering any subset of pairs in any order before the
        program runs (the rest arrive lazily mid-run) must leave
        ``time_ns`` bit-identical to the eager reference and keep the
        FIFO monitor clean — route keys are positional, and census
        epochs re-arm probe state on every commit."""
        cluster = Cluster(4, noc=NocConfig(route_policy="lazy", **SMALL))
        for s, d in pairs:
            cluster._ensure_pair(s, d)
        r = simulate(C.ring_all_reduce(4, 4 * KiB, 1, "put"),
                     fidelity="fine", cluster=cluster, check="off")
        assert (r.time_ns, tuple(r.per_rank_done_ns)) == _reference()
        assert cluster.fabric.order_violations == 0
