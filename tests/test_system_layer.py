"""End-to-end timing simulation: collectives + traces on the detailed model."""

import pytest

from repro.core import Cluster, NocConfig
from repro.core import collectives as C
from repro.core.chakra import ExecutionTrace, TraceExecutor
from repro.core.protocols import ProtocolModel
from repro.core.system import (simulate_collective, simulate_collective_coarse)

SMALL_NOC = NocConfig(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
                      io_ports=4)


def test_fine_grained_all_gather_runs_and_scales_with_size():
    r1 = simulate_collective(C.direct_all_gather(4, 4096, 2, "put"),
                             noc=SMALL_NOC)
    r2 = simulate_collective(C.direct_all_gather(4, 16384, 2, "put"),
                             noc=SMALL_NOC)
    assert r1.time_ns > 0 and r2.time_ns > r1.time_ns
    assert r1.nranks == 4
    # 4x payload should take roughly 2-6x longer (latency amortizes)
    assert 1.5 < r2.time_ns / r1.time_ns < 8


def test_ring_all_reduce_fine_vs_coarse_agree_roughly():
    prog = C.ring_all_reduce(4, 8192, 2, "put")
    fine = simulate_collective(prog, noc=SMALL_NOC)
    coarse = simulate_collective_coarse(prog)
    # same algorithm; coarse misses control-path latency so it's faster,
    # but both should be the same order of magnitude
    assert coarse.time_ns < fine.time_ns
    assert fine.time_ns / coarse.time_ns < 50


def test_collective_correct_even_with_straggler_injection():
    prog = C.ring_all_gather(4, 2048, 1, "put")
    base = simulate_collective(prog, noc=SMALL_NOC)
    prog2 = C.ring_all_gather(4, 2048, 1, "put")
    lag = simulate_collective(prog2, noc=SMALL_NOC,
                              rank_delay_ns=[0, 0, 50_000, 0])
    assert lag.time_ns >= base.time_ns + 40_000  # straggler propagates


def test_unroll_improves_all_to_all_bandwidth():
    """Paper Fig. 12: higher intra-wavefront ILP helps bandwidth-bound
    collectives."""
    times = {}
    for unroll in (1, 8):
        prog = C.direct_all_to_all(4, 8192, 2, "put")
        r = simulate_collective(prog, noc=SMALL_NOC, unroll=unroll)
        times[unroll] = r.time_ns
    assert times[8] < times[1]


def test_trace_executor_comp_then_collective():
    et = ExecutionTrace(num_ranks=2)
    comp = {r: et.comp(r, f"gemm.r{r}", flops=1e7) for r in range(2)}
    et.coll(0, "all_reduce", 4096, "ring",
            deps_by_rank={r: [comp[r]] for r in range(2)})
    cl = Cluster(2, noc=SMALL_NOC)
    res = TraceExecutor(et, cl, comp_workgroups=4, coll_workgroups=2).run()
    assert res.time_ns > 0
    # collective must start after its rank's compute
    coll_nodes = [n for n in et.nodes if n.kind == "coll"]
    assert all(res.node_times[n.nid][0] >= min(
        res.node_times[c.nid][1] for c in comp.values()) - 1
        for n in coll_nodes)


def test_two_collectives_on_one_cluster_do_not_collide():
    """Semaphore namespacing: back-to-back collectives must both finish."""
    et = ExecutionTrace(num_ranks=2)
    first = et.coll(0, "all_gather", 2048, "ring")
    et.coll(1, "all_gather", 2048, "ring",
            deps_by_rank={r: [first[r]] for r in range(2)})
    cl = Cluster(2, noc=SMALL_NOC)
    res = TraceExecutor(et, cl).run()
    assert res.time_ns > 0


def test_protocol_crossover_scales_with_latency():
    """Fig. 4: overestimating latency pushes the LL->Simple crossover out."""
    m_fast = ProtocolModel(alpha_ns=500, beta_GBps=256 * 1.0737)
    m_slow = ProtocolModel(alpha_ns=5000, beta_GBps=256 * 1.0737)
    assert m_slow.crossover_bytes() == pytest.approx(
        10 * m_fast.crossover_bytes())
    m_wide = ProtocolModel(alpha_ns=500, beta_GBps=1024 * 1.0737)
    assert m_wide.crossover_bytes() > m_fast.crossover_bytes()
    # bandwidth asymptotes: LL -> beta/2, Simple -> beta
    big = 1 << 30
    assert m_fast.bw_ll_GBps(big) == pytest.approx(m_fast.beta_GBps / 2,
                                                   rel=0.01)
    assert m_fast.bw_simple_GBps(big) == pytest.approx(m_fast.beta_GBps,
                                                       rel=0.01)
