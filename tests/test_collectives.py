"""Functional correctness of every collective generator, under randomized
interleavings (paper §4.2: custom collectives must be *correct* programs)."""

import pytest

from repro.core import collectives as C
from repro.core.mscclpp import Program
from repro.core.verify import check_program

NR = [2, 3, 4, 5, 8]
NR_POW2 = [2, 4, 8]


@pytest.mark.parametrize("n", NR)
@pytest.mark.parametrize("proto", ["put", "get"])
@pytest.mark.parametrize("nwg", [1, 3])
def test_ring_all_gather(n, proto, nwg):
    check_program(C.ring_all_gather(n, 64, nwg, proto), seed=n)


@pytest.mark.parametrize("n", NR)
@pytest.mark.parametrize("proto", ["put", "get"])
def test_direct_all_gather(n, proto):
    check_program(C.direct_all_gather(n, 64, 2, proto), seed=n)


@pytest.mark.parametrize("n", NR)
@pytest.mark.parametrize("proto", ["put", "get"])
@pytest.mark.parametrize("nwg", [1, 2])
def test_ring_reduce_scatter(n, proto, nwg):
    check_program(C.ring_reduce_scatter(n, 48, nwg, proto), seed=n)


@pytest.mark.parametrize("n", NR)
@pytest.mark.parametrize("proto", ["put", "get"])
def test_direct_reduce_scatter(n, proto):
    check_program(C.direct_reduce_scatter(n, 48, 2, proto), seed=n)


@pytest.mark.parametrize("n", NR)
@pytest.mark.parametrize("proto", ["put"])
@pytest.mark.parametrize("nwg", [1, 2])
def test_ring_all_reduce(n, proto, nwg):
    check_program(C.ring_all_reduce(n, 96, nwg, proto), seed=n)


@pytest.mark.parametrize("n", NR)
def test_double_binary_tree_all_reduce(n):
    check_program(C.double_binary_tree_all_reduce(n, 96, 2), seed=n)


@pytest.mark.parametrize("n", NR_POW2)
def test_halving_doubling_all_reduce(n):
    check_program(C.halving_doubling_all_reduce(n, 64, 2), seed=n)


@pytest.mark.parametrize("n", NR)
@pytest.mark.parametrize("proto", ["put", "get"])
def test_direct_all_to_all(n, proto):
    check_program(C.direct_all_to_all(n, 32, 2, proto), seed=n)


@pytest.mark.parametrize("seed", range(8))
def test_schedule_independence(seed):
    """The same program must be correct under many interleavings."""
    prog = C.ring_all_reduce(4, 64, 2, "put")
    check_program(prog, seed=seed)


def test_json_round_trip():
    prog = C.ring_reduce_scatter(4, 64, 2, "get")
    prog2 = Program.from_json(prog.to_json())
    assert prog2.num_ranks == prog.num_ranks
    assert prog2.op_count() == prog.op_count()
    check_program(prog2, seed=3)


def test_unbalanced_sizes():
    """Sizes not divisible by nranks/nworkgroups still correct."""
    check_program(C.ring_all_reduce(3, 101, 2, "put"), seed=1)
    check_program(C.ring_all_gather(5, 33, 3, "put"), seed=1)
    check_program(C.double_binary_tree_all_reduce(6, 77, 3), seed=1)
