"""Ledger state isolation and configuration parity (ISSUE 6).

The channel-clock kernel keeps all of its mutable state — batch flags,
cache generation, depth budget, observability counters — on the
:class:`~repro.core.engine.Engine` instance (plus per-link slots), never
in module globals.  These tests pin that contract:

* two simulations interleaved event-by-event in one process produce
  bit-identical results to the same simulations run solo;
* the clock recursion budget (``NocConfig.ledger_depth``) changes only
  wall-time/event trade-offs, never ``time_ns``;
* the adaptive per-link probe policy (``fabric_ledger="auto"``) is
  timing-neutral against always-on proving.
"""

import pytest

from repro.core import collectives as C
from repro.core.cluster import Cluster, NocConfig
from repro.core.mscclpp import lower_program
from repro.core.system import simulate_collective

NRANKS = 4
SIZE = 1 << 14


def _prepare(noc=None):
    """Build a cluster with the reference collective dispatched and sealed,
    ready to be driven manually through its engine."""
    program = C.ring_all_reduce(NRANKS, SIZE, 1, "put")
    cluster = Cluster(NRANKS, noc=noc or NocConfig())
    done_at = {}

    def on_done(kernel, t, rank=None):
        done_at[kernel.gpu] = t

    for k in lower_program(program):
        k.on_done = on_done
        cluster.dispatch(k)
    cluster.seal()
    return cluster, done_at


def _drain(cluster):
    cluster.run(5e10)
    return cluster


def _result(cluster, done_at):
    assert len(done_at) == NRANKS, "collective did not complete"
    return (max(done_at.values()),
            tuple(done_at[r] for r in range(NRANKS)),
            cluster.engine.events_processed,
            cluster.fabric.order_violations)


def test_interleaved_simulations_match_solo_runs():
    """Two clusters alternating through ``Engine.run(max_events=...)`` in
    one process must each reproduce their solo run bit-exactly: nothing in
    the clock kernel (generation counters, memo epochs, batch flags,
    backoff state) may leak across engine instances."""
    ca, da = _prepare()
    _drain(ca)
    solo_a = _result(ca, da)
    cb, db = _prepare(NocConfig(fabric_mode="exact"))
    _drain(cb)
    solo_b = _result(cb, db)

    ia, ida = _prepare()
    ib, idb = _prepare(NocConfig(fabric_mode="exact"))
    # alternate in uneven slices so the interleave points differ from any
    # natural phase boundary of either simulation
    step = 257
    while ia.engine.pending or ib.engine.pending:
        if ia.engine.pending:
            ia.engine.run(max_events=step)
        if ib.engine.pending:
            ib.engine.run(max_events=step + 91)
    assert _result(ia, ida) == solo_a
    assert _result(ib, idb) == solo_b


def test_back_to_back_simulations_match_solo_runs():
    """Sequential reuse in one process: a second simulation after a first
    has fully drained must be unaffected by it."""
    ca, da = _prepare()
    _drain(ca)
    ref = _result(ca, da)
    cb, db = _prepare()
    _drain(cb)
    assert _result(cb, db) == ref


@pytest.mark.parametrize("depth", [0, 2, 4])
def test_ledger_depth_is_timing_neutral(depth):
    """The recursion budget bounds how hard the prover tries, never what
    the simulated hardware does: ``time_ns`` must be bit-identical at any
    depth (depth 0 degenerates to horizon-only proofs)."""
    ref = simulate_collective(C.ring_all_reduce(NRANKS, SIZE, 1, "put"),
                              noc=NocConfig())
    cluster = Cluster(NRANKS, noc=NocConfig(ledger_depth=depth))
    r = simulate_collective(C.ring_all_reduce(NRANKS, SIZE, 1, "put"),
                            cluster=cluster)
    assert r.time_ns == ref.time_ns
    assert r.per_rank_done_ns == ref.per_rank_done_ns
    assert cluster.fabric.order_violations == 0


@pytest.mark.parametrize("ledger", ["off", "auto"])
def test_ledger_policy_is_timing_neutral(ledger):
    """Disabling proving entirely, or letting the adaptive policy disable
    it per link, only changes event counts — never the schedule."""
    ref = simulate_collective(C.ring_all_reduce(NRANKS, SIZE, 1, "put"),
                              noc=NocConfig())
    cluster = Cluster(NRANKS, noc=NocConfig(fabric_ledger=ledger))
    r = simulate_collective(C.ring_all_reduce(NRANKS, SIZE, 1, "put"),
                            cluster=cluster)
    assert r.time_ns == ref.time_ns
    assert r.per_rank_done_ns == ref.per_rank_done_ns
    assert cluster.fabric.order_violations == 0
