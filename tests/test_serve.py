"""Serving layer tests (ISSUE 8): rank-completion bugfix regressions,
p2p transfer correctness, arrival-release semantics, TR-DUP-COLL, and the
cross-tier serving parity suite (monotone fidelity, bit-identical seeded
replay, check_workload-clean generated scenarios).

The three bugfix regression tests are written to FAIL on the pre-PR code:

* ``test_bystander_rank_completes_*`` — ``ProgramInterpreter.__init__``
  never completed a rank with zero workgroups, so coarse/analytic runs of
  a p2p program raised "sim incomplete".
* ``test_analytic_closed_form_*`` — the closed form returned
  ``per_rank_done_ns=[t]*n`` for every run; uniform delays now shift the
  closed form (still zero events) and non-uniform skew routes through the
  interpreter so tails stay honest.
* ``test_coll_start_stamped_at_release*`` — ``_TierTraceExecutor`` stamped
  ``node.start_ns`` when the node was handed to ``_launch``, not when the
  rank's half was actually released into the interpreter.
"""

import numpy as np
import pytest

from repro.core import collectives as C
from repro.core import verify
from repro.core.backends import simulate
from repro.core.backends.workload import _TierTraceExecutor
from repro.core.chakra import ExecutionTrace
from repro.core.check import check_trace, check_workload
from repro.serve import (DiurnalArrivals, MMPPArrivals, PoissonArrivals,
                         Request, ServingModel, continuous_batching,
                         disaggregated, generate_requests, latency_stats,
                         percentile, request_latencies)

TOY = ServingModel("toy", flops_per_token=2e6, weight_bytes=1e6,
                   coll_bytes_per_token=4096, kv_bytes_per_token=2048)


def toy_requests(n=12, seed=3, rate=2000.0):
    return generate_requests(PoissonArrivals(rate), n=n, seed=seed,
                             prompt_tokens=(8, 32), decode_tokens=(2, 12))


# ---------------------------------------------------------------------------
# bugfix 1: empty-workgroup ranks must complete (non-deferred interpreter)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fidelity", ["coarse", "analytic"])
def test_bystander_rank_completes_at_cheap_tiers(fidelity):
    """A p2p program leaves every non-endpoint rank with zero workgroups;
    pre-PR the non-deferred interpreter never completed them and the
    backend raised 'sim incomplete: ranks [...]'."""
    prog = C.p2p_transfer(4, 4096, 2, src=0, dst=2)
    assert prog.gpus[1] == [] and prog.gpus[3] == []
    r = simulate(prog, fidelity=fidelity, check="off")
    assert len(r.per_rank_done_ns) == 4
    # bystanders finish no later than the endpoints
    assert r.per_rank_done_ns[1] <= r.time_ns
    assert r.per_rank_done_ns[3] <= r.time_ns
    assert r.time_ns > 0


def test_bystander_rank_honors_rank_delay():
    prog = C.p2p_transfer(3, 1024, 1, src=0, dst=1)
    r = simulate(prog, fidelity="coarse", check="off",
                 rank_delay_ns=[0.0, 0.0, 777.0])
    assert r.per_rank_done_ns[2] == pytest.approx(777.0)


# ---------------------------------------------------------------------------
# bugfix 2: analytic closed form must stay honest under launch skew
# ---------------------------------------------------------------------------

def test_analytic_closed_form_uniform_delay_shifts_without_events():
    """A uniform delay d only shifts the collective: the closed form must
    still answer (zero events) with every percentile moved by d.  Pre-PR,
    any nonzero delay fell through to the interpreter (events > 0)."""
    prog = C.ring_all_reduce(4, 1 << 16, 2)
    base = simulate(prog, fidelity="analytic", check="off")
    shifted = simulate(prog, fidelity="analytic", check="off",
                       rank_delay_ns=[500.0] * 4)
    assert base.events == 0 and shifted.events == 0
    assert shifted.time_ns == pytest.approx(base.time_ns + 500.0)
    for a, b in zip(base.per_rank_done_ns, shifted.per_rank_done_ns):
        assert b == pytest.approx(a + 500.0)


def test_analytic_skewed_run_has_distinct_tail():
    """Non-uniform skew must NOT be flattened to the closed form's
    [t]*n — p99 and p50 of per-rank completions must differ."""
    prog = C.ring_all_reduce(4, 1 << 16, 2)
    r = simulate(prog, fidelity="analytic", check="off",
                 rank_delay_ns=[0.0, 0.0, 0.0, 50_000.0])
    assert r.events > 0, "skewed runs must go through the interpreter"
    done = sorted(r.per_rank_done_ns)
    assert percentile(done, 99.0) > percentile(done, 50.0)


# ---------------------------------------------------------------------------
# bugfix 3: coll start_ns stamped at actual release, not launch
# ---------------------------------------------------------------------------

def _held_coll_trace(hold_ns=5000.0):
    et = ExecutionTrace(num_ranks=2)
    halves = et.coll(0, "all_reduce", 2048, "ring")
    for h in halves:
        h.start_after_ns = hold_ns
    return et


@pytest.mark.parametrize("fidelity", ["analytic", "coarse", "fine"])
def test_coll_start_stamped_at_release(fidelity):
    hold = 5000.0
    r = simulate(_held_coll_trace(hold), fidelity=fidelity, check="off")
    for nid, (start, end) in r.node_times.items():
        assert start >= hold - 1e-9, \
            f"{fidelity}: node {nid} stamped start {start} before its " \
            f"release at {hold}"
        assert end >= start


def test_coll_start_release_parity_across_tiers():
    """The release-time stamp is tier-invariant: every tier reports the
    held collective starting at its release, not at t=0."""
    hold = 12_345.0
    starts = {}
    for fid in ("analytic", "coarse", "fine"):
        r = simulate(_held_coll_trace(hold), fidelity=fid, check="off")
        starts[fid] = {nid: s for nid, (s, _) in r.node_times.items()}
    for fid, per_node in starts.items():
        assert all(s == pytest.approx(hold) for s in per_node.values()), \
            f"{fid}: starts {per_node} != release {hold}"


def test_comp_start_after_honored_at_every_tier():
    for fid in ("analytic", "coarse", "fine"):
        et = ExecutionTrace(num_ranks=2)
        et.comp(0, "a", flops=1e6, start_after_ns=3000.0)
        et.comp(1, "b", flops=1e6)
        r = simulate(et, fidelity=fid, check="off")
        assert r.node_times[0][0] >= 3000.0 - 1e-9
        assert r.node_times[1][0] < 3000.0


# ---------------------------------------------------------------------------
# TR-DUP-COLL: duplicate (coll_id, rank) halves
# ---------------------------------------------------------------------------

def _dup_coll_trace():
    et = ExecutionTrace(num_ranks=2)
    et.coll(7, "all_reduce", 1024, "ring")
    # reuse coll_id 7 for a second instance — the iterative-decode mistake
    et.coll(7, "all_reduce", 1024, "ring")
    return et


def test_check_trace_reports_tr_dup_coll():
    rep = check_trace(_dup_coll_trace(), deep=False)
    assert not rep.ok
    assert any(d.rule == "TR-DUP-COLL" for d in rep.diagnostics)
    assert any("appears twice" in d.message for d in rep.diagnostics)


def test_validate_rejects_duplicate_coll_halves():
    with pytest.raises(ValueError, match="appears twice"):
        _dup_coll_trace().validate()


def test_tier_executor_raises_on_duplicate_instead_of_miswiring(monkeypatch):
    """Even with validation bypassed, the cheap-tier executor must refuse
    to overwrite completion routing for a duplicate (coll_id, rank)."""
    monkeypatch.setattr(ExecutionTrace, "validate", lambda self: None)
    trace = _dup_coll_trace()
    from repro.core.backends import CoarseConfig
    backend = CoarseConfig().make_backend(None)
    ex = _TierTraceExecutor(trace, backend, CoarseConfig())
    with pytest.raises(RuntimeError, match="TR-DUP-COLL"):
        ex.run()


# ---------------------------------------------------------------------------
# p2p transfer: functional correctness + trace integration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("protocol", ["put", "get"])
def test_p2p_transfer_moves_the_bytes(protocol):
    prog = C.p2p_transfer(4, 512, 2, protocol=protocol, src=1, dst=3)
    inputs = verify.make_inputs(prog, seed=5)
    outs = verify.execute(prog, inputs, seed=5)
    assert np.array_equal(outs[3], inputs[1]), \
        "dst output must equal src input"
    for r in (0, 2):
        assert not np.array_equal(outs[r], inputs[1])


def test_p2p_transfer_rejects_bad_endpoints():
    with pytest.raises(ValueError):
        C.p2p_transfer(4, 512, src=0, dst=0)
    with pytest.raises(ValueError):
        C.p2p_transfer(4, 512, src=0, dst=7)


def test_p2p_trace_node_runs_at_every_tier():
    events = {}
    for fid in ("analytic", "coarse", "fine"):
        et = ExecutionTrace(num_ranks=4)
        pre = et.comp(0, "prefill", flops=1e6)
        et.p2p(0, 4096, src=0, dst=2, deps_by_rank={0: [pre]})
        r = simulate(et, fidelity=fid, check="off")
        assert r.time_ns > 0
        events[fid] = r.events
        # only the two endpoint halves exist; ranks 1 and 3 have no nodes
        assert len(r.node_times) == 3
    assert events["analytic"] <= events["coarse"] < events["fine"]


def test_p2p_trace_validation_rules():
    et = ExecutionTrace(num_ranks=4)
    half = et.p2p(0, 1024, src=0, dst=2)[0]
    half.rank = 1                                # half on a bystander rank
    with pytest.raises(ValueError, match="p2p half on rank"):
        et.validate()
    rep = check_trace(et, deep=False)
    assert any(d.rule == "TR-P2P" for d in rep.diagnostics)


def test_trace_json_round_trips_serving_fields():
    et = ExecutionTrace(num_ranks=2)
    a = et.comp(0, "a", flops=1e6, start_after_ns=1500.0)
    et.p2p(0, 2048, src=0, dst=1, deps_by_rank={0: [a]})
    for n in et.nodes:
        if n.kind == "coll":
            n.req_done = [4]
    text = et.to_json()
    back = ExecutionTrace.from_json(text)
    assert back.to_json() == text
    assert back.nodes[0].start_after_ns == 1500.0
    assert back.nodes[1].src_rank == 0 and back.nodes[1].dst_rank == 1
    assert back.nodes[1].req_done == [4]


def test_negative_start_after_rejected():
    et = ExecutionTrace(num_ranks=1)
    et.comp(0, "a", flops=1.0, start_after_ns=-1.0)
    with pytest.raises(ValueError, match="start_after_ns"):
        et.validate()
    assert any(d.rule == "TR-START"
               for d in check_trace(et, deep=False).diagnostics)


# ---------------------------------------------------------------------------
# traffic: seeded determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("proc", [
    PoissonArrivals(1000.0),
    DiurnalArrivals(800.0, amplitude=0.5, period_s=0.05),
    MMPPArrivals(200.0, 4000.0, mean_dwell_s=0.003),
], ids=lambda p: p.name)
def test_arrivals_deterministic_and_increasing(proc):
    a = proc.arrivals(64, seed=11)
    b = proc.arrivals(64, seed=11)
    assert a == b, "same seed must reproduce the stream bit-for-bit"
    assert proc.arrivals(64, seed=12) != a
    assert all(x < y for x, y in zip(a, a[1:]))
    assert a[0] > 0


def test_generate_requests_deterministic():
    r1 = toy_requests(n=20, seed=9)
    r2 = toy_requests(n=20, seed=9)
    assert r1 == r2
    assert r1 != toy_requests(n=20, seed=10)
    for r in r1:
        assert 8 <= r.prompt_tokens <= 32
        assert 2 <= r.decode_tokens <= 12


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank():
    vals = sorted(float(v) for v in range(1, 101))
    assert percentile(vals, 50.0) == 50.0
    assert percentile(vals, 99.0) == 99.0
    assert percentile(vals, 100.0) == 100.0
    assert percentile(vals, 0.0) == 1.0
    with pytest.raises(ValueError):
        percentile([], 50.0)


def test_request_latencies_raise_on_untagged_request():
    et = ExecutionTrace(num_ranks=1)
    n = et.comp(0, "a", flops=1.0)
    n.req_done = [0]
    reqs = [Request(0, 0.0, 1, 1), Request(1, 5.0, 1, 1)]
    with pytest.raises(ValueError, match="no req_done"):
        request_latencies(et, reqs, {n.nid: (0.0, 10.0)})


def test_latency_stats_from_known_distribution():
    reqs = [Request(i, 0.0, 1, 1) for i in range(10)]
    lats = {i: float(i + 1) for i in range(10)}
    s = latency_stats(reqs, lats)
    assert s.count == 10 and s.max_ns == 10.0
    assert s.p50_ns == 5.0 and s.mean_ns == pytest.approx(5.5)


# ---------------------------------------------------------------------------
# cross-tier serving parity suite
# ---------------------------------------------------------------------------

def _scenarios(seed=3):
    reqs = toy_requests(n=12, seed=seed)
    return [continuous_batching(TOY, reqs, tp=2),
            disaggregated(TOY, reqs, prefill_ranks=2, decode_ranks=2)]


def test_serving_monotone_fidelity_and_latency_attached():
    for scen in _scenarios():
        events = {}
        for fid in ("analytic", "coarse", "fine"):
            r = scen.simulate(fidelity=fid, check="off")
            events[fid] = r.events
            assert r.latency is not None
            assert r.latency.count == len(scen.requests)
            assert r.latency.p50_ns <= r.latency.p95_ns \
                <= r.latency.p99_ns <= r.latency.p999_ns <= r.latency.max_ns
            assert r.latency.goodput_rps > 0
        assert events["analytic"] <= events["coarse"] < events["fine"], \
            f"{scen.name}: fidelity must buy event detail, got {events}"


def test_serving_seeded_replay_bit_identical():
    for build in (lambda: _scenarios(seed=21)[0],
                  lambda: _scenarios(seed=21)[1]):
        a, b = build(), build()
        assert a.trace.to_json() == b.trace.to_json()
        ra = a.simulate(fidelity="coarse", check="off")
        rb = b.simulate(fidelity="coarse", check="off")
        assert ra.time_ns == rb.time_ns
        assert ra.events == rb.events
        assert ra.node_times == rb.node_times
        assert ra.latency == rb.latency


def test_serving_latency_exceeds_queueing_floor():
    """Every request's latency is positive, and bursty traffic queues:
    p999 is strictly above p50 for a scenario with contention."""
    scen = continuous_batching(TOY, toy_requests(n=24, seed=5, rate=5000.0),
                               tp=2, max_batch=4)
    r = scen.simulate(fidelity="coarse", check="off")
    lats = request_latencies(scen.trace, scen.requests, r.node_times)
    assert all(v > 0 for v in lats.values())
    assert r.latency.p999_ns > r.latency.p50_ns


def _assert_scenario_checks_clean(seed, proc_kind):
    proc = {"poisson": PoissonArrivals(1500.0),
            "diurnal": DiurnalArrivals(1000.0, 0.4, 0.02),
            "mmpp": MMPPArrivals(300.0, 3000.0, 0.002)}[proc_kind]
    reqs = generate_requests(proc, n=8, seed=seed,
                             prompt_tokens=(4, 16), decode_tokens=(2, 8))
    for scen in (continuous_batching(TOY, reqs, tp=2),
                 disaggregated(TOY, reqs)):
        rep = check_workload(scen.trace, None)
        assert rep.clean, f"{scen.name} (seed={seed}): {rep.format()}"


def test_seeded_scenarios_pass_check_workload_clean():
    """Deterministic stand-in for the hypothesis property below."""
    for seed in range(4):
        for kind in ("poisson", "diurnal", "mmpp"):
            _assert_scenario_checks_clean(seed, kind)


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                     # optional test extra
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000),
           st.sampled_from(["poisson", "diurnal", "mmpp"]))
    def test_generated_scenarios_pass_check_workload_clean(seed, kind):
        _assert_scenario_checks_clean(seed, kind)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_generated_scenarios_pass_check_workload_clean():
        pass
