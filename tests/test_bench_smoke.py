"""Bench smoke check (ISSUE 2 satellite): the tracked benchmark must not
regress against the committed ``results/BENCH_engine.json`` baseline.

Runs the tracked workload (8-rank 1 MiB ring all-reduce, default NoC,
coalesce + bulk emission) once and asserts, against the committed baseline:

* ``time_ns`` is bit-identical (the simulation result is deterministic —
  any drift means the schedule changed);
* the heap-event count did not regress (> 2% more events fails);
* the run stays FIFO-certified (``order_violations == 0``).

Wall clock is intentionally NOT asserted — CI boxes are shared-CPU and a
single sample swings by 30%; events/time are the stable proxies.
"""

import json
import os

import pytest

from repro.core import collectives as C
from repro.core.cluster import Cluster, NocConfig
from repro.core.system import simulate_collective

BASELINE = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_engine.json")


@pytest.mark.slow
def test_tracked_benchmark_matches_committed_baseline():
    if not os.path.exists(BASELINE):
        pytest.skip("no committed BENCH_engine.json baseline")
    with open(BASELINE) as f:
        base = json.load(f)
    wl = base["workload"]
    assert wl["collective"] == "ring_all_reduce"
    ref = base["modes"]["coalesce"]

    cluster = Cluster(wl["nranks"], noc=NocConfig())
    r = simulate_collective(
        C.ring_all_reduce(wl["nranks"], wl["size_bytes"],
                          wl["nworkgroups"], wl["protocol"]),
        cluster=cluster)

    assert r.time_ns == ref["time_ns"], \
        f"simulated time drifted: {r.time_ns} != baseline {ref['time_ns']}"
    assert cluster.fabric.order_violations == 0
    assert r.events <= ref["events"] * 1.02, \
        f"event count regressed: {r.events} vs baseline {ref['events']}"


@pytest.mark.slow
def test_tracked_benchmark_ledger_off_row():
    """The ledger-off path stays gated too: same simulated time, and the
    ledger must keep strictly beating it on heap events."""
    if not os.path.exists(BASELINE):
        pytest.skip("no committed BENCH_engine.json baseline")
    with open(BASELINE) as f:
        base = json.load(f)
    ref = base["modes"].get("coalesce_ledger_off")
    if ref is None:
        pytest.skip("baseline predates the ledger rows")
    wl = base["workload"]

    cluster = Cluster(wl["nranks"], noc=NocConfig(fabric_ledger="off"))
    r = simulate_collective(
        C.ring_all_reduce(wl["nranks"], wl["size_bytes"],
                          wl["nworkgroups"], wl["protocol"]),
        cluster=cluster)

    assert r.time_ns == ref["time_ns"], \
        "ledger off/on must simulate the identical schedule"
    assert cluster.fabric.order_violations == 0
    assert r.events <= ref["events"] * 1.02
    assert base["modes"]["coalesce"]["events"] < ref["events"], \
        "committed baseline must show the ledger reducing events"
