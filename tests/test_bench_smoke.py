"""Bench smoke check (ISSUE 2 satellite; trace + quick gates, ISSUE 5):
the tracked benchmarks must not regress against the committed baselines —
``results/BENCH_engine.json`` (8-rank 1 MiB ring all-reduce),
``results/BENCH_engine_quick.json`` (the --quick 128 KiB variant) and
``results/BENCH_trace.json`` (the 2-step training-loop trace at every
fidelity tier).

Each gate reruns its workload once and asserts, against the committed row:

* ``time_ns`` is bit-identical (the simulation result is deterministic —
  any drift means the schedule changed);
* the heap-event count did not regress (> 2% more events fails);
* fine-tier runs stay FIFO-certified (``order_violations == 0``).

Wall clock is intentionally NOT asserted — CI boxes are shared-CPU and a
single sample swings by 30%; events/time are the stable proxies.
"""

import json
import os
import sys

import pytest

from repro.core import collectives as C
from repro.core.backends import FineConfig, simulate
from repro.core.cluster import Cluster, NocConfig
from repro.core.system import simulate_collective

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
BASELINE = os.path.join(RESULTS, "BENCH_engine.json")
QUICK_BASELINE = os.path.join(RESULTS, "BENCH_engine_quick.json")
TRACE_BASELINE = os.path.join(RESULTS, "BENCH_trace.json")
SERVING_BASELINE = os.path.join(RESULTS, "BENCH_serving.json")
SCALABILITY_BASELINE = os.path.join(RESULTS, "BENCH_scalability.json")


@pytest.mark.slow
def test_tracked_benchmark_matches_committed_baseline():
    if not os.path.exists(BASELINE):
        pytest.skip("no committed BENCH_engine.json baseline")
    with open(BASELINE) as f:
        base = json.load(f)
    wl = base["workload"]
    assert wl["collective"] == "ring_all_reduce"
    ref = base["modes"]["coalesce"]

    cluster = Cluster(wl["nranks"], noc=NocConfig())
    r = simulate_collective(
        C.ring_all_reduce(wl["nranks"], wl["size_bytes"],
                          wl["nworkgroups"], wl["protocol"]),
        cluster=cluster)

    assert r.time_ns == ref["time_ns"], \
        f"simulated time drifted: {r.time_ns} != baseline {ref['time_ns']}"
    assert cluster.fabric.order_violations == 0
    assert r.events <= ref["events"] * 1.02, \
        f"event count regressed: {r.events} vs baseline {ref['events']}"


@pytest.mark.slow
def test_tracked_benchmark_ledger_off_row():
    """The ledger-off path stays gated too: same simulated time, and the
    ledger must keep strictly beating it on heap events."""
    if not os.path.exists(BASELINE):
        pytest.skip("no committed BENCH_engine.json baseline")
    with open(BASELINE) as f:
        base = json.load(f)
    ref = base["modes"].get("coalesce_ledger_off")
    if ref is None:
        pytest.skip("baseline predates the ledger rows")
    wl = base["workload"]

    cluster = Cluster(wl["nranks"], noc=NocConfig(fabric_ledger="off"))
    r = simulate_collective(
        C.ring_all_reduce(wl["nranks"], wl["size_bytes"],
                          wl["nworkgroups"], wl["protocol"]),
        cluster=cluster)

    assert r.time_ns == ref["time_ns"], \
        "ledger off/on must simulate the identical schedule"
    assert cluster.fabric.order_violations == 0
    assert r.events <= ref["events"] * 1.02
    assert base["modes"]["coalesce"]["events"] < ref["events"], \
        "committed baseline must show the ledger reducing events"


@pytest.mark.slow
def test_quick_benchmark_matches_committed_baseline():
    """The --quick engine row is gated too (same workload at 1/8 size) —
    cheap enough to catch schedule drift without the full-size run."""
    if not os.path.exists(QUICK_BASELINE):
        pytest.skip("no committed BENCH_engine_quick.json baseline")
    with open(QUICK_BASELINE) as f:
        base = json.load(f)
    wl = base["workload"]
    ref = base["modes"]["coalesce"]

    cluster = Cluster(wl["nranks"], noc=NocConfig())
    r = simulate_collective(
        C.ring_all_reduce(wl["nranks"], wl["size_bytes"],
                          wl["nworkgroups"], wl["protocol"]),
        cluster=cluster)

    assert r.time_ns == ref["time_ns"], \
        f"simulated time drifted: {r.time_ns} != baseline {ref['time_ns']}"
    assert cluster.fabric.order_violations == 0
    assert r.events <= ref["events"] * 1.02, \
        f"event count regressed: {r.events} vs baseline {ref['events']}"


@pytest.mark.slow
def test_quick_benchmark_wall_within_tolerance_of_median():
    """Coarse wall-clock gate (ISSUE 6): the committed quick row records
    min/median/stddev over ``WALL_TRIALS`` runs; a fresh single sample must
    land within a *generous* multiple of the committed median.  This only
    catches order-of-magnitude perf regressions — shared-CPU CI boxes swing
    individual samples by 30%+, so anything tighter would flake."""
    if not os.path.exists(QUICK_BASELINE):
        pytest.skip("no committed BENCH_engine_quick.json baseline")
    with open(QUICK_BASELINE) as f:
        base = json.load(f)
    ref = base["modes"]["coalesce"]
    med = ref.get("wall_median_s")
    if med is None:
        pytest.skip("baseline predates wall_median_s")
    wl = base["workload"]

    import time
    cluster = Cluster(wl["nranks"], noc=NocConfig())
    t0 = time.perf_counter()
    simulate_collective(
        C.ring_all_reduce(wl["nranks"], wl["size_bytes"],
                          wl["nworkgroups"], wl["protocol"]),
        cluster=cluster)
    wall = time.perf_counter() - t0
    assert wall <= med * 4 + 2.0, \
        f"quick coalesce wall {wall:.2f}s blew past committed median {med}s"


@pytest.mark.slow
def test_trace_benchmark_matches_committed_baseline():
    """The tracked trace workload (ISSUE 5): every fidelity tier's
    ``time_ns`` must stay bit-identical to the committed BENCH_trace.json
    and its event count must not regress."""
    if not os.path.exists(TRACE_BASELINE):
        pytest.skip("no committed BENCH_trace.json baseline")
    with open(TRACE_BASELINE) as f:
        base = json.load(f)
    wl = base["workload"]
    assert wl["kind"] == "training_loop_trace"
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    try:
        from trace_throughput import training_loop_trace
    finally:
        sys.path.pop(0)

    for fid, ref in base["tiers"].items():
        trace = training_loop_trace(wl["nranks"], wl["steps"],
                                    wl["grad_bytes"], wl["fwd_flops"],
                                    wl["opt_flops"])
        cfg = FineConfig(coll_workgroups=wl["coll_workgroups"]) \
            if fid == "fine" else None
        r = simulate(trace, fidelity=fid, config=cfg)
        assert r.time_ns == ref["time_ns"], \
            f"{fid} trace time drifted: {r.time_ns} != {ref['time_ns']}"
        assert r.events <= ref["events"] * 1.02, \
            f"{fid} trace events regressed: {r.events} vs {ref['events']}"


@pytest.mark.slow
def test_serving_benchmark_matches_committed_baseline():
    """The tracked serving scenarios (ISSUE 8): the seeded Poisson
    continuous-batching and disaggregated prefill/decode workloads must
    reproduce every committed tail-latency row bit-for-bit at every tier
    (time_ns and all percentiles), and event counts must not regress."""
    if not os.path.exists(SERVING_BASELINE):
        pytest.skip("no committed BENCH_serving.json baseline")
    with open(SERVING_BASELINE) as f:
        base = json.load(f)
    assert base["workload"]["kind"] == "serving_scenarios"
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    try:
        from serving_tail_latency import build_scenarios
    finally:
        sys.path.pop(0)

    scens = build_scenarios()
    assert set(scens) == set(base["scenarios"])
    for name, tiers in base["scenarios"].items():
        for fid, ref in tiers.items():
            r = scens[name].simulate(fidelity=fid, check="off")
            assert r.time_ns == ref["time_ns"], \
                f"{name}/{fid} time drifted: {r.time_ns} != {ref['time_ns']}"
            got = r.latency.to_dict()
            for key in ("p50_ns", "p99_ns", "p999_ns", "mean_ns", "max_ns",
                        "goodput_rps"):
                assert got[key] == ref[key], \
                    f"{name}/{fid} {key} drifted: {got[key]} != {ref[key]}"
            assert r.events <= ref["events"] * 1.02, \
                f"{name}/{fid} events regressed: {r.events} vs {ref['events']}"


@pytest.mark.slow
def test_scalability_benchmark_matches_committed_baseline():
    """The tracked 2-128-rank hierarchical sweep (ISSUE 9): rows up to 32
    ranks are re-simulated and must reproduce the committed ``time_ns``
    bit-for-bit with no event regression; every committed row must stay
    FIFO-certified with O(n) lazy route registration; and the committed
    sweep's events-vs-ranks growth must stay near-linear (the 64- and
    128-rank points are gated through the committed numbers only — too
    slow to re-run on every CI pass)."""
    if not os.path.exists(SCALABILITY_BASELINE):
        pytest.skip("no committed BENCH_scalability.json baseline")
    with open(SCALABILITY_BASELINE) as f:
        base = json.load(f)
    assert base["workload"]["collective"] == "ring_all_gather"
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    try:
        from fig14_scalability import bench_point
    finally:
        sys.path.pop(0)

    rows = base["sweep"]
    assert rows[-1]["ranks"] >= 128, "sweep must reach 128 ranks"
    for ref in rows:
        n = ref["ranks"]
        assert ref["order_violations"] == 0
        assert ref["pairs_registered"] <= 4 * n, \
            f"route registration not sub-quadratic at {n} ranks: {ref}"
        if n > 32:
            continue
        got = bench_point(ref["hosts"], ref["gpus_per_host"])
        assert got["time_ns"] == ref["time_ns"], \
            f"{n}-rank time drifted: {got['time_ns']} != {ref['time_ns']}"
        assert got["order_violations"] == 0
        assert got["events"] <= ref["events"] * 1.02, \
            f"{n}-rank events regressed: {got['events']} vs {ref['events']}"
    # near-linear growth: log-log slope of events vs ranks well below
    # quadratic, and events-per-rank spread across the >=8-rank tail bounded
    assert base["loglog_slope_events_vs_ranks"] <= 1.4, base
    assert base["events_per_rank_spread_tail"] <= 2.0, base


def test_sweep_cli_smoke_two_workers(tmp_path):
    """Tier-1 sweep smoke (ISSUE 10): ``python -m repro.sweep demo_smoke``
    with two workers must complete its 8-point analytic prefilter plus one
    escalated fine point, exit 0, and emit schema-clean JSONL rows."""
    import subprocess

    from repro.sweep import read_jsonl, validate_jsonl

    repo = os.path.join(os.path.dirname(__file__), "..")
    out = tmp_path / "demo_smoke.jsonl"
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo, "src"), env.get("PYTHONPATH")) if p)
    env["REPRO_SWEEP_CACHE"] = str(tmp_path / "cache")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.sweep", "demo_smoke", "--jobs", "2",
         "--out", str(out), "--fresh"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, \
        f"sweep CLI failed:\n{proc.stdout}\n{proc.stderr}"

    rows = list(read_jsonl(out))
    by_tier = {}
    for r in rows:
        by_tier.setdefault(r["tier"], []).append(r)
    assert len(by_tier.get("analytic", ())) == 8, by_tier.keys()
    assert len(by_tier.get("fine", ())) == 1, by_tier.keys()
    assert all(r["status"] == "ok" for r in rows), \
        [r for r in rows if r["status"] != "ok"]
    assert validate_jsonl(out) == {}
