"""Fabric/engine fast path: bit-exactness and event accounting.

Three scheduling modes share one model:

* ``classic``  — reference implementation, two heap events per hop;
* ``exact``    — one event per hop + sound lookahead chaining (region
  horizons, sole-feeder corridors), provably bit-identical schedules;
* ``coalesce`` — ``exact`` plus train coalescing of back-to-back
  same-route flights, still certified by the per-link FIFO monitor
  (``order_violations == 0``  =>  bit-identical to the un-coalesced run).

All three modes are bit-exact against each other: same-tick link-service
ties resolve by the deterministic route tie-break key (``fabric.Route``),
not by each mode's incidental heap insertion order, so even symmetric
workloads (all_to_all over the ring wiring) schedule identically in
classic, exact and coalesce — with the ledger on or off.
"""

import pytest

from repro.core import collectives as C
from repro.core.cluster import Cluster, NocConfig
from repro.core.engine import Engine
from repro.core.network.fabric import (CONTROL, DATA, Fabric, MODE_CLASSIC,
                                       MODE_COALESCE, MODE_EXACT)
from repro.core.system import simulate_collective

SMALL = dict(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
             io_ports=4)
MODES = (MODE_CLASSIC, MODE_EXACT, MODE_COALESCE)


def run_modes(prog_fn, *, topology="switch", nranks=4, **sim_kw):
    out = {}
    for mode in MODES:
        cluster = Cluster(nranks, noc=NocConfig(fabric_mode=mode, **SMALL),
                          topology=topology)
        r = simulate_collective(prog_fn(), cluster=cluster, **sim_kw)
        out[mode] = (r, cluster)
    return out


@pytest.mark.parametrize("gen,args,kw", [
    (C.ring_all_gather, (2, 4096, 1, "get"), {}),
    (C.ring_all_reduce, (3, 16384, 2, "put"), {}),
    (C.ring_all_reduce, (4, 8192, 2, "put"), {}),
    (C.ring_all_gather, (4, 2048, 1, "get"), {}),
    (C.direct_reduce_scatter, (4, 4096, 2, "get"), {}),
    (C.direct_all_to_all, (4, 8192, 2, "put"), dict(unroll=8)),
    (C.double_binary_tree_all_reduce, (5, 4096, 1), {}),
])
def test_modes_bit_exact(gen, args, kw):
    res = run_modes(lambda: gen(*args), nranks=args[0], **kw)
    # the hard guarantee: coalesced == un-coalesced, bit for bit
    rex, rco = res[MODE_EXACT][0], res[MODE_COALESCE][0]
    assert rco.time_ns == rex.time_ns
    assert rco.per_rank_done_ns == rex.per_rank_done_ns
    # classic is bit-exact too: same-tick service ties resolve by the
    # deterministic route key in every mode
    rcl = res[MODE_CLASSIC][0]
    assert rcl.time_ns == rex.time_ns
    assert rcl.per_rank_done_ns == rex.per_rank_done_ns
    # the fast paths must also process strictly fewer heap events.  With
    # the reservation ledger, exact and coalesce are no longer strictly
    # ordered: trains chain differently than single lines (own-delivery
    # caps, splits), leaving a few percent of accounting noise between the
    # two (the identical-timing asserts above are the hard guarantee).
    assert rex.events < rcl.events
    assert rco.events <= rex.events * 1.05
    # and the run certifies itself: no FIFO inversion anywhere
    assert res[MODE_COALESCE][1].fabric.order_violations == 0


def test_ring_topology_bit_exact():
    for nranks in (2, 4):
        res = run_modes(lambda: C.ring_all_reduce(nranks, 8192, 1, "put"),
                        topology="ring", nranks=nranks)
        assert res[MODE_COALESCE][0].time_ns == res[MODE_EXACT][0].time_ns
        assert res[MODE_CLASSIC][0].time_ns == res[MODE_EXACT][0].time_ns


def test_straggler_injection_bit_exact():
    res = run_modes(lambda: C.ring_all_gather(4, 2048, 1, "put"),
                    rank_delay_ns=[0, 0, 50_000, 0])
    assert len({r.time_ns for r, _ in res.values()}) == 1


def test_event_reduction_target():
    """The headline fast-path claim at test scale: >= 2.5x fewer events on
    a ring all-reduce (the full benchmark measures >= 3x at 1 MiB)."""
    res = run_modes(lambda: C.ring_all_reduce(4, 32768, 1, "put"))
    assert res[MODE_CLASSIC][0].events / res[MODE_COALESCE][0].events > 2.5


def test_trains_coalesce_on_contended_bottleneck():
    """Back-to-back same-route messages on a slow link ride shared train
    events: same arrival times, fewer heap events."""
    def run(mode):
        eng = Engine()
        fab = Fabric(eng, mode=mode)
        a, b, c = fab.add_node("a"), fab.add_node("b"), fab.add_node("c")
        fab.add_link(a, b, 1.0, 50.0)     # slow: 1 B/ns
        fab.add_link(b, c, 1.0, 50.0)
        route = fab.route(a, c)
        arrivals = []
        for i in range(32):
            # back-to-back: all injected at t=0, queue up on the first link
            fab.send(route, 256, DATA, lambda f: arrivals.append(eng.now))
        eng.run()
        return arrivals, eng.events_processed

    base, ev_exact = run(MODE_EXACT)
    coal, ev_coal = run(MODE_COALESCE)
    assert coal == base                      # bit-identical arrival times
    assert ev_coal < ev_exact                # strictly fewer heap events
    assert len(base) == 32 and base == sorted(base)


def test_fair_arbitration_still_uses_classic_machinery():
    """`fair` links cannot be precomputed (round-robin depends on queue
    state at pick time): they must keep the classic path in every mode."""
    eng = Engine()
    fab = Fabric(eng, default_policy="fair", mode=MODE_COALESCE)
    a, b = fab.add_node("a"), fab.add_node("b")
    link, _ = fab.add_bidi(a, b, 1.0, 10.0)
    assert not link.fast
    route = fab.route(a, b)
    got = []
    # first data goes straight into service; the control message then
    # round-robins ahead of the queued second data message
    fab.send(route, 1000, DATA, lambda f: got.append("data"))
    fab.send(route, 1000, DATA, lambda f: got.append("data"))
    fab.send(route, 10, CONTROL, lambda f: got.append("ctl"))
    eng.run()
    assert got == ["data", "ctl", "data"]


def test_order_violation_monitor_counts_optimistic_window():
    """With an optimistic coalescing window, contended links may invert
    FIFO order by a bounded amount — and the run must report it."""
    prog = C.direct_all_to_all(4, 8192, 2, "put")
    cluster = Cluster(4, noc=NocConfig(fabric_mode=MODE_COALESCE,
                                       coalesce_window_ns=2000.0, **SMALL))
    r = simulate_collective(prog, cluster=cluster, unroll=8)
    assert r.time_ns > 0
    assert cluster.fabric.order_violations > 0  # detected, not silent


def test_integer_picosecond_invariants():
    eng = Engine()
    fab = Fabric(eng, mode=MODE_COALESCE)
    a, b = fab.add_node("a"), fab.add_node("b")
    link = fab.add_link(a, b, 3.0, 7.3)
    # serialization/propagation are rounded once, to integer picoseconds
    assert link._ser_ps(100) == int(round(100 / 3.0 * 1000))
    assert link._lat_ps == 7300
    done = []
    fab.send(fab.route(a, b), 100, DATA, lambda f: done.append(eng.now_ps))
    eng.run()
    assert done == [link._ser_ps(100) + 7300]
