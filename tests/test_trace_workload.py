"""Workload seam (ISSUE 5 tentpole): traces through simulate() at every tier.

Covers the typed-config entry point (`simulate(workload, infra, fidelity=,
config=)`), the cross-tier trace parity suite (same ExecutionTrace at
fine/coarse/analytic: dependency order respected, comp/coll overlap sane,
fine bit-exact vs. the direct TraceExecutor path), the ExecutionTrace JSON
round-trip, and a hypothesis property running random DAGs at every tier.
"""

import json
import warnings

import pytest

from repro.core import collectives as C
from repro.core.backends import (AnalyticConfig, CoarseConfig, FIDELITIES,
                                 FineConfig, SimResult, simulate)
from repro.core.chakra import ExecutionTrace, TraceExecutor, TraceResult
from repro.core.cluster import Cluster, NocConfig
from repro.core.infragraph import single_tier_fabric

SMALL = dict(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
             io_ports=4)


def small_noc(**kw):
    return NocConfig(**SMALL, **kw)


def training_step_trace(nranks=4, steps=2, grad_bytes=4096):
    """A small training loop: fwd comp -> grad all-reduce -> optimizer comp,
    chained across steps (the workload shape DSE studies sweep)."""
    et = ExecutionTrace(num_ranks=nranks)
    prev = {r: None for r in range(nranks)}
    for s in range(steps):
        fwd = {r: et.comp(r, f"fwd{s}.r{r}", flops=2e6, bytes_moved=1 << 16,
                          deps=[prev[r]] if prev[r] else None)
               for r in range(nranks)}
        ar = et.coll(2 * s, "all_reduce", grad_bytes, "ring",
                     deps_by_rank={r: [fwd[r]] for r in range(nranks)})
        opt = {r: et.comp(r, f"opt{s}.r{r}", flops=5e5, deps=[ar[r]])
               for r in range(nranks)}
        prev = opt
    return et


# ---------------------------------------------------------------------------
# cross-tier parity: one trace, three fidelities
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tier_results():
    infra = single_tier_fabric(4, link_GBps=50.0)
    out = {}
    for fid in FIDELITIES:
        cfg = FineConfig(noc=small_noc()) if fid == "fine" else None
        out[fid] = simulate(training_step_trace(), infra, fidelity=fid,
                            config=cfg)
    return out


def test_trace_runs_at_every_tier(tier_results):
    for fid, r in tier_results.items():
        assert isinstance(r, TraceResult) and isinstance(r, SimResult)
        assert r.fidelity == fid
        assert r.time_ns > 0
        assert len(r.per_rank_done_ns) == 4
        assert max(r.per_rank_done_ns) == r.time_ns
        assert len(r.node_times) == len(training_step_trace().nodes)


def test_trace_dependency_order_respected_at_every_tier(tier_results):
    trace = training_step_trace()
    by_id = {n.nid: n for n in trace.nodes}
    for fid, r in tier_results.items():
        for n in trace.nodes:
            start = r.node_times[n.nid][0]
            for d in n.deps:
                dep_end = r.node_times[d][1]
                assert start >= dep_end - 1e-9, \
                    f"{fid}: node {n.nid} started at {start} before dep " \
                    f"{d} ({by_id[d].name}) finished at {dep_end}"


def test_trace_fidelity_event_ordering(tier_results):
    """Fidelity buys detail for traces too: events rise with the tier."""
    assert tier_results["analytic"].events <= tier_results["coarse"].events
    assert tier_results["coarse"].events < tier_results["fine"].events


def test_fine_trace_bit_exact_vs_direct_trace_executor():
    """`simulate(trace, fidelity='fine')` must reproduce the pre-redesign
    TraceExecutor path bit for bit (same scenarios as test_system_layer)."""
    def scenario_a():
        et = ExecutionTrace(num_ranks=2)
        comp = {r: et.comp(r, f"gemm.r{r}", flops=1e7) for r in range(2)}
        et.coll(0, "all_reduce", 4096, "ring",
                deps_by_rank={r: [comp[r]] for r in range(2)})
        return et

    def scenario_b():
        et = ExecutionTrace(num_ranks=2)
        first = et.coll(0, "all_gather", 2048, "ring")
        et.coll(1, "all_gather", 2048, "ring",
                deps_by_rank={r: [first[r]] for r in range(2)})
        return et

    for mk in (scenario_a, scenario_b):
        direct = TraceExecutor(mk(), Cluster(2, noc=small_noc()),
                               comp_workgroups=4, coll_workgroups=2).run()
        via = simulate(mk(), fidelity="fine",
                       config=FineConfig(noc=small_noc(), comp_workgroups=4,
                                         coll_workgroups=2))
        assert via.time_ns == direct.time_ns
        assert via.per_rank_done_ns == direct.per_rank_end_ns
        assert via.node_times == direct.node_times


def test_comp_coll_overlap_at_coarse_tier():
    """A compute node independent of an in-flight collective must overlap
    it (the seam's whole point for overlap studies)."""
    nranks = 4
    et = ExecutionTrace(num_ranks=nranks)
    et.coll(0, "all_reduce", 1 << 16, "ring")
    for r in range(nranks):
        et.comp(r, f"bg.r{r}", flops=1e8)       # no deps: free to overlap
    r = simulate(et, fidelity="coarse")
    comp_dur = max(r.node_times[n.nid][1] - r.node_times[n.nid][0]
                   for n in et.nodes if n.kind == "comp")
    coll_dur = max(r.node_times[n.nid][1] - r.node_times[n.nid][0]
                   for n in et.nodes if n.kind == "coll")
    assert r.time_ns >= max(comp_dur, coll_dur)
    assert r.time_ns < comp_dur + coll_dur, \
        "independent comp and coll must overlap, not serialize"


def test_trace_straggler_skew_propagates_at_cheap_tiers():
    """A slow rank's comp delays every rank's collective completion."""
    def mk(slow):
        et = ExecutionTrace(num_ranks=4)
        fwd = {r: et.comp(r, f"fwd.r{r}",
                          flops=(1e9 if slow and r == 2 else 1e6))
               for r in range(4)}
        et.coll(0, "all_reduce", 8192, "ring",
                deps_by_rank={r: [fwd[r]] for r in range(4)})
        return et
    base = simulate(mk(False), fidelity="coarse")
    lag = simulate(mk(True), fidelity="coarse")
    assert lag.time_ns > base.time_ns + 1e4


def test_program_and_trace_results_handled_uniformly():
    """Sweep-script contract: one SimResult base over both workload kinds."""
    rows = [
        simulate(C.ring_all_reduce(4, 4096, 1, "put"), fidelity="coarse"),
        simulate(training_step_trace(), fidelity="coarse"),
    ]
    for r in rows:
        assert isinstance(r, SimResult)
        for f in ("time_ns", "events", "wallclock_s", "fidelity",
                  "per_rank_done_ns"):
            assert getattr(r, f) is not None


# ---------------------------------------------------------------------------
# typed configs: unknown keys fail fast, shim keeps old call sites alive
# ---------------------------------------------------------------------------

def test_unknown_kwarg_raises_with_valid_keys():
    with pytest.raises(TypeError, match=r"unknown keyword.*valid keys"):
        simulate(C.ring_all_gather(2, 256, 1, "put"), fidelity="coarse",
                 noc=small_noc())
    with pytest.raises(TypeError, match="link_GBp"):
        simulate(C.ring_all_gather(2, 256, 1, "put"), fidelity="coarse",
                 link_GBpss=1.0)      # typo'd key names the valid spelling


def test_config_dataclass_rejects_unknown_fields():
    with pytest.raises(TypeError):
        CoarseConfig(noc=small_noc())


def test_trace_run_rejects_program_only_kwargs():
    with pytest.raises(TypeError, match="valid run keys"):
        simulate(training_step_trace(), fidelity="coarse",
                 config=CoarseConfig(), rank_delay_ns=[0, 0, 0, 0])


def test_fidelity_config_conflict_raises():
    with pytest.raises(ValueError, match="conflicts"):
        simulate(C.ring_all_gather(2, 256, 1, "put"), fidelity="coarse",
                 config=AnalyticConfig())


def test_config_fidelity_is_inferred():
    r = simulate(C.ring_all_gather(2, 256, 1, "put"),
                 config=AnalyticConfig())
    assert r.fidelity == "analytic"


def test_legacy_kwargs_shim_warns_and_matches_typed_config():
    prog = lambda: C.ring_all_reduce(4, 4096, 1, "put")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = simulate(prog(), fidelity="fine", noc=small_noc())
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    typed = simulate(prog(), fidelity="fine",
                     config=FineConfig(noc=small_noc()))
    assert legacy.time_ns == typed.time_ns


def test_legacy_coarse_kwargs_still_run():
    r = simulate(C.ring_all_gather(4, 2048, 1, "put"), fidelity="coarse",
                 link_GBps=100.0, link_lat_ns=500.0)
    assert r.time_ns > 0


def test_queued_comp_nodes_report_real_start_times():
    """Two independent comp nodes on one rank serialize on the per-rank
    timeline — node_times must report the real roofline start, not the
    dispatch tick, or overlap studies read durations ~2x too long."""
    et = ExecutionTrace(num_ranks=1)
    a = et.comp(0, "a", flops=1e6)
    b = et.comp(0, "b", flops=1e6)
    r = simulate(et, fidelity="coarse")
    a_start, a_end = r.node_times[a.nid]
    b_start, b_end = r.node_times[b.nid]
    assert b_start == pytest.approx(a_end)
    assert (b_end - b_start) == pytest.approx(a_end - a_start)


def test_duplicate_coll_id_rejected():
    """Reusing a coll_id across two collective instances used to corrupt
    the per-coll kernel cache (silently wrong fine time, cheap-tier hang);
    validate() now rejects it up front."""
    et = ExecutionTrace(num_ranks=2)
    first = et.coll(0, "all_gather", 1024, "ring")
    et.coll(0, "all_gather", 1024, "ring",
            deps_by_rank={r: [first[r]] for r in range(2)})
    with pytest.raises(ValueError, match="appears twice"):
        simulate(et, fidelity="fine", config=FineConfig(noc=small_noc()))


def test_partial_or_inconsistent_coll_group_rejected():
    from repro.core.chakra import ETNode
    et = ExecutionTrace(num_ranks=2)
    # missing rank half
    et.nodes.append(ETNode(0, 0, "ar", "coll", coll_id=0,
                           coll_kind="all_reduce", coll_bytes=512))
    with pytest.raises(ValueError, match="missing rank halves"):
        et.validate()
    # inconsistent payload across ranks
    et.nodes.append(ETNode(1, 1, "ar", "coll", coll_id=0,
                           coll_kind="all_reduce", coll_bytes=1024))
    with pytest.raises(ValueError, match="inconsistent"):
        et.validate()


def test_empty_trace_rejected_with_actionable_error():
    with pytest.raises(ValueError, match="num_ranks >= 1"):
        ExecutionTrace.from_json("[]")
    with pytest.raises(ValueError, match="num_ranks >= 1"):
        simulate(ExecutionTrace(num_ranks=0), fidelity="coarse")


# ---------------------------------------------------------------------------
# ExecutionTrace JSON round-trip (ISSUE 5 satellite)
# ---------------------------------------------------------------------------

def test_trace_json_round_trip():
    et = training_step_trace()
    text = et.to_json()
    back = ExecutionTrace.from_json(text)
    assert back.num_ranks == et.num_ranks
    assert back.to_json() == text
    assert [n.__dict__ for n in back.nodes] == [n.__dict__ for n in et.nodes]
    # the re-imported trace is runnable and appendable (fresh node ids)
    assert back._next == max(n.nid for n in et.nodes) + 1
    r = simulate(back, fidelity="analytic")
    assert r.time_ns > 0


def test_trace_json_strips_runtime_fields():
    et = training_step_trace(nranks=2, steps=1)
    simulate(et, fidelity="coarse")            # stamps start/end on nodes
    d = json.loads(et.to_json())
    assert all("start_ns" not in n and "end_ns" not in n for n in d["nodes"])
    back = ExecutionTrace.from_json(et.to_json())
    assert all(n.start_ns < 0 and n.end_ns < 0 for n in back.nodes)


def test_trace_json_accepts_legacy_runtime_fields():
    """Old dumps carried runtime fields; the loader ignores them."""
    nodes = [{"nid": 0, "rank": 0, "name": "k", "kind": "comp",
              "flops": 1.0, "start_ns": 5.0, "end_ns": 9.0}]
    back = ExecutionTrace.from_json(json.dumps(nodes))   # legacy bare list
    assert back.num_ranks == 1
    assert back.nodes[0].start_ns < 0


@pytest.mark.parametrize("mutate,err", [
    (lambda d: d["nodes"][0].update(bogus=1), "unknown field"),
    (lambda d: d["nodes"][0].pop("kind"), "missing required"),
    (lambda d: d["nodes"][0].update(kind="mystery"), "bad kind"),
    (lambda d: d["nodes"][-1].update(deps=[999]), "missing dep"),
    (lambda d: next(n for n in d["nodes"] if n["kind"] == "coll")
     .update(algorithm="quantum"), "no algorithm"),
    (lambda d: d.pop("nodes"), "'nodes' list"),
])
def test_trace_json_validation_errors(mutate, err):
    d = json.loads(training_step_trace().to_json())
    mutate(d)
    with pytest.raises(ValueError, match=err):
        ExecutionTrace.from_json(json.dumps(d))


# ---------------------------------------------------------------------------
# property: random DAGs complete at every tier
# ---------------------------------------------------------------------------

def _assert_dag_completes_everywhere(et):
    text = et.to_json()
    for fid in FIDELITIES:
        trace = ExecutionTrace.from_json(text)
        cfg = FineConfig(noc=small_noc(), coll_workgroups=2,
                         comp_workgroups=2) if fid == "fine" else None
        r = simulate(trace, fidelity=fid, config=cfg)
        assert r.time_ns >= 0
        assert all(n.end_ns >= 0 for n in trace.nodes)
        for n in trace.nodes:
            for d in n.deps:
                assert r.node_times[n.nid][0] >= r.node_times[d][1] - 1e-9


def _grow_random_dag(rng) -> ExecutionTrace:
    """One random DAG: comp chains per rank interleaved with collectives
    that depend on each rank's latest node."""
    nranks = rng.randint(2, 3)
    et = ExecutionTrace(num_ranks=nranks)
    next_cid = 0
    for _ in range(rng.randint(1, 4)):
        if et.nodes and rng.random() < 0.5:
            rank = rng.randrange(nranks)
            mine = [n for n in et.nodes
                    if n.rank == rank and n.kind == "comp"]
            deps = [rng.choice(mine)] if mine and rng.random() < 0.5 else None
            et.comp(rank, f"c{et._next}", flops=rng.random() * 1e6, deps=deps)
        else:
            kind, algo = rng.choice([("all_reduce", "ring"),
                                     ("all_gather", "ring"),
                                     ("reduce_scatter", "direct")])
            last = {n.rank: n for n in et.nodes}
            et.coll(next_cid, kind, rng.choice([512, 2048]), algo,
                    deps_by_rank={r: [last[r]] for r in last})
            next_cid += 1
    return et


def test_seeded_random_dags_complete_at_every_tier():
    """Deterministic stand-in for the hypothesis property below, so the
    every-tier random-DAG guarantee is exercised even without hypothesis."""
    import random
    for seed in range(6):
        _assert_dag_completes_everywhere(_grow_random_dag(random.Random(seed)))


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                     # optional test extra
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.randoms(use_true_random=False))
    def test_random_dags_complete_at_every_tier(rng):
        _assert_dag_completes_everywhere(_grow_random_dag(rng))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_dags_complete_at_every_tier():
        pass
