"""Beyond-paper case study: straggler sensitivity of collective algorithms.

Injects per-rank launch skew into the fine-grained simulation.  For
all-gather BOTH algorithms pay the full skew (every rank needs the late
rank's shard — the simulator proves the dependency structure rather than
assuming it); per-rank completion times show WHERE the bubble sits.  This
is the fault-tolerance design loop the framework's straggler mitigation
builds on.

Run:  PYTHONPATH=src python examples/straggler_study.py
"""

from repro.core.cluster import NocConfig
from repro.core.collectives import (direct_all_gather, ring_all_gather)
from repro.core.gpu_model import GpuConfig
from repro.core.backends import FineConfig, simulate

NOC = NocConfig(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
                io_ports=4)
GPU = GpuConfig(cache_line=512)
KiB = 1 << 10
N = 8

print(f"{'algorithm':18s} {'clean_us':>9s} {'skewed_us':>10s} {'penalty':>8s}")
for name, gen in [("ring_ag", ring_all_gather),
                  ("direct_ag", direct_all_gather)]:
    cfg = FineConfig(noc=NOC, gpu_config=GPU)
    base = simulate(gen(N, 32 * KiB, 2, "put"), fidelity="fine", config=cfg,
                    unroll=4)
    skew = [0.0] * N
    skew[3] = 20_000.0            # one rank launches 20 us late — comparable
                                  # to the collective itself, so algorithm
                                  # structure (chained ring vs direct) shows
    lag = simulate(gen(N, 32 * KiB, 2, "put"), fidelity="fine", config=cfg,
                   unroll=4, rank_delay_ns=skew)
    penalty = (lag.time_ns - base.time_ns) / 20_000.0
    spread = max(lag.per_rank_done_ns) - min(lag.per_rank_done_ns)
    print(f"{name:18s} {base.time_ns/1e3:9.1f} {lag.time_ns/1e3:10.1f} "
          f"{penalty:7.2f}x   rank-finish spread {spread/1e3:6.1f} us")
print("penalty = extra completion per unit skew (1.0 = unavoidable for AG:")
print("every rank needs the late shard); spread shows where the bubble sits")
