"""Design-space exploration the paper showcases (§5.2): compare collective
algorithms / protocols / architectural knobs on the fine-grained simulator,
then author a CUSTOM MSCCL++ algorithm and validate + simulate it.

Run:  PYTHONPATH=src python examples/collective_design.py
"""

from repro.core.cluster import NocConfig
from repro.core.collectives import (direct_all_gather,
                                    direct_reduce_scatter, ring_all_reduce)
from repro.core.gpu_model import GpuConfig
from repro.core.mscclpp import ProgramBuilder
from repro.core.backends import FineConfig, simulate
from repro.core.verify import check_program

NOC = NocConfig(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
                io_ports=4)
GPU = GpuConfig(cache_line=512)
KiB = 1 << 10

print("== get vs put reduce-scatter (paper Fig. 10) ==")
for proto in ("put", "get"):
    r = simulate(direct_reduce_scatter(8, 64 * KiB, 4, proto),
                 fidelity="fine", config=FineConfig(noc=NOC, gpu_config=GPU),
                 unroll=4)
    print(f"  {proto}: {r.time_ns/1e3:9.1f} us   bw {r.bus_GBps:.2f} GB/s")

print("== loop unrolling on all-gather (paper Fig. 12 axis) ==")
for unroll in (1, 4, 16):
    r = simulate(direct_all_gather(8, 32 * KiB, 4, "put"),
                 fidelity="fine", config=FineConfig(noc=NOC, gpu_config=GPU),
                 unroll=unroll)
    print(f"  unroll={unroll:2d}: {r.time_ns/1e3:9.1f} us")

print("== custom algorithm: broadcast-reduce star (authored in the DSL) ==")
# rank 0 pulls every peer's shard and reduces; then pushes results back —
# a deliberately bad algorithm; the simulator shows WHY it's bad.
n, S = 4, 16 * KiB
b = ProgramBuilder("star_all_reduce", "all_reduce", n,
                   {"input": S, "output": S, "scratch": S * n}, 1)
for r in range(n):
    if r == 0:
        srcs = [("input", 0)] + [("input", 0, peer) for peer in range(1, n)]
        b.reduce(0, 0, srcs, ("output", 0), S)
        b.flush(0, 0)
        for peer in range(1, n):
            b.put(0, 0, ("output", 0), ("output", 0), S, remote=peer)
            b.flush(0, 0)
            b.signal(0, 0, remote=peer, sem=b.sem_id(peer, "done"))
    else:
        b.wait(r, 0, sem=b.sem_id(r, "done"), expected=1)
star = b.build()
check_program(star)          # it IS correct...
ring = ring_all_reduce(n, S, 1, "put")
for name, prog in [("star(custom)", star), ("ring(textbook)", ring)]:
    r = simulate(prog, fidelity="fine",
                 config=FineConfig(noc=NOC, gpu_config=GPU), unroll=4)
    print(f"  {name:15s}: {r.time_ns/1e3:9.1f} us")   # ...but slower at scale
