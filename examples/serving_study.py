"""Serving study: how traffic shape and serving topology move the tail.

Sweeps three seeded arrival processes (steady Poisson, diurnal-modulated,
bursty MMPP) over two serving topologies — continuous-batching decode on
one TP group, and disaggregated prefill/decode with per-request KV-cache
p2p transfers — and prints per-request tail latency (p50/p99/p999) and
goodput at the coarse tier, with an analytic cross-check.

The model's per-token costs are derived from a real architecture config
(the reduced llama3-8b variant), so flops, weight traffic, TP all-reduce
payloads and KV-cache sizes are all internally consistent.

Run:  PYTHONPATH=src python examples/serving_study.py
"""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.configs import get, reduced
from repro.serve import (DiurnalArrivals, MMPPArrivals, PoissonArrivals,
                         ServingModel, continuous_batching, disaggregated,
                         generate_requests)

SEED = 42
N_REQUESTS = 32

model = ServingModel.from_arch(reduced(get("llama3-8b")))
print(f"model {model.name}: {model.flops_per_token:.2e} flops/token, "
      f"{model.kv_bytes_per_token} KV bytes/token, "
      f"{model.coll_bytes_per_token} TP all-reduce bytes/token\n")

processes = [
    PoissonArrivals(2000.0),
    DiurnalArrivals(2000.0, amplitude=0.6, period_s=0.02),
    MMPPArrivals(400.0, 8000.0, mean_dwell_s=0.002),
]

header = (f"{'traffic':34s} {'topology':22s} {'p50 us':>8s} "
          f"{'p99 us':>8s} {'p999 us':>9s} {'goodput':>9s}")
print(header)
print("-" * len(header))
for proc in processes:
    reqs = generate_requests(proc, n=N_REQUESTS, seed=SEED,
                             prompt_tokens=(16, 64), decode_tokens=(4, 24))
    scenarios = [
        ("continuous tp=4", continuous_batching(model, reqs, tp=4)),
        ("disagg 2p+2d", disaggregated(model, reqs, prefill_ranks=2,
                                       decode_ranks=2)),
    ]
    for label, scen in scenarios:
        res = scen.simulate(fidelity="coarse", check="off")
        quick = scen.simulate(fidelity="analytic", check="off")
        lat = res.latency
        print(f"{proc.name:34s} {label:22s} {lat.p50_ns/1e3:8.1f} "
              f"{lat.p99_ns/1e3:8.1f} {lat.p999_ns/1e3:9.1f} "
              f"{lat.goodput_rps:7.1f}/s"
              f"   (analytic p99 {quick.latency.p99_ns/1e3:.1f} us)")
print("\nserving study OK")
