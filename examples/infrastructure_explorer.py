"""InfraGraph walkthrough (paper §4.6-4.7): define fabrics from blueprints,
visualize, translate to every backend, and compare topologies under the
same collective.

Run:  PYTHONPATH=src python examples/infrastructure_explorer.py
"""

from repro.core.backends import FineConfig, simulate
from repro.core.cluster import NocConfig
from repro.core.collectives import ring_all_reduce
from repro.core.infragraph import (clos_fat_tree_fabric, single_tier_fabric,
                                   summary, to_dot, torus2d_fabric,
                                   tpu_pod_fabric)
from repro.core.infragraph.blueprints import ring_fabric

for infra in (single_tier_fabric(8), clos_fat_tree_fabric(8, 4),
              torus2d_fabric(4, 2), tpu_pod_fabric(2, 4, 4)):
    print(summary(infra))

clos = clos_fat_tree_fabric(8, 4)
print("\nDOT preview (first lines):")
print("\n".join(to_dot(clos).splitlines()[:8]), "\n  ...")

print("\nsame 1MiB ring all-reduce, different fabrics (coarse fidelity):")
prog = lambda: ring_all_reduce(8, 1 << 20, 2, "put")
fabrics = [("single-tier", single_tier_fabric(8)),
           ("clos", clos_fat_tree_fabric(8, 4)),
           ("ring", ring_fabric(8)),
           ("torus 4x2", torus2d_fabric(4, 2))]
for name, infra in fabrics:
    r = simulate(prog(), infra, fidelity="coarse")
    print(f"  {name:12s}: {r.time_ns/1e3:9.1f} us  bus {r.bus_GBps:.2f} GB/s")

print("\nsame program, fine fidelity: InfraGraph edges wire the detailed "
      "cluster's scale-up fabric:")
small = NocConfig(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
                  io_ports=4)
small_prog = lambda: ring_all_reduce(4, 64 << 10, 1, "put")
for name, infra in [("single-tier", single_tier_fabric(4)),
                    ("ring", ring_fabric(4))]:
    r = simulate(small_prog(), infra, fidelity="fine",
                 config=FineConfig(noc=small))
    print(f"  {name:12s}: {r.time_ns/1e3:9.1f} us  {r.events} events")

# JSON round trip = the community-exchange story
text = clos.to_json()
from repro.core.infragraph import Infrastructure
again = Infrastructure.from_json(text)
assert set(again.expand().nodes) == set(clos.expand().nodes)
print("\nInfraGraph JSON round-trip OK "
      f"({len(text)} bytes describes {len(clos.expand().nodes)} nodes)")
