"""InfraGraph walkthrough (paper §4.6-4.7): define fabrics from blueprints,
visualize, translate to every backend, and compare topologies under the
same collective.

Run:  PYTHONPATH=src python examples/infrastructure_explorer.py
"""

from repro.core.collectives import ring_all_reduce
from repro.core.infragraph import (clos_fat_tree_fabric, single_tier_fabric,
                                   summary, to_dot, to_simple_topology,
                                   torus2d_fabric, tpu_pod_fabric)
from repro.core.system import simulate_collective_coarse

for infra in (single_tier_fabric(8), clos_fat_tree_fabric(8, 4),
              torus2d_fabric(4, 2), tpu_pod_fabric(2, 4, 4)):
    print(summary(infra))

clos = clos_fat_tree_fabric(8, 4)
print("\nDOT preview (first lines):")
print("\n".join(to_dot(clos).splitlines()[:8]), "\n  ...")

print("\nsame 1MiB ring all-reduce, different fabrics (coarse backend):")
prog = ring_all_reduce(8, 1 << 20, 2, "put")
for name, infra in [("single-tier", single_tier_fabric(8)),
                    ("clos", clos_fat_tree_fabric(8, 4)),
                    ("torus 4x2", torus2d_fabric(4, 2))]:
    topo = to_simple_topology(infra)
    r = simulate_collective_coarse(prog, topo=topo)
    print(f"  {name:12s}: {r.time_ns/1e3:9.1f} us  bus {r.bus_GBps:.2f} GB/s")

# JSON round trip = the community-exchange story
text = clos.to_json()
from repro.core.infragraph import Infrastructure
again = Infrastructure.from_json(text)
assert set(again.expand().nodes) == set(clos.expand().nodes)
print("\nInfraGraph JSON round-trip OK "
      f"({len(text)} bytes describes {len(clos.expand().nodes)} nodes)")
