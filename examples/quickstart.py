"""Quickstart: the two halves of the repo in one script.

1. Simulate a custom collective algorithm AND a whole training-step
   execution trace at every fidelity tier (the ASTRA-sim 3.0
   reproduction): one workload-native entry point,
   ``simulate(workload, infra, fidelity=..., config=...)``.
2. Train a reduced LM for a few steps with the JAX framework and predict
   its production step time through the simulator's roofline lens.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

# --- 1. the simulator ------------------------------------------------------
# one entry point, three fidelity tiers, one InfraGraph infrastructure:
#   simulate(workload, infra, fidelity="fine" | "coarse" | "analytic")
# where the workload is an MSCCL++ Program or a Chakra-style ExecutionTrace
from repro.core.backends import FineConfig, simulate
from repro.core.chakra import ExecutionTrace
from repro.core.collectives import direct_reduce_scatter
from repro.core.infragraph import single_tier_fabric
from repro.core.verify import check_program

prog = direct_reduce_scatter(nranks=4, shard_bytes=16384, nworkgroups=2,
                             protocol="get")
check_program(prog)                      # data-correctness proof
infra = single_tier_fabric(4)            # InfraGraph scale-up description
for fidelity in ("analytic", "coarse", "fine"):
    res = simulate(prog, infra, fidelity=fidelity)
    print(f"[sim:{fidelity:8s}] get-based RS on 4 GPUs: "
          f"{res.time_ns/1e3:9.1f} us, bus bw {res.bus_GBps:6.2f} GB/s, "
          f"{res.events} events")

# a multi-collective workload: one training step as a per-rank DAG of
# compute and communication kernels (paper §2.1/§4.3 Chakra flow) —
# the same trace runs at every tier; tier knobs ride a typed config
trace = ExecutionTrace(num_ranks=4)
fwd = {r: trace.comp(r, f"fwd.r{r}", flops=2e8, bytes_moved=1 << 20)
       for r in range(4)}
grads = trace.coll(0, "all_reduce", 1 << 18, "ring",
                   deps_by_rank={r: [fwd[r]] for r in range(4)})
for r in range(4):
    trace.comp(r, f"opt.r{r}", flops=5e7, deps=[grads[r]])
for fidelity in ("analytic", "coarse", "fine"):
    cfg = FineConfig(coll_workgroups=2) if fidelity == "fine" else None
    res = simulate(trace, infra, fidelity=fidelity, config=cfg)
    print(f"[trace:{fidelity:8s}] 1 training step on 4 GPUs: "
          f"{res.time_ns/1e3:9.1f} us, {res.events} events")

# --- verifying a custom collective ------------------------------------------
# Before a sweep burns hours simulating a hand-written algorithm, prove it
# can't hang or corrupt data.  The static checker runs with no execution at
# all: deadlock (semaphore counting + wait-for cycles), data races
# (unordered overlapping byte ranges), buffer bounds, and output coverage.
# It is wired into simulate() (check="warn" by default, "error" to fail
# fast, "off" to skip) and available standalone:
from repro.core.check import check_workload

report = check_workload(prog, infra)
assert report.clean, report.format()
print(f"[check] {prog.name}: statically verified "
      f"({report.format().splitlines()[0].split(': ')[1]})")

# a seeded bug shows what a diagnostic looks like: truncate one put of an
# all_gather and the checker pins the uncovered output interval to a
# (rank, wg, op) cursor
from repro.core.collectives import ring_all_gather

broken = ring_all_gather(nranks=4, shard_bytes=16384, nworkgroups=1,
                         protocol="put")
for op in broken.gpus[0][0]:
    if op.op == "put":
        op.size //= 2
        break
bad = check_workload(broken)
print(f"[check] seeded truncation -> {bad.errors[0].rule} at "
      f"{bad.errors[0].loc}")
# the same checks run from the shell over program/trace/infra JSON files:
#   python -m repro.check prog.json trace.json --json
#   python -m repro.check --collectives      # verify every builtin

# --- simulate a serving scenario --------------------------------------------
# Latency-sensitive inference is the paper's headline motivation: compose a
# seeded arrival process with a scenario builder and the result is a plain
# ExecutionTrace — same simulate(), every tier — whose result carries
# per-request tail latency extracted from request-tagged nodes.
from repro.serve import (PoissonArrivals, ServingModel, continuous_batching,
                         generate_requests)

requests = generate_requests(PoissonArrivals(2000.0), n=16, seed=7,
                             prompt_tokens=(16, 64), decode_tokens=(4, 16))
model = ServingModel("demo", flops_per_token=2e6, weight_bytes=1e6,
                     coll_bytes_per_token=4096, kv_bytes_per_token=2048)
scenario = continuous_batching(model, requests, tp=4)
for fidelity in ("analytic", "coarse"):
    res = scenario.simulate(infra, fidelity=fidelity)
    lat = res.latency
    print(f"[serve:{fidelity:8s}] {lat.count} requests: "
          f"p50 {lat.p50_ns/1e3:7.1f} us, p99 {lat.p99_ns/1e3:7.1f} us, "
          f"goodput {lat.goodput_rps:7.1f} req/s")

# --- running a DSE sweep ----------------------------------------------------
# Design-space exploration at scale: declare a typed grid once and the
# sweep harness expands it, shards points across worker processes
# (``jobs=N``; a crashed or hung worker fails one point, never the run),
# caches every result under a canonical content hash (rerunning recomputes
# only changed points), and tier-escalates — the cheap analytic tier
# prefilters the full grid, the expensive tier runs only on the frontier.
from repro.sweep import (Escalation, PointSpec, SweepSpec, register_sweep,
                         run_sweep)


def _dse_build(coords, tier):
    prog = ring_all_gather(nranks=4, shard_bytes=coords["shard_KiB"] * 1024,
                           nworkgroups=1, protocol=coords["protocol"])
    return PointSpec(workload=prog,
                     infra=single_tier_fabric(4,
                                              link_GBps=coords["link_GBps"]))


dse = register_sweep(SweepSpec(
    name="quickstart_dse",
    axes={"protocol": ("put", "get"),
          "shard_KiB": (4, 16),
          "link_GBps": (50.0, 200.0)},
    build=_dse_build,
    escalate=Escalation(prefilter="analytic", final="coarse", mode="top_k",
                        k=2, objectives=("min:time_ns",)),
))
res = run_sweep(dse, jobs=0, fresh=True, progress=False)
best = min((r for r in res.ok if r["tier"] == "coarse"),
           key=lambda r: r["time_ns"])
print(f"[sweep] {len(res.rows)} rows ({res.counts()}), best escalated "
      f"point {best['point']} -> {best['time_ns']/1e3:.1f} us; "
      f"JSONL at {res.out_path}")
# the same study from the shell, 4 workers, resumable via the cache:
#   python -m repro.sweep quickstart_dse --jobs 4
#   python -m repro.sweep --list          # every registered sweep

# --- 2. the framework -------------------------------------------------------
from repro.configs import ShapeConfig, get, reduced
from repro.models import api
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step

cfg = reduced(get("llama3-8b"))
shape = ShapeConfig("demo", seq_len=64, global_batch=4, kind="train")
state = init_train_state(jax.random.PRNGKey(0), cfg)
step = jax.jit(make_train_step(cfg, AdamWConfig(lr=2e-3)))
batch = {k: jnp.asarray(v) for k, v in api.make_batch(cfg, shape).items()}
for i in range(5):
    state, m = step(state, batch)
    print(f"[train] step {i} loss {float(m['loss']):.4f}")
print("quickstart OK")
