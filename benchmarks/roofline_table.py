"""Deliverable (g): format the dry-run sweep into the roofline table
(EXPERIMENTS.md §Roofline) — three terms, dominant bottleneck, useful-flop
ratio, and a one-line 'what would move the dominant term' note."""

from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.sweep import register_suite

from .common import Report

NOTES = {
    ("memory", "train"): "fuse attention/WKV inner loops (Pallas kernels "
                         "keep block intermediates in VMEM)",
    ("memory", "prefill"): "same as train: kernel-fused attention removes "
                           "block-intermediate HBM round trips",
    ("memory", "decode"): "batch more requests per step / quantize KV cache",
    ("collective", "train"): "reshard: pure-FSDP (drop TP all-reduces) or "
                             "overlap grad reduce-scatter with backward",
    ("collective", "prefill"): "shard KV heads (duplicate GQA heads) / "
                               "overlap layer all-gathers with compute",
    ("collective", "decode"): "keep cache shards stationary (avoid "
                              "resharding on update); smaller TP group",
    ("compute", "train"): "tighter remat policy (save attention outputs)",
    ("compute", "prefill"): "larger per-chip batch",
    ("compute", "decode"): "speculative decoding / wider batch",
}


def load(path: str) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


@register_suite("roofline_table")
def run(single="results/dryrun_single_pod.json") -> str:
    if not os.path.exists(single):
        print("roofline_table,0,skipped(no dryrun results)")
        return "skipped"
    rows = load(single)
    rep = Report("roofline_table")
    n_ok = 0
    worst = (1.0, "")
    for r in rows:
        if r["status"] != "ok":
            rep.add(arch=r["arch"], shape=r["shape"], status=r["status"])
            continue
        n_ok += 1
        rf = r["roofline"]
        from repro.configs.base import SHAPES
        kind = SHAPES[r["shape"]].kind
        note = NOTES.get((rf["dominant"], kind), "")
        frac = rf["roofline_fraction"]
        if kind != "decode" and frac < worst[0]:
            worst = (frac, f"{r['arch']}/{r['shape']}")
        rep.add(arch=r["arch"], shape=r["shape"],
                compute_s=round(rf["compute_s"], 3),
                memory_s=round(rf["memory_s"], 3),
                collective_s=round(rf["collective_s"], 3),
                dominant=rf["dominant"],
                model_flops=rf["model_flops_global"],
                useful_flop_ratio=round(rf["useful_flop_ratio"], 3),
                roofline_fraction=round(frac, 4),
                next_action=note)
    derived = f"cells_ok={n_ok};worst_train_fraction={worst[0]:.4f}@{worst[1]}"
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
