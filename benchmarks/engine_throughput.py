"""Event-engine throughput benchmark (tracked PR-over-PR).

Runs the reference workload — a fine-grained 8-rank 1 MiB ring all-reduce
on the default NoC — through the three fabric scheduling modes:

* ``classic``  — the seed's two-events-per-hop reference implementation;
* ``exact``    — one event per hop + sound lookahead chaining;
* ``coalesce`` — ``exact`` + train coalescing (the default).

Asserts that the fast paths are bit-exact against each other and FIFO-
certified (``order_violations == 0``), then writes ``results/
BENCH_engine.json`` with events, wall time, events/s and simulated-ns per
wall-second so the perf trajectory is visible across PRs.

Run:  PYTHONPATH=src python benchmarks/engine_throughput.py [--quick]
      [--profile]   (cProfile the default-mode run, print top 25 by cumtime)
"""

from __future__ import annotations

import json
import os
import statistics
import sys
import time

# pin JAX to the CPU backend before anything imports it (as test_system
# does): on the bench boxes accelerator-plugin probing — not compute —
# costs upwards of 400 s and masquerades as a hang
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import collectives as C                        # noqa: E402
from repro.core.backends import simulate                       # noqa: E402
from repro.core.cluster import Cluster, NocConfig              # noqa: E402
from repro.sweep import (SweepSpec, payload,                   # noqa: E402
                         register_suite, register_sweep, run_sweep)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

NRANKS = 8
SIZE = 1 << 20          # 1 MiB
NWG = 1
PROTOCOL = "put"

#: the scheduling-mode grid (name -> run_mode arguments); declared as
#: explicit sweep points so the suite and main() drive the same spec
MODE_POINTS = (
    {"name": "classic", "mode": "classic", "bulk": "on", "ledger": "on"},
    {"name": "exact", "mode": "exact", "bulk": "on", "ledger": "on"},
    {"name": "coalesce", "mode": "coalesce", "bulk": "on", "ledger": "on"},
    {"name": "coalesce_bulk_off", "mode": "coalesce", "bulk": "off",
     "ledger": "on"},
    {"name": "coalesce_ledger_off", "mode": "coalesce", "bulk": "on",
     "ledger": "off"},
    {"name": "coalesce_ledger_auto", "mode": "coalesce", "bulk": "on",
     "ledger": "auto"},
    {"name": "exact_ledger_off", "mode": "exact", "bulk": "on",
     "ledger": "off"},
)

#: seed baseline on this workload (measured at the fast-path PR; the seed
#: predates BENCH_engine.json, so its numbers are pinned here once)
SEED_BASELINE = {"events": 9_864_416, "wall_s": 23.32}


#: wall-clock trials per mode; min and median are both reported (the CI
#: boxes run shared-CPU, so single samples swing by 30% — the median is
#: what the smoke test gates on; sim results are identical across trials
#: and asserted so)
WALL_TRIALS = 3


def run_mode(mode: str, size: int, bulk: str = "on", ledger: str = "on"):
    walls = []
    sims = set()
    for _ in range(WALL_TRIALS):
        cluster = Cluster(NRANKS, noc=NocConfig(fabric_mode=mode,
                                                bulk_emission=bulk,
                                                fabric_ledger=ledger))
        t0 = time.perf_counter()
        r = simulate(C.ring_all_reduce(NRANKS, size, NWG, PROTOCOL),
                     fidelity="fine", cluster=cluster, check="off")
        walls.append(time.perf_counter() - t0)
        sims.add((r.time_ns, r.events, cluster.fabric.order_violations))
    assert len(sims) == 1, f"trials disagree on sim results: {sims}"
    wall = min(walls)
    med = statistics.median(walls)
    return {
        "mode": mode,
        "bulk_emission": bulk,
        "fabric_ledger": ledger,
        "time_ns": r.time_ns,
        "per_rank_done_ns": r.per_rank_done_ns,
        "events": r.events,
        "wall_s": round(wall, 3),
        "wall_median_s": round(med, 3),
        "wall_stddev_s": round(statistics.stdev(walls), 3)
        if len(walls) > 1 else 0.0,
        "wall_trials": WALL_TRIALS,
        "events_per_s": round(r.events / wall) if wall > 0 else None,
        "sim_ns_per_wall_s": round(r.time_ns / wall) if wall > 0 else None,
        "order_violations": cluster.fabric.order_violations,
        "ledger": cluster.fabric.ledger_counters(),
    }


def _run_point(coords: dict, tier: str) -> dict:
    return run_mode(coords["mode"], coords["size"], bulk=coords["bulk"],
                    ledger=coords["ledger"])


SWEEP = register_sweep(SweepSpec(
    name="engine_throughput",
    points=[dict(p, size=SIZE) for p in MODE_POINTS],
    run_point=_run_point,
))


def measure(size: int, jobs: int = 0) -> dict:
    """All mode rows at ``size``, via the sweep runner (inline by default
    so wall-clock numbers are unperturbed by process scheduling)."""
    pts = [dict(p, size=size) for p in MODE_POINTS]
    res = run_sweep(SWEEP, jobs=jobs, fresh=True, progress=False,
                    out=os.path.join(RESULTS, "sweeps",
                                     "engine_throughput.jsonl"),
                    points=pts)
    assert not res.failed, res.failed[0]
    return {r["point"]["name"]: payload(r) for r in res.rows}


def check_rows(rows: dict) -> None:
    """Cross-mode correctness gates (bit-exactness + FIFO certification)."""
    exact, coal, classic = rows["exact"], rows["coalesce"], rows["classic"]
    nobulk = rows["coalesce_bulk_off"]
    noled, noled_ex = rows["coalesce_ledger_off"], rows["exact_ledger_off"]
    assert coal["time_ns"] == exact["time_ns"], \
        "coalesced result must be bit-exact vs the un-coalesced path"
    assert coal["per_rank_done_ns"] == exact["per_rank_done_ns"]
    assert coal["order_violations"] == 0, \
        "FIFO monitor must certify the coalesced run"
    assert classic["time_ns"] == exact["time_ns"], \
        "fast path must reproduce the reference schedule"
    assert nobulk["time_ns"] == coal["time_ns"], \
        "bulk wavefront emission must be timing-neutral"
    assert nobulk["per_rank_done_ns"] == coal["per_rank_done_ns"]
    assert nobulk["order_violations"] == 0
    assert noled["time_ns"] == coal["time_ns"] \
        and noled_ex["time_ns"] == coal["time_ns"], \
        "reservation ledgers must be timing-neutral"
    assert noled["per_rank_done_ns"] == coal["per_rank_done_ns"]
    assert noled["order_violations"] == 0 and noled_ex["order_violations"] == 0
    auto = rows["coalesce_ledger_auto"]
    assert auto["time_ns"] == coal["time_ns"], \
        "the adaptive per-link probe policy must be timing-neutral"
    assert auto["per_rank_done_ns"] == coal["per_rank_done_ns"]
    assert auto["order_violations"] == 0
    assert coal["events"] < noled["events"], \
        "ledger chaining must strictly reduce heap events"


@register_suite("engine_throughput")
def suite() -> dict:
    """Quick-size engine run for the benchmark driver: same modes, same
    gates, 1/8th buffer; writes an *untracked* report so the committed
    BENCH_engine baselines stay pristine."""
    rows = measure(SIZE // 8)
    check_rows(rows)
    out = {
        "workload": {"collective": "ring_all_reduce", "nranks": NRANKS,
                     "size_bytes": SIZE // 8, "nworkgroups": NWG,
                     "protocol": PROTOCOL, "noc": "default"},
        "modes": {m: {k: v for k, v in row.items()
                      if k != "per_rank_done_ns"}
                  for m, row in rows.items()},
    }
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "engine_throughput_suite.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    coal = rows["coalesce"]
    print(f"engine_throughput,{coal['wall_s'] * 1e6:.0f},"
          f"events={coal['events']}")
    return out


def profile_run(size: int) -> None:
    """cProfile one default-mode simulation; print the top 25 by cumtime."""
    import cProfile
    import pstats

    cluster = Cluster(NRANKS, noc=NocConfig())
    wl = C.ring_all_reduce(NRANKS, size, NWG, PROTOCOL)
    prof = cProfile.Profile()
    prof.enable()
    simulate(wl, fidelity="fine", cluster=cluster, check="off")
    prof.disable()
    pstats.Stats(prof).sort_stats("cumulative").print_stats(25)
    print(json.dumps(cluster.fabric.ledger_counters(), indent=1))


def main() -> None:
    size = SIZE if "--quick" not in sys.argv else SIZE // 8
    if "--profile" in sys.argv:
        profile_run(size)
        return
    rows = measure(size)
    check_rows(rows)
    classic, coal = rows["classic"], rows["coalesce"]

    out = {
        "workload": {"collective": "ring_all_reduce", "nranks": NRANKS,
                     "size_bytes": size, "nworkgroups": NWG,
                     "protocol": PROTOCOL, "noc": "default"},
        "modes": {m: {k: v for k, v in row.items()
                      if k != "per_rank_done_ns"}
                  for m, row in rows.items()},
        "event_ratio_vs_classic": round(classic["events"] / coal["events"], 2),
        "wall_speedup_vs_classic": round(classic["wall_s"] / coal["wall_s"], 2),
    }
    if size == SIZE:
        out["seed_baseline"] = SEED_BASELINE
        out["event_ratio_vs_seed"] = round(
            SEED_BASELINE["events"] / coal["events"], 2)
        out["wall_speedup_vs_seed"] = round(
            SEED_BASELINE["wall_s"] / coal["wall_s"], 2)

    os.makedirs(RESULTS, exist_ok=True)
    # --quick runs must not clobber the committed full-size baseline (the
    # bench smoke test compares against it)
    name = "BENCH_engine.json" if size == SIZE else "BENCH_engine_quick.json"
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
