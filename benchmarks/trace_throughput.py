"""Trace-workload throughput benchmark (tracked PR-over-PR).

Runs the reference *multi-collective* workload — a 2-step training loop on
8 ranks (fwd comp -> ring all-reduce of gradients -> optimizer comp,
chained across steps) — through ``simulate(trace, infra, fidelity=...)``
at all three fidelity tiers, and writes ``results/BENCH_trace.json`` with
one row per tier (time_ns, events, wall) so the workload seam's perf and
determinism are visible across PRs.

Determinism gates: per-tier results are identical across wall trials, the
fine tier stays FIFO-certified, and every tier respects the trace's
dependency order.

Run:  PYTHONPATH=src python benchmarks/trace_throughput.py
"""

from __future__ import annotations

import json
import os
import sys
import time

# pin JAX to the CPU backend before anything imports it (bench-box rule:
# accelerator-plugin probing costs >400 s and masquerades as a hang)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.backends import FineConfig, simulate          # noqa: E402
from repro.core.chakra import ExecutionTrace                  # noqa: E402
from repro.sweep import (SweepSpec, payload,                  # noqa: E402
                         register_suite, register_sweep, run_sweep)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

NRANKS = 8
STEPS = 2
GRAD_BYTES = 1 << 16          # 64 KiB per-rank gradient shard
FWD_FLOPS = 2e8
OPT_FLOPS = 5e7
COLL_WGS = 2

#: wall-clock trials per tier; minimum reported (shared-CPU bench boxes)
WALL_TRIALS = 2


def training_loop_trace(nranks: int = NRANKS, steps: int = STEPS,
                        grad_bytes: int = GRAD_BYTES,
                        fwd_flops: float = FWD_FLOPS,
                        opt_flops: float = OPT_FLOPS) -> ExecutionTrace:
    """The tracked trace: a small data-parallel training loop."""
    et = ExecutionTrace(num_ranks=nranks)
    prev = {r: None for r in range(nranks)}
    for s in range(steps):
        fwd = {r: et.comp(r, f"fwd{s}.r{r}", flops=fwd_flops,
                          bytes_moved=grad_bytes,
                          deps=[prev[r]] if prev[r] else None)
               for r in range(nranks)}
        ar = et.coll(s, "all_reduce", grad_bytes, "ring",
                     deps_by_rank={r: [fwd[r]] for r in range(nranks)})
        prev = {r: et.comp(r, f"opt{s}.r{r}", flops=opt_flops, deps=[ar[r]])
                for r in range(nranks)}
    return et


def run_tier(fidelity: str) -> dict:
    wall = None
    sims = set()
    for _ in range(WALL_TRIALS):
        trace = training_loop_trace()
        cfg = FineConfig(coll_workgroups=COLL_WGS) if fidelity == "fine" \
            else None
        t0 = time.perf_counter()
        r = simulate(trace, fidelity=fidelity, config=cfg)
        trial = time.perf_counter() - t0
        wall = trial if wall is None else min(wall, trial)
        # dependency order must hold at every tier
        for n in trace.nodes:
            for d in n.deps:
                assert r.node_times[n.nid][0] >= r.node_times[d][1] - 1e-9, \
                    f"{fidelity}: node {n.nid} ran before dep {d}"
        sims.add((r.time_ns, r.events, tuple(r.per_rank_done_ns)))
    assert len(sims) == 1, f"{fidelity} trials disagree: {sims}"
    return {
        "fidelity": fidelity,
        "time_ns": r.time_ns,
        "per_rank_done_ns": r.per_rank_done_ns,
        "events": r.events,
        "wall_s": round(wall, 3),
        "wall_trials": WALL_TRIALS,
        "events_per_s": round(r.events / wall) if wall > 0 else None,
        "sim_ns_per_wall_s": round(r.time_ns / wall) if wall > 0 else None,
    }


def _run_point(coords: dict, tier: str) -> dict:
    return run_tier(tier)


SWEEP = register_sweep(SweepSpec(
    name="trace_throughput",
    points=[{}],
    run_point=_run_point,
    tiers=("analytic", "coarse", "fine"),
))


@register_suite("trace_throughput")
def suite() -> dict:
    """Driver-facing run: same tiers and gates via the sweep runner;
    writes an *untracked* report so the committed BENCH_trace baseline
    stays pristine."""
    res = run_sweep(SWEEP, jobs=0, fresh=True, progress=False,
                    out=os.path.join(RESULTS, "sweeps",
                                     "trace_throughput.jsonl"))
    assert not res.failed, res.failed[0]
    rows = {r["tier"]: payload(r) for r in res.rows}
    assert rows["analytic"]["events"] <= rows["coarse"]["events"] \
        < rows["fine"]["events"], "fidelity must buy event detail"
    out = {"tiers": {fid: {k: v for k, v in row.items()
                           if k != "per_rank_done_ns"}
                     for fid, row in rows.items()}}
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "trace_throughput_suite.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    fine = rows["fine"]
    print(f"trace_throughput,{fine['wall_s'] * 1e6:.0f},"
          f"events={fine['events']}")
    return out


def main() -> None:
    rows = {fid: run_tier(fid) for fid in ("analytic", "coarse", "fine")}
    assert rows["analytic"]["events"] <= rows["coarse"]["events"] \
        < rows["fine"]["events"], "fidelity must buy event detail"
    out = {
        "workload": {"kind": "training_loop_trace", "nranks": NRANKS,
                     "steps": STEPS, "grad_bytes": GRAD_BYTES,
                     "fwd_flops": FWD_FLOPS, "opt_flops": OPT_FLOPS,
                     "coll_workgroups": COLL_WGS, "noc": "default"},
        "tiers": {fid: {k: v for k, v in row.items()
                        if k != "per_rank_done_ns"}
                  for fid, row in rows.items()},
    }
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_trace.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
