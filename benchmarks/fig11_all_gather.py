"""Paper Fig. 11: get- vs put-based All-Gather with and without fair
arbitration between control and data messages.

Paper insight: AG has no reduction, so get loses its overlap advantage;
worse, get's control requests get stuck behind data responses under FIFO
links.  Fair arbitration narrows the gap."""

from __future__ import annotations

from repro.core.backends import FineConfig, simulate
from repro.core.collectives import direct_all_gather

from .common import Report, fast_gpu, small_noc

KiB = 1 << 10


def run(nranks: int = 8, nwg: int = 4,
        sizes=(32 * KiB, 128 * KiB, 256 * KiB)) -> str:
    rep = Report("fig11_all_gather")
    last = {}
    for size in sizes:
        row = {"shard_KiB": size // KiB}
        for proto in ("put", "get"):
            for arb in ("fifo", "fair"):
                prog = direct_all_gather(nranks, size, nwg, proto)
                gc = fast_gpu(max_outstanding=128, unroll=16)
                r = simulate(prog, fidelity="fine",
                             config=FineConfig(noc=small_noc(arb),
                                               gpu_config=gc),
                             unroll=16, check="off")
                row[f"bw_{proto}_{arb}_GBps"] = round(r.bus_GBps, 3)
        rep.add(**row)
        last = row
    put_over_get = last["bw_put_fifo_GBps"] / last["bw_get_fifo_GBps"]
    fair_recovery = last["bw_get_fair_GBps"] / last["bw_get_fifo_GBps"]
    derived = (f"put_over_get_fifo={put_over_get:.2f}x;"
               f"fair_arbitration_gain_get={fair_recovery:.2f}x")
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
