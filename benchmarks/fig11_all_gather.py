"""Paper Fig. 11: get- vs put-based All-Gather with and without fair
arbitration between control and data messages.

Paper insight: AG has no reduction, so get loses its overlap advantage;
worse, get's control requests get stuck behind data responses under FIFO
links.  Fair arbitration narrows the gap.

Declared as a 3-axis SweepSpec (shard size x protocol x arbitration) and
executed through the sweep runner."""

from __future__ import annotations

from repro.core.backends import FineConfig
from repro.core.collectives import direct_all_gather
from repro.sweep import PointSpec, SweepSpec, register_suite, register_sweep

from .common import Report, fast_gpu, small_noc, sweep_rows

KiB = 1 << 10

NRANKS = 8
NWG = 4
SIZES_KIB = (32, 128, 256)


def _build(coords: dict, tier: str) -> PointSpec:
    prog = direct_all_gather(NRANKS, coords["shard_KiB"] * KiB, NWG,
                             coords["protocol"])
    gc = fast_gpu(max_outstanding=128, unroll=16)
    return PointSpec(workload=prog,
                     config=FineConfig(noc=small_noc(coords["arbitration"]),
                                       gpu_config=gc),
                     run_kw={"unroll": 16},
                     metrics=lambda r: {"bus_GBps": r.bus_GBps})


SWEEP = register_sweep(SweepSpec(
    name="fig11_all_gather",
    axes={"shard_KiB": SIZES_KIB, "protocol": ("put", "get"),
          "arbitration": ("fifo", "fair")},
    build=_build,
))


@register_suite("fig11_all_gather")
def run() -> str:
    rep = Report("fig11_all_gather")
    rows = {(r["point"]["shard_KiB"], r["point"]["protocol"],
             r["point"]["arbitration"]): r for r in sweep_rows(SWEEP)}
    last = {}
    for size_kib in SIZES_KIB:
        row = {"shard_KiB": size_kib}
        for proto in ("put", "get"):
            for arb in ("fifo", "fair"):
                r = rows[(size_kib, proto, arb)]
                row[f"bw_{proto}_{arb}_GBps"] = round(r["bus_GBps"], 3)
        rep.add(**row)
        last = row
    put_over_get = last["bw_put_fifo_GBps"] / last["bw_get_fifo_GBps"]
    fair_recovery = last["bw_get_fair_GBps"] / last["bw_get_fifo_GBps"]
    derived = (f"put_over_get_fifo={put_over_get:.2f}x;"
               f"fair_arbitration_gain_get={fair_recovery:.2f}x")
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
