"""Step-time prediction benchmark: the HLO -> ET -> simulator pipeline
(paper's end-to-end flow, §4.3, applied to our own framework's compiled
cells).  Closed-form bounds for every cell; fine-grained contention sim
for three representative cells."""

from __future__ import annotations

import json
import os

from repro.analysis.predict import predict_cell, simulate_cell_fine
from repro.sweep import register_suite

from .common import Report

FINE_CELLS = [("llama3-8b", "train_4k"), ("grok-1-314b", "train_4k"),
              ("llama3-8b", "decode_32k")]


@register_suite("step_prediction")
def run(path="results/dryrun_single_pod.json") -> str:
    if not os.path.exists(path):
        print("step_prediction,0,skipped(no dryrun results)")
        return "skipped"
    cells = json.load(open(path))
    rep = Report("step_prediction")
    fine_done = 0
    for cell in cells:
        if cell["status"] != "ok":
            continue
        pred = predict_cell(cell)
        row = {"arch": cell["arch"], "shape": cell["shape"],
               **{k: round(v, 4) for k, v in pred.items()}}
        if (cell["arch"], cell["shape"]) in FINE_CELLS:
            fine = simulate_cell_fine(cell)
            row.update({k: (round(v, 4) if isinstance(v, float) else v)
                        for k, v in fine.items()})
            fine_done += 1
        rep.add(**row)
    derived = f"cells={len(rep.rows)};fine_sims={fine_done}"
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
