"""Beyond-paper: quantify what fidelity buys — the same MSCCL++ program
simulated at ASTRA-sim 2.0 granularity (chunk alpha-beta) vs 3.0
granularity (Load-Store + NoC + CU contention).  The gap IS the paper's
motivation (control path, contention, per-line latency are invisible to
the coarse model)."""

from __future__ import annotations

from repro.core.backends import FineConfig, simulate
from repro.core.collectives import (direct_all_gather,
                                    direct_reduce_scatter, ring_all_reduce)

from .common import Report, fast_gpu, small_noc

KiB = 1 << 10


def run(nranks: int = 8, size: int = 64 * KiB) -> str:
    rep = Report("fidelity_compare")
    gaps = {}
    for name, prog_fn in [
        ("ring_all_reduce", lambda: ring_all_reduce(nranks, size, 2, "put")),
        ("direct_rs_get", lambda: direct_reduce_scatter(nranks, size, 2,
                                                        "get")),
        ("direct_ag_put", lambda: direct_all_gather(nranks, size, 2, "put")),
    ]:
        fine = simulate(prog_fn(), fidelity="fine",
                        config=FineConfig(noc=small_noc(),
                                          gpu_config=fast_gpu()),
                        unroll=8, check="off")
        coarse = simulate(prog_fn(), fidelity="coarse", check="off")
        gap = fine.time_ns / coarse.time_ns
        gaps[name] = gap
        rep.add(program=name, fine_us=round(fine.time_ns / 1e3, 1),
                coarse_us=round(coarse.time_ns / 1e3, 1),
                fidelity_gap=round(gap, 2),
                fine_events=fine.events, coarse_events=coarse.events)
    derived = ";".join(f"{k}={v:.2f}x" for k, v in gaps.items())
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
