"""Beyond-paper: quantify what fidelity buys — the same MSCCL++ program
simulated at ASTRA-sim 2.0 granularity (chunk alpha-beta) vs 3.0
granularity (Load-Store + NoC + CU contention).  The gap IS the paper's
motivation (control path, contention, per-line latency are invisible to
the coarse model).

Declared as a multi-tier SweepSpec: one ``program`` axis, run at both the
fine and coarse tiers by the sweep runner; ``run()`` pairs the rows up to
compute the per-program fidelity gap."""

from __future__ import annotations

from repro.core.backends import FineConfig
from repro.core.collectives import (direct_all_gather,
                                    direct_reduce_scatter, ring_all_reduce)
from repro.sweep import PointSpec, SweepSpec, register_suite, register_sweep

from .common import Report, fast_gpu, small_noc, sweep_rows

KiB = 1 << 10

NRANKS = 8
SIZE = 64 * KiB

PROGRAMS = ("ring_all_reduce", "direct_rs_get", "direct_ag_put")


def _program(name: str):
    if name == "ring_all_reduce":
        return ring_all_reduce(NRANKS, SIZE, 2, "put")
    if name == "direct_rs_get":
        return direct_reduce_scatter(NRANKS, SIZE, 2, "get")
    if name == "direct_ag_put":
        return direct_all_gather(NRANKS, SIZE, 2, "put")
    raise ValueError(f"unknown program {name!r}")


def _build(coords: dict, tier: str) -> PointSpec:
    prog = _program(coords["program"])
    if tier == "fine":
        return PointSpec(workload=prog,
                         config=FineConfig(noc=small_noc(),
                                           gpu_config=fast_gpu()),
                         run_kw={"unroll": 8})
    return PointSpec(workload=prog)


SWEEP = register_sweep(SweepSpec(
    name="fidelity_compare",
    axes={"program": PROGRAMS},
    build=_build,
    tiers=("fine", "coarse"),
))


@register_suite("fidelity_compare")
def run() -> str:
    rep = Report("fidelity_compare")
    rows = {(r["point"]["program"], r["tier"]): r for r in sweep_rows(SWEEP)}
    gaps = {}
    for name in PROGRAMS:
        fine, coarse = rows[(name, "fine")], rows[(name, "coarse")]
        gap = fine["time_ns"] / coarse["time_ns"]
        gaps[name] = gap
        rep.add(program=name, fine_us=round(fine["time_ns"] / 1e3, 1),
                coarse_us=round(coarse["time_ns"] / 1e3, 1),
                fidelity_gap=round(gap, 2),
                fine_events=fine["events"], coarse_events=coarse["events"])
    derived = ";".join(f"{k}={v:.2f}x" for k, v in gaps.items())
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
