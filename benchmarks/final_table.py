"""Emit the final (post-§Perf) roofline table as markdown for
EXPERIMENTS.md, merging single-pod, multi-pod and hillclimb-plan cells."""

from __future__ import annotations

import glob
import json


def fmt(results, title):
    out = [f"### {title}", "",
           "| arch | shape | plan | compute_s | memory_s | mem_adj_s | "
           "coll_s | dominant | useful | roofline% | adj% |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in results:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        frac = rf["roofline_fraction"]
        adj = rf.get("roofline_fraction_adjusted", frac)
        kind_decode = r["shape"].startswith(("decode", "long"))
        f1 = "—" if kind_decode else f"{100 * frac:.2f}"
        f2 = "—" if kind_decode else f"{100 * adj:.2f}"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('plan', 'default')} | "
            f"{rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf.get('memory_adjusted_s', rf['memory_s']):.3f} | "
            f"{rf['collective_s']:.3f} | {rf['dominant']} | "
            f"{rf['useful_flop_ratio']:.3f} | {f1} | {f2} |")
    return "\n".join(out)


def main():
    single = json.load(open("results/dryrun_single_pod.json"))
    print(fmt(single, "Final single-pod (16×16), plan=default"))
    print()
    hill = []
    for f in sorted(glob.glob("results/hillclimb_*.json")):
        hill.extend(json.load(open(f)))
    if hill:
        print(fmt(hill, "Hillclimb plan variants (beyond-paper)"))
        print()
    multi = json.load(open("results/dryrun_multi_pod.json"))
    ok = sum(1 for r in multi if r.get("status") == "ok")
    print(f"### Multi-pod (2×16×16 = 512 chips): {ok}/32 cells compiled OK "
          f"(full terms in results/dryrun_multi_pod.json)")
    # brief summary of multi-pod deltas
    sp = {(r["arch"], r["shape"]): r for r in single
          if r.get("status") == "ok"}
    rows = []
    for r in multi:
        if r.get("status") != "ok":
            continue
        k = (r["arch"], r["shape"])
        if k in sp:
            d = r["roofline"]["collective_s"] - \
                sp[k]["roofline"]["collective_s"]
            rows.append((k, d))
    worst = sorted(rows, key=lambda t: -abs(t[1]))[:3]
    for (a, s), d in worst:
        print(f"  - largest cross-pod collective delta: {a}/{s}: "
              f"{d:+.3f} s (pod-DP gradient reduce over DCN)")


if __name__ == "__main__":
    main()
