"""Paper Fig. 4: LL vs Simple protocol transfer bandwidth under different
assumed link latencies/bandwidths — why latency fidelity decides protocol
choice."""

from __future__ import annotations

from repro.core.protocols import ProtocolModel
from repro.sweep import register_suite

from .common import Report

GiB = 1 << 30


@register_suite("fig4_protocols")
def run() -> str:
    rep = Report("fig4_protocols")
    sizes = [1 << s for s in range(10, 26)]     # 1 KiB .. 32 MiB
    cases = [
        ("a=0.5us,b=256GiB/s", 500.0, 256 * 1.0737),
        ("a=5us,b=256GiB/s", 5000.0, 256 * 1.0737),
        ("a=0.5us,b=1TiB/s", 500.0, 1024 * 1.0737),
        ("a=5us,b=1TiB/s", 5000.0, 1024 * 1.0737),
    ]
    crossovers = {}
    for name, alpha, beta in cases:
        m = ProtocolModel(alpha_ns=alpha, beta_GBps=beta)
        for s in sizes:
            rep.add(case=name, size=s,
                    bw_ll_GBps=round(m.bw_ll_GBps(s), 2),
                    bw_simple_GBps=round(m.bw_simple_GBps(s), 2))
        crossovers[name] = m.crossover_pow2_bytes()
    # the paper's qualitative claims
    assert crossovers["a=5us,b=256GiB/s"] > crossovers["a=0.5us,b=256GiB/s"]
    assert crossovers["a=5us,b=1TiB/s"] > crossovers["a=5us,b=256GiB/s"]
    derived = ";".join(f"{k}:xover={v >> 10}KiB" for k, v in
                       crossovers.items())
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
