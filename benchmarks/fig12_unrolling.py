"""Paper Fig. 12: All-to-All bandwidth vs loop-unrolling factor
(intra-wavefront ILP).  Expected: more in-flight Wavefront Requests help
bandwidth-bound sizes, with saturation; no effect on tiny latency-bound
transfers.

Declared as a SweepSpec (shard size x unroll factor) and executed through
the sweep runner."""

from __future__ import annotations

from repro.core.backends import FineConfig
from repro.core.collectives import direct_all_to_all
from repro.sweep import PointSpec, SweepSpec, register_suite, register_sweep

from .common import Report, fast_gpu, small_noc, sweep_rows

KiB = 1 << 10

NRANKS = 8
NWG = 4
SIZES_KIB = (4, 64)
UNROLLS = (1, 2, 4, 8, 16)


def _build(coords: dict, tier: str) -> PointSpec:
    prog = direct_all_to_all(NRANKS, coords["shard_KiB"] * KiB, NWG, "put")
    return PointSpec(workload=prog,
                     config=FineConfig(noc=small_noc(),
                                       gpu_config=fast_gpu()),
                     run_kw={"unroll": coords["unroll"]},
                     metrics=lambda r: {"bus_GBps": r.bus_GBps})


SWEEP = register_sweep(SweepSpec(
    name="fig12_unrolling",
    axes={"shard_KiB": SIZES_KIB, "unroll": UNROLLS},
    build=_build,
))


@register_suite("fig12_unrolling")
def run() -> str:
    rep = Report("fig12_unrolling")
    series = {}
    for r in sweep_rows(SWEEP):
        size_kib, u = r["point"]["shard_KiB"], r["point"]["unroll"]
        rep.add(shard_KiB=size_kib, unroll=u,
                bw_GBps=round(r["bus_GBps"], 3),
                t_us=round(r["time_ns"] / 1e3, 1))
        series.setdefault(size_kib, []).append(r["time_ns"])
    big = series[SIZES_KIB[-1]]
    small = series[SIZES_KIB[0]]
    derived = (f"large_xfer_speedup_u16={big[0] / big[-1]:.2f}x;"
               f"small_xfer_speedup_u16={small[0] / small[-1]:.2f}x")
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
