"""Paper Fig. 12: All-to-All bandwidth vs loop-unrolling factor
(intra-wavefront ILP).  Expected: more in-flight Wavefront Requests help
bandwidth-bound sizes, with saturation; no effect on tiny latency-bound
transfers."""

from __future__ import annotations

from repro.core.backends import FineConfig, simulate
from repro.core.collectives import direct_all_to_all

from .common import Report, fast_gpu, small_noc

KiB = 1 << 10


def run(nranks: int = 8, nwg: int = 4,
        sizes=(4 * KiB, 64 * KiB), unrolls=(1, 2, 4, 8, 16)) -> str:
    rep = Report("fig12_unrolling")
    series = {}
    for size in sizes:
        for u in unrolls:
            prog = direct_all_to_all(nranks, size, nwg, "put")
            r = simulate(prog, fidelity="fine",
                         config=FineConfig(noc=small_noc(),
                                           gpu_config=fast_gpu()),
                         unroll=u, check="off")
            rep.add(shard_KiB=size // KiB, unroll=u,
                    bw_GBps=round(r.bus_GBps, 3),
                    t_us=round(r.time_ns / 1e3, 1))
            series.setdefault(size, []).append(r.time_ns)
    big = series[sizes[-1]]
    small = series[sizes[0]]
    derived = (f"large_xfer_speedup_u16={big[0] / big[-1]:.2f}x;"
               f"small_xfer_speedup_u16={small[0] / small[-1]:.2f}x")
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
