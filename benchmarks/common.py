"""Shared benchmark scaffolding.

The paper's case studies (§5) use 16-32 GPUs x 128 CUs and buffers up to
256 MiB; a pure-Python event engine on one CPU core simulates ~10^5-10^6
events/s, so each benchmark documents its scaled-down configuration
(fewer GPUs/CUs, smaller buffers, larger cache lines) — trends, not
absolute magnitudes, are the reproduction target (DESIGN.md §9/§10).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from repro.core.cluster import NocConfig
from repro.core.gpu_model import GpuConfig


def fast_gpu(**kw) -> GpuConfig:
    """512 B cache lines (TPU-DMA-burst analogue) — 4x fewer events than
    the GPU-faithful 128 B; trends unchanged (documented scaling)."""
    kw.setdefault("cache_line", 512)
    return GpuConfig(**kw)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


# scaled-down generic GPU (paper §5.1 is 8x4 routers x 4 CUs, 32+32 mem/io)
def small_noc(arbitration: str = "fifo") -> NocConfig:
    return NocConfig(mesh_x=2, mesh_y=2, cus_per_router=2, mem_channels=4,
                     io_ports=4, arbitration=arbitration)


def medium_noc(arbitration: str = "fifo") -> NocConfig:
    return NocConfig(mesh_x=4, mesh_y=2, cus_per_router=2, mem_channels=8,
                     io_ports=8, arbitration=arbitration)


def sweep_rows(spec, **kw) -> List[Dict]:
    """Execute a benchmark's SweepSpec inline and return its rows.

    Benchmarks measure *current* code, so the run is ``fresh`` (no cache
    reads, JSONL restarted) — the stream still lands in
    ``results/sweeps/<name>.jsonl`` for provenance.  Any failed point
    fails the suite loudly.
    """
    from repro.sweep import run_sweep
    out = os.path.join(RESULTS_DIR, "sweeps", f"{spec.name}.jsonl")
    res = run_sweep(spec, jobs=0, fresh=True, progress=False, out=out, **kw)
    bad = res.failed
    assert not bad, (f"{spec.name}: {len(bad)} point(s) failed, first: "
                     f"{bad[0].get('error', bad[0]['status'])}")
    return res.rows


class Report:
    """Collects rows; prints ``name,us_per_call,derived`` CSV lines and
    writes the full table to results/<name>.json."""

    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict] = []
        self._t0 = time.perf_counter()

    def add(self, **row) -> None:
        self.rows.append(row)

    def finish(self, derived: str = "") -> None:
        wall_us = (time.perf_counter() - self._t0) * 1e6
        print(f"{self.name},{wall_us:.0f},{derived}")
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(RESULTS_DIR, f"{self.name}.json"), "w") as f:
            json.dump(self.rows, f, indent=1)

    def table(self) -> str:
        if not self.rows:
            return "(empty)"
        cols = list(self.rows[0].keys())
        out = [" | ".join(cols)]
        for r in self.rows:
            out.append(" | ".join(str(r.get(c, "")) for c in cols))
        return "\n".join(out)
