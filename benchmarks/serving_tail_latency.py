"""Serving tail-latency benchmark (tracked PR-over-PR).

Runs two seeded serving scenarios — Poisson-traffic continuous batching
on a 4-way TP group, and disaggregated prefill/decode with KV-cache p2p
transfers — through ``simulate()`` at all three fidelity tiers, and
writes ``results/BENCH_serving.json`` with per-tier tail-latency rows
(p50/p99/p999, mean, max, goodput).

Determinism gates: every scenario is built and simulated twice from the
same seed and both passes must agree bit-for-bit (arrival streams, trace
shape, per-tier time_ns and every latency percentile).

Run:  PYTHONPATH=src python benchmarks/serving_tail_latency.py
"""

from __future__ import annotations

import json
import os
import sys
import time

# pin JAX to the CPU backend before anything imports it (bench-box rule:
# accelerator-plugin probing costs >400 s and masquerades as a hang)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve import (PoissonArrivals, ServingModel,   # noqa: E402
                         continuous_batching, disaggregated,
                         generate_requests)
from repro.sweep import (SweepSpec, payload,              # noqa: E402
                         register_suite, register_sweep, run_sweep)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

SEED = 20260808
N_REQUESTS = 48
RATE_RPS = 2000.0
PROMPT_TOKENS = (16, 64)
DECODE_TOKENS = (4, 24)

#: toy per-token serving costs — small enough that the fine tier finishes
#: in seconds, large enough that comp, all-reduce and KV transfer all
#: contribute to the critical path
MODEL = ServingModel("bench_toy", flops_per_token=2e6, weight_bytes=1e6,
                     coll_bytes_per_token=4096, kv_bytes_per_token=2048)

TIERS = ("analytic", "coarse", "fine")


def build_scenarios():
    reqs = generate_requests(PoissonArrivals(RATE_RPS), n=N_REQUESTS,
                             seed=SEED, prompt_tokens=PROMPT_TOKENS,
                             decode_tokens=DECODE_TOKENS)
    return {
        "continuous_batching": continuous_batching(MODEL, reqs, tp=4),
        "disaggregated": disaggregated(MODEL, reqs, prefill_ranks=2,
                                       decode_ranks=2),
    }


def run_scenario(scen) -> dict:
    rows = {}
    for fid in TIERS:
        t0 = time.perf_counter()
        r = scen.simulate(fidelity=fid, check="off")
        wall = time.perf_counter() - t0
        s = r.latency
        rows[fid] = {
            "time_ns": r.time_ns,
            "events": r.events,
            "wall_s": round(wall, 3),
            "p50_ns": s.p50_ns,
            "p99_ns": s.p99_ns,
            "p999_ns": s.p999_ns,
            "mean_ns": s.mean_ns,
            "max_ns": s.max_ns,
            "goodput_rps": s.goodput_rps,
        }
    return rows


def _run_point(coords: dict, tier: str) -> dict:
    scen = build_scenarios()[coords["scenario"]]
    r = scen.simulate(fidelity=tier, check="off")
    s = r.latency
    return {"time_ns": r.time_ns, "events": r.events,
            "p50_ns": s.p50_ns, "p99_ns": s.p99_ns, "p999_ns": s.p999_ns,
            "mean_ns": s.mean_ns, "max_ns": s.max_ns,
            "goodput_rps": s.goodput_rps}


SWEEP = register_sweep(SweepSpec(
    name="serving_tail_latency",
    axes={"scenario": ("continuous_batching", "disaggregated")},
    run_point=_run_point,
    tiers=TIERS,
))


@register_suite("serving_tail_latency")
def suite() -> dict:
    """Driver-facing run: scenario x tier through the sweep runner; writes
    an *untracked* report so the committed BENCH_serving baseline stays
    pristine."""
    res = run_sweep(SWEEP, jobs=0, fresh=True, progress=False,
                    out=os.path.join(RESULTS, "sweeps",
                                     "serving_tail_latency.jsonl"))
    assert not res.failed, res.failed[0]
    out: dict = {"scenarios": {}}
    for r in res.rows:
        scen = r["point"]["scenario"]
        out["scenarios"].setdefault(scen, {})[r["tier"]] = payload(r)
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "serving_tail_latency_suite.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    p99s = {n: tiers["fine"]["p99_ns"]
            for n, tiers in out["scenarios"].items()}
    print("serving_tail_latency,0," + ";".join(
        f"{n}_p99_us={v / 1e3:.1f}" for n, v in sorted(p99s.items())))
    return out


def main() -> None:
    passes = []
    for _ in range(2):                        # same-seed replay gate
        scens = build_scenarios()
        passes.append({name: run_scenario(s) for name, s in scens.items()})
    stable = {n: {f: {k: v for k, v in row.items() if k != "wall_s"}
                  for f, row in tiers.items()}
              for n, tiers in passes[0].items()}
    stable2 = {n: {f: {k: v for k, v in row.items() if k != "wall_s"}
                   for f, row in tiers.items()}
               for n, tiers in passes[1].items()}
    assert stable == stable2, "same-seed serving runs must be bit-identical"

    scens = build_scenarios()
    out = {
        "workload": {
            "kind": "serving_scenarios", "seed": SEED,
            "n_requests": N_REQUESTS, "rate_rps": RATE_RPS,
            "prompt_tokens": list(PROMPT_TOKENS),
            "decode_tokens": list(DECODE_TOKENS),
            "model": {"flops_per_token": MODEL.flops_per_token,
                      "weight_bytes": MODEL.weight_bytes,
                      "coll_bytes_per_token": MODEL.coll_bytes_per_token,
                      "kv_bytes_per_token": MODEL.kv_bytes_per_token},
            "trace_nodes": {n: len(s.trace.nodes)
                            for n, s in scens.items()},
        },
        "scenarios": passes[0],
    }
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
