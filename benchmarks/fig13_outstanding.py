"""Paper Fig. 13: All-Gather bandwidth vs max outstanding Wavefront
Requests per CU (register-file-size proxy).  Expected: saturating gain for
bandwidth-bound sizes, no effect for latency-bound ones."""

from __future__ import annotations

from repro.core.backends import FineConfig, simulate
from repro.core.collectives import direct_all_gather
from repro.core.gpu_model import GpuConfig

from .common import Report, small_noc

KiB = 1 << 10


def run(nranks: int = 8, nwg: int = 4,
        sizes=(4 * KiB, 64 * KiB), limits=(2, 4, 8, 16, 32, 64)) -> str:
    rep = Report("fig13_outstanding")
    series = {}
    for size in sizes:
        for lim in limits:
            prog = direct_all_gather(nranks, size, nwg, "put")
            gc = GpuConfig(max_outstanding=lim, unroll=8,
                           cache_line=512)
            r = simulate(prog, fidelity="fine",
                         config=FineConfig(noc=small_noc(), gpu_config=gc),
                         unroll=8, check="off")
            rep.add(shard_KiB=size // KiB, max_outstanding=lim,
                    bw_GBps=round(r.bus_GBps, 3))
            series.setdefault(size, []).append(r.time_ns)
    big = series[sizes[-1]]
    saturation = big[-1] / big[-2] if len(big) > 1 else 1.0
    derived = (f"large_speedup_64v2={big[0] / big[-1]:.2f}x;"
               f"saturation_tail={saturation:.3f}")
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
