"""Paper Fig. 13: All-Gather bandwidth vs max outstanding Wavefront
Requests per CU (register-file-size proxy).  Expected: saturating gain for
bandwidth-bound sizes, no effect for latency-bound ones.

Declared as a SweepSpec (shard size x outstanding limit) and executed
through the sweep runner."""

from __future__ import annotations

from repro.core.backends import FineConfig
from repro.core.collectives import direct_all_gather
from repro.core.gpu_model import GpuConfig
from repro.sweep import PointSpec, SweepSpec, register_suite, register_sweep

from .common import Report, small_noc, sweep_rows

KiB = 1 << 10

NRANKS = 8
NWG = 4
SIZES_KIB = (4, 64)
LIMITS = (2, 4, 8, 16, 32, 64)


def _build(coords: dict, tier: str) -> PointSpec:
    prog = direct_all_gather(NRANKS, coords["shard_KiB"] * KiB, NWG, "put")
    gc = GpuConfig(max_outstanding=coords["max_outstanding"], unroll=8,
                   cache_line=512)
    return PointSpec(workload=prog,
                     config=FineConfig(noc=small_noc(), gpu_config=gc),
                     run_kw={"unroll": 8},
                     metrics=lambda r: {"bus_GBps": r.bus_GBps})


SWEEP = register_sweep(SweepSpec(
    name="fig13_outstanding",
    axes={"shard_KiB": SIZES_KIB, "max_outstanding": LIMITS},
    build=_build,
))


@register_suite("fig13_outstanding")
def run() -> str:
    rep = Report("fig13_outstanding")
    series = {}
    for r in sweep_rows(SWEEP):
        size_kib, lim = r["point"]["shard_KiB"], r["point"]["max_outstanding"]
        rep.add(shard_KiB=size_kib, max_outstanding=lim,
                bw_GBps=round(r["bus_GBps"], 3))
        series.setdefault(size_kib, []).append(r["time_ns"])
    big = series[SIZES_KIB[-1]]
    saturation = big[-1] / big[-2] if len(big) > 1 else 1.0
    derived = (f"large_speedup_64v2={big[0] / big[-1]:.2f}x;"
               f"saturation_tail={saturation:.3f}")
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
