"""Paper Fig. 10: get- vs put-based Reduce-Scatter collective bandwidth.

Paper: 32 GPUs, 32 workgroups, buffers to 256 MiB.  Scaled: 8 GPUs x 8 CUs,
4 workgroups, 16-512 KiB buffers.  Expected reproduction: get overtakes put
as buffers grow (fused load-reduce overlaps transfer with reduction;
put pays semaphore synchronization before every reduce).

Declared as a :class:`repro.sweep.SweepSpec` (buffer size x protocol) and
executed through the sweep runner; ``run()`` folds the JSONL rows back
into the legacy per-size report table."""

from __future__ import annotations

from repro.core.backends import FineConfig
from repro.core.collectives import direct_reduce_scatter
from repro.sweep import PointSpec, SweepSpec, register_suite, register_sweep

from .common import Report, fast_gpu, small_noc, sweep_rows

KiB = 1 << 10

NRANKS = 8
NWG = 4
SIZES_KIB = (16, 64, 256)


def _build(coords: dict, tier: str) -> PointSpec:
    prog = direct_reduce_scatter(NRANKS, coords["buffer_KiB"] * KiB, NWG,
                                 coords["protocol"])
    return PointSpec(workload=prog,
                     config=FineConfig(noc=small_noc(),
                                       gpu_config=fast_gpu()),
                     run_kw={"unroll": 4},
                     metrics=lambda r: {"bus_GBps": r.bus_GBps})


SWEEP = register_sweep(SweepSpec(
    name="fig10_reduce_scatter",
    axes={"buffer_KiB": SIZES_KIB, "protocol": ("put", "get")},
    build=_build,
))


@register_suite("fig10_reduce_scatter")
def run() -> str:
    rep = Report("fig10_reduce_scatter")
    rows = {(r["point"]["buffer_KiB"], r["point"]["protocol"]): r
            for r in sweep_rows(SWEEP)}
    wins = []
    for size_kib in SIZES_KIB:
        row = {"buffer_KiB": size_kib}
        for proto in ("put", "get"):
            r = rows[(size_kib, proto)]
            row[f"bw_{proto}_GBps"] = round(r["bus_GBps"], 3)
            row[f"t_{proto}_us"] = round(r["time_ns"] / 1e3, 1)
        row["get_speedup"] = round(row["t_put_us"] / row["t_get_us"], 3)
        wins.append(row["get_speedup"])
        rep.add(**row)
    derived = f"get_speedup_large={wins[-1]:.2f}x"
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
