"""Paper Fig. 10: get- vs put-based Reduce-Scatter collective bandwidth.

Paper: 32 GPUs, 32 workgroups, buffers to 256 MiB.  Scaled: 8 GPUs x 8 CUs,
4 workgroups, 16-512 KiB buffers.  Expected reproduction: get overtakes put
as buffers grow (fused load-reduce overlaps transfer with reduction;
put pays semaphore synchronization before every reduce)."""

from __future__ import annotations

from repro.core.backends import FineConfig, simulate
from repro.core.collectives import direct_reduce_scatter

from .common import Report, fast_gpu, small_noc

KiB = 1 << 10


def run(nranks: int = 8, nwg: int = 4, sizes=(16 * KiB, 64 * KiB,
                                              256 * KiB)) -> str:
    rep = Report("fig10_reduce_scatter")
    wins = []
    for size in sizes:
        row = {"buffer_KiB": size // KiB}
        for proto in ("put", "get"):
            prog = direct_reduce_scatter(nranks, size, nwg, proto)
            r = simulate(prog, fidelity="fine",
                         config=FineConfig(noc=small_noc(),
                                           gpu_config=fast_gpu()),
                         unroll=4, check="off")
            row[f"bw_{proto}_GBps"] = round(r.bus_GBps, 3)
            row[f"t_{proto}_us"] = round(r.time_ns / 1e3, 1)
        row["get_speedup"] = round(row["t_put_us"] / row["t_get_us"], 3)
        wins.append(row["get_speedup"])
        rep.add(**row)
    derived = f"get_speedup_large={wins[-1]:.2f}x"
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
