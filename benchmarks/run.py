"""Benchmark suite entry point.

Suites are *auto-discovered* from the sweep registry
(:mod:`repro.sweep.registry`): every benchmark module registers its
runnable with ``@register_suite`` as an import side effect, and this
driver runs whatever is registered — so a new benchmark shows up here the
moment it registers, instead of drifting out of a hand-maintained list.

Prints ``name,us_per_call,derived`` CSV lines (one per benchmark) and
writes full tables to results/<name>.json.
"""

from __future__ import annotations

import sys
import traceback


def main(names=None) -> None:
    from repro.sweep import registry
    registry.discover()
    suites = registry.SUITES
    if names:
        unknown = sorted(set(names) - set(suites))
        if unknown:
            sys.exit(f"unknown suite(s) {unknown}; "
                     f"available: {sorted(suites)}")
        selected = names
    else:
        selected = sorted(suites)
    failures = 0
    for name in selected:
        try:
            suites[name]()
        except Exception:  # noqa: BLE001 — keep the suite running
            failures += 1
            print(f"{name},0,ERROR")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main(sys.argv[1:])
