"""Benchmark suite entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (one per benchmark) and
writes full tables to results/<name>.json.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (fidelity_compare, fig4_protocols, fig10_reduce_scatter,
                   fig11_all_gather, fig12_unrolling, fig13_outstanding,
                   fig14_scalability, roofline_table, step_prediction,
                   table1_clos_allreduce)
    suites = [
        ("fig4_protocols", fig4_protocols.run),
        ("fig10_reduce_scatter", fig10_reduce_scatter.run),
        ("fig11_all_gather", fig11_all_gather.run),
        ("fig12_unrolling", fig12_unrolling.run),
        ("fig13_outstanding", fig13_outstanding.run),
        ("fig14_scalability", fig14_scalability.run),
        ("table1_clos_allreduce", table1_clos_allreduce.run),
        ("fidelity_compare", fidelity_compare.run),
        ("roofline_table", roofline_table.run),
        ("step_prediction", step_prediction.run),
    ]
    failures = 0
    for name, fn in suites:
        try:
            fn()
        except Exception:  # noqa: BLE001 — keep the suite running
            failures += 1
            print(f"{name},0,ERROR")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
