"""Paper Table 1 / §5.5: ring All-Reduce over an InfraGraph-defined Clos
fabric, flow-completion-time metrics.

The paper runs ns-3 on an 8-GPU Clos from an InfraGraph blueprint; we
translate the same blueprint to our chunk-granularity backend over the
expanded fabric and report AllReduce completion time, achieved bus
bandwidth, and per-flow FCT statistics (min/max/avg vs standalone)."""

from __future__ import annotations

from typing import List

from repro.core.engine import Engine
from repro.core.infragraph import clos_fat_tree_fabric, to_fabric
from repro.core.network.fabric import DATA
from repro.sweep import register_suite

from .common import Report

MB = 1 << 20


class _FlowTracker:
    """Sends each collective step's chunk as one flow; records FCTs."""

    def __init__(self, fabric, gpu_nodes):
        self.fabric = fabric
        self.gpu_nodes = gpu_nodes
        self.fcts: List[float] = []

    def send(self, src: int, dst: int, size: int, on_done) -> None:
        t0 = self.fabric.engine.now
        route = self.fabric.route(self.gpu_nodes[src], self.gpu_nodes[dst])

        def arrived(flight):
            self.fcts.append(self.fabric.engine.now - t0)
            on_done()

        self.fabric.send(route, size, DATA, arrived)


@register_suite("table1_clos_allreduce")
def run(num_gpus: int = 8, size_bytes: int = 1 * MB) -> str:
    infra = clos_fat_tree_fabric(num_hosts=num_gpus, switch_ports=4,
                                 link_GBps=50.0, link_lat_ns=500.0)
    fabric, g = to_fabric(infra)
    gpu_nodes = [fabric.node(f"host.{i}.gpu.0") for i in range(num_gpus)]
    tracker = _FlowTracker(fabric, gpu_nodes)

    # ring AR as explicit flows: 2(n-1) steps of size/n chunks per rank
    n = num_gpus
    chunk = size_bytes // n
    done = {"ranks": 0, "t": 0.0}
    step_of = [0] * n

    def advance(r):
        step_of[r] += 1
        if step_of[r] == 2 * (n - 1):
            done["ranks"] += 1
            done["t"] = fabric.engine.now
        else:
            tracker.send(r, (r + 1) % n, chunk, lambda rr=r: advance(rr))

    for r in range(n):
        tracker.send(r, (r + 1) % n, chunk, lambda rr=r: advance(rr))
    fabric.engine.run(5e10)
    assert done["ranks"] == n, f"incomplete: {done['ranks']}/{n}"
    t = done["t"]

    # standalone FCT: one chunk on an idle fabric
    e2 = Engine()
    fabric2, _ = to_fabric(infra, engine=e2)
    nodes2 = [fabric2.node(f"host.{i}.gpu.0") for i in range(num_gpus)]
    solo = {}
    fabric2.send(fabric2.route(nodes2[0], nodes2[1]), chunk, DATA,
                 lambda f: solo.setdefault("t", e2.now))
    e2.run()

    fcts = tracker.fcts
    bus_bw = size_bytes / t if t else 0.0
    rep = Report("table1_clos_allreduce")
    rep.add(metric="allreduce_completion_us", value=round(t / 1e3, 2))
    rep.add(metric="achieved_bus_bw_GBps", value=round(bus_bw, 3))
    rep.add(metric="min_fct_ns", value=round(min(fcts)))
    rep.add(metric="max_fct_ns", value=round(max(fcts)))
    rep.add(metric="avg_fct_ns", value=round(sum(fcts) / len(fcts)))
    rep.add(metric="standalone_fct_ns", value=round(solo["t"]))
    rep.add(metric="peak_fct_overhead_ns",
            value=round(max(fcts) - solo["t"]))
    rep.add(metric="flows", value=len(fcts))
    derived = (f"completion_us={t / 1e3:.1f};"
               f"avg_fct={sum(fcts) / len(fcts):.0f}ns;"
               f"standalone={solo['t']:.0f}ns")
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
