"""Paper Figs. 14-15: wall-clock simulation time and simulation throughput
(simulated ns per wall-clock second) of fine-grained All-Gather, scaling
target system size.  Paper: 2-128 GPUs at 448 endpoints each; here 2-16
GPUs at ~30 endpoints each (one CPU core)."""

from __future__ import annotations

from repro.core.collectives import direct_all_gather
from repro.core.system import simulate_collective

from .common import Report, fast_gpu, small_noc

KiB = 1 << 10


def run(sizes=(16 * KiB, 64 * KiB), ranks=(2, 4, 8, 16)) -> str:
    rep = Report("fig14_scalability")
    rows = []
    for n in ranks:
        for size in sizes:
            prog = direct_all_gather(n, size, 2, "put")
            r = simulate_collective(prog, noc=small_noc(),
                                    gpu_config=fast_gpu(), unroll=8)
            thr = r.time_ns / max(r.wallclock_s, 1e-9)
            rows.append((n, size, r.events, r.wallclock_s, thr))
            rep.add(gpus=n, shard_KiB=size // KiB, events=r.events,
                    wallclock_s=round(r.wallclock_s, 3),
                    sim_ns_per_wall_s=round(thr, 0),
                    events_per_s=round(r.events / max(r.wallclock_s, 1e-9)))
    # paper insight: wall time ~ linear in buffer size; throughput set by
    # target scale, not buffer size
    n_big = [r for r in rows if r[0] == ranks[-1]]
    lin = n_big[-1][3] / max(n_big[0][3], 1e-9)
    derived = (f"walltime_ratio_4x_buffer={lin:.2f}x;"
               f"events_per_s={n_big[-1][2] / max(n_big[-1][3], 1e-9):.0f}")
    rep.finish(derived)
    return derived


if __name__ == "__main__":
    print(run())
