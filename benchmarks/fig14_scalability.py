"""Paper Figs. 14-15: wall-clock simulation time and simulation throughput
(simulated ns per wall-clock second) of fine-grained All-Gather, scaling
target system size.  Paper: 2-128 GPUs at 448 endpoints each; here the
figure sweep covers 2-16 GPUs at ~30 endpoints each (one CPU core), and
the tracked scalability bench sweeps 2-128 ranks on the hierarchical
multi-host blueprint (tiny per-GPU NoC) and writes
``results/BENCH_scalability.json``.

The bench holds the *total* gathered buffer fixed (shard = total / n), so
ring All-Gather traffic — and therefore event count — grows linearly with
rank count; events-per-rank staying flat is the tracked near-linearity
signal.  Route registration is lazy: a ring workload touches O(n) pairs,
so ``pairs_registered`` staying well under n^2 is the tracked
sub-quadratic-registration signal.

Run:  PYTHONPATH=src python benchmarks/fig14_scalability.py [--quick]
      (--quick caps the sweep at 32 ranks and writes
       BENCH_scalability_quick.json instead of the tracked baseline)
"""

from __future__ import annotations

import json
import math
import os
import sys
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import collectives as C                        # noqa: E402
from repro.core.backends import FineConfig, simulate           # noqa: E402
from repro.core.cluster import NocConfig                       # noqa: E402
from repro.core.infragraph import (hierarchical_fabric,        # noqa: E402
                                   to_cluster)

from repro.sweep import (PointSpec, SweepSpec,                 # noqa: E402
                         register_suite, register_sweep)

try:
    from .common import (Report, fast_gpu, small_noc,          # noqa: E402
                         sweep_rows)
except ImportError:                                            # script mode
    from common import (Report, fast_gpu, small_noc,           # noqa: E402
                        sweep_rows)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

KiB = 1 << 10

#: fixed total gathered bytes for the bench sweep (shard = TOTAL / n)
TOTAL = 128 * KiB

#: (hosts, gpus_per_host) points — ranks = hosts * gpus_per_host, 2..128
BENCH_POINTS = ((1, 2), (1, 4), (2, 4), (4, 4), (8, 4), (16, 4), (32, 4))


FIG_RANKS = (2, 4, 8, 16)
FIG_SIZES_KIB = (16, 64)


def _build_fig(coords: dict, tier: str) -> PointSpec:
    prog = C.direct_all_gather(coords["gpus"], coords["shard_KiB"] * KiB,
                               2, "put")
    return PointSpec(workload=prog,
                     config=FineConfig(noc=small_noc(),
                                       gpu_config=fast_gpu()),
                     run_kw={"unroll": 8})


SWEEP = register_sweep(SweepSpec(
    name="fig14_scalability",
    axes={"gpus": FIG_RANKS, "shard_KiB": FIG_SIZES_KIB},
    build=_build_fig,
))


@register_suite("fig14_scalability")
def run() -> str:
    rep = Report("fig14_scalability")
    rows = []
    for r in sweep_rows(SWEEP):
        n, size_kib = r["point"]["gpus"], r["point"]["shard_KiB"]
        wall = max(r["sim_wallclock_s"], 1e-9)
        thr = r["time_ns"] / wall
        rows.append((n, size_kib, r["events"], wall, thr))
        rep.add(gpus=n, shard_KiB=size_kib, events=r["events"],
                wallclock_s=round(wall, 3),
                sim_ns_per_wall_s=round(thr, 0),
                events_per_s=round(r["events"] / wall))
    # paper insight: wall time ~ linear in buffer size; throughput set by
    # target scale, not buffer size
    n_big = [r for r in rows if r[0] == FIG_RANKS[-1]]
    lin = n_big[-1][3] / max(n_big[0][3], 1e-9)
    derived = (f"walltime_ratio_4x_buffer={lin:.2f}x;"
               f"events_per_s={n_big[-1][2] / max(n_big[-1][3], 1e-9):.0f}")
    rep.finish(derived)
    return derived


# ---------------------------------------------------------------------------
# Tracked scalability bench (hierarchical blueprint, 2-128 ranks)
# ---------------------------------------------------------------------------

def tiny_noc() -> NocConfig:
    """Smallest viable per-GPU NoC so the 128-rank point fits one core."""
    return NocConfig(mesh_x=2, mesh_y=1, cus_per_router=1, mem_channels=2,
                     io_ports=2)


def bench_point(hosts: int, gpus_per_host: int) -> dict:
    graph = hierarchical_fabric(hosts=hosts, gpus_per_host=gpus_per_host)
    cluster = to_cluster(graph, noc=tiny_noc(), gpu_config=fast_gpu())
    n = len(cluster.gpus)
    assert n == hosts * gpus_per_host
    prog = C.ring_all_gather(n, TOTAL // n, 1, "put")
    t0 = time.perf_counter()
    r = simulate(prog, fidelity="fine", cluster=cluster, check="off")
    wall = time.perf_counter() - t0
    fab = cluster.fabric
    return {
        "ranks": n,
        "hosts": hosts,
        "gpus_per_host": gpus_per_host,
        "shard_bytes": TOTAL // n,
        "time_ns": r.time_ns,
        "events": r.events,
        "events_per_rank": round(r.events / n, 1),
        "wall_s": round(wall, 3),
        "events_per_s": round(r.events / wall) if wall > 0 else None,
        "order_violations": fab.order_violations,
        "pairs_registered": cluster.pairs_registered,
        "routes_registered": fab.routes_registered,
    }


def bench(max_ranks: int = 128, name: str = "BENCH_scalability.json") -> dict:
    rows = [bench_point(h, g) for h, g in BENCH_POINTS
            if h * g <= max_ranks]
    for row in rows:
        assert row["order_violations"] == 0, row
        # lazy registration: a ring touches O(n) pairs, never the n^2
        # product — the sub-quadratic-registration gate
        n = row["ranks"]
        assert row["pairs_registered"] <= 4 * n, row
    # near-linearity: with total bytes fixed, events/rank must be flat
    # (within noise from the n-1 step count) across the tail of the sweep
    tail = [r for r in rows if r["ranks"] >= 8]
    epr = [r["events_per_rank"] for r in tail]
    slope = max(epr) / min(epr) if epr else 1.0
    # log-log slope of events vs ranks across the full sweep (1.0 = linear)
    lo, hi = rows[0], rows[-1]
    loglog = (math.log(hi["events"] / lo["events"])
              / math.log(hi["ranks"] / lo["ranks"]))
    out = {
        "workload": {"collective": "ring_all_gather",
                     "total_bytes": TOTAL, "nworkgroups": 1,
                     "protocol": "put", "blueprint": "hierarchical_fabric",
                     "noc": "tiny(2x1, 1 cu, 2 mem, 2 io)",
                     "route_policy": "lazy"},
        "sweep": rows,
        "events_per_rank_spread_tail": round(slope, 3),
        "loglog_slope_events_vs_ranks": round(loglog, 3),
    }
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"\nwrote {path}")
    return out


if __name__ == "__main__":
    if "--quick" in sys.argv:
        bench(max_ranks=32, name="BENCH_scalability_quick.json")
    else:
        bench()
