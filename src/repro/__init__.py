"""repro: ASTRA-sim 3.0 reproduction + multi-pod JAX training/serving
framework.

Two halves, one repo:
  repro.core         — the paper: fine-grained distributed-ML simulator
  repro.{models,...} — the framework whose compiled artifacts feed it

See README.md / DESIGN.md / EXPERIMENTS.md.
"""

__version__ = "1.0.0"
