from .base import (ArchConfig, MoEConfig, ShapeConfig, SHAPES, get,
                   reduced, registry)

__all__ = ["ArchConfig", "MoEConfig", "ShapeConfig", "SHAPES", "get",
           "reduced", "registry"]
