"""Grok-1 314B: MoE 8 experts top-2 [hf:xai-org/grok-1].

Expert sharding: "tp" — 8 experts do not divide the 16-way model axis, so
each expert's d_ff=32768 hidden dim is tensor-sharded instead
(DESIGN.md SS5).
"""
from .base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab=131072, act="swiglu", rope_theta=10_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32768, sharding="tp"),
))
