"""RecurrentGemma 9B: RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427].  38 layers = 12 x (rec, rec, attn) + (rec, rec).
Windowed attention (2048) + O(1) recurrent state => long_500k eligible."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab=256000, head_dim=256, act="geglu", rope_theta=10_000.0,
    window=2048, rec_d_rnn=4096, rec_conv=4,
    rec_pattern=("rec", "rec", "attn"), sub_quadratic=True,
))
