"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload shape
is a ``ShapeConfig``.  ``registry()`` maps ``--arch`` ids to configs;
``reduced()`` derives the CPU-smoke-test variant of any architecture
(small layers/width/vocab, same family and code paths).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # "tp": shard each expert's hidden dim over the model axis (few big
    # experts, e.g. grok).  "ep": shard the expert dim (many small experts,
    # e.g. moonshot) — all-to-all dispatch.
    sharding: str = "tp"
    # GShard dispatch group size: one-hot dispatch flops and intermediate
    # bytes are LINEAR in this (cap ~ k*group/E) — small groups are cheap
    group_size: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    act: str = "swiglu"            # swiglu | geglu
    norm_eps: float = 1e-5
    rope_theta: float = 500_000.0
    moe: Optional[MoEConfig] = None
    # hybrid (recurrentgemma): block pattern, local-attention window
    window: int = 0                # 0 -> full attention
    rec_d_rnn: int = 0
    rec_conv: int = 4
    rec_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    # encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0
    # modality frontend stubs ([audio]/[vlm]: precomputed embeddings)
    frontend: str = "none"         # none | audio | vision
    frontend_len: int = 0          # frames / patches provided by the stub
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # does the arch support O(1)-state / windowed decode (long_500k)?
    sub_quadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.hd
        mlp_mats = 2 if self.act == "gelu" else 3
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + \
            self.n_heads * hd * d
        if self.family in ("dense", "moe", "vlm"):
            mlp = mlp_mats * d * self.d_ff
            if self.moe:
                mlp = (3 * d * self.moe.d_ff_expert) * self.moe.num_experts \
                    + d * self.moe.num_experts
            per_layer = attn + mlp + 2 * d
            total = emb + self.n_layers * per_layer
        elif self.family == "ssm":                    # rwkv6
            tm = 5 * d * d + 2 * d * 64 * 5 + 2 * d   # time-mix + loras
            cm = 2 * d * self.d_ff + d * d            # channel-mix
            total = emb + self.n_layers * (tm + cm + 2 * d)
        elif self.family == "hybrid":
            rec = 2 * d * self.rec_d_rnn + self.rec_d_rnn * d + \
                self.rec_d_rnn * self.rec_conv + 2 * self.rec_d_rnn
            mlp = 3 * d * self.d_ff
            n_attn = sum(1 for i in range(self.n_layers)
                         if self.rec_pattern[i % len(self.rec_pattern)]
                         == "attn")
            n_rec = self.n_layers - n_attn
            total = emb + n_rec * (rec + mlp + 2 * d) + \
                n_attn * (attn + mlp + 2 * d)
        elif self.family == "encdec":
            mlp = 3 * d * self.d_ff
            enc = self.enc_layers * (attn + mlp + 2 * d)
            dec = self.dec_layers * (2 * attn + mlp + 3 * d)
            total = emb + enc + dec
        else:
            total = emb + self.n_layers * (attn + 3 * d * self.d_ff + 2 * d)
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense_like = self.param_count() - self.n_layers * (
            3 * d * self.moe.d_ff_expert) * self.moe.num_experts
        return int(dense_like + self.n_layers *
                   3 * d * self.moe.d_ff_expert * self.moe.top_k)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """CPU smoke-test variant: same family/code paths, tiny dimensions."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2 if not cfg.rec_pattern
                     else len(cfg.rec_pattern)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        d_ff=128,
        vocab=256,
        head_dim=16 if cfg.head_dim else 0,
        dtype="float32",
    )
    if cfg.moe:
        kw["moe"] = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                              sharding=cfg.moe.sharding)
    if cfg.rec_d_rnn:
        kw["rec_d_rnn"] = 64
    if cfg.enc_layers:
        kw["enc_layers"], kw["dec_layers"] = 2, 2
        kw["n_layers"] = 4
    if cfg.frontend_len:
        kw["frontend_len"] = 16
    if cfg.window:
        kw["window"] = 32
    return replace(cfg, **kw)


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def registry() -> Dict[str, ArchConfig]:
    # import for side effects: each config module registers itself
    from . import gemma_2b, grok_1_314b, internvl2_1b, llama3_8b  # noqa: E501,F401
    from . import moonshot_v1_16b_a3b, phi3_medium_14b  # noqa: F401
    from . import recurrentgemma_9b, rwkv6_7b  # noqa: F401
    from . import seamless_m4t_large_v2, starcoder2_7b  # noqa: F401
    return dict(_REGISTRY)


def get(name: str) -> ArchConfig:
    return registry()[name]
