"""SeamlessM4T-large v2 backbone: encoder-decoder, 24+24 layers
[arXiv:2308.11596].  Audio frontend is a STUB: input_specs() provides
precomputed frame embeddings (brief: modality frontend not modeled)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=256206, act="swiglu", rope_theta=10_000.0,
    enc_layers=24, dec_layers=24, frontend="audio", frontend_len=4096,
))
