"""Moonlight 16B-A3B: fine-grained MoE, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B].

Expert sharding: "ep" — 64 experts / 16-way model axis = 4 experts per
shard; token dispatch becomes an all-to-all (DESIGN.md SS5).
"""
from .base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, act="swiglu", rope_theta=50_000.0,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, sharding="ep"),
))
