"""InternVL2-1B backbone (InternLM2-ish LM): ViT frontend is a STUB
providing precomputed patch embeddings [arXiv:2404.16821]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151655, act="swiglu", rope_theta=1_000_000.0,
    frontend="vision", frontend_len=256,
))
