"""``python -m repro.check`` entry point (shim over
:mod:`repro.core.check.cli`)."""

from repro.core.check.cli import main

if __name__ == "__main__":
    raise SystemExit(main())
