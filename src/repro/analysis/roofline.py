"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Terms per (arch x shape x mesh), TPU v5e constants from the brief:

    compute    = HLO_FLOPs            / (chips * 197e12 FLOP/s)
    memory     = HLO_bytes            / (chips * 819e9  B/s)
    collective = per-chip wire bytes  / (50e9 B/s per chip link budget)

``cost_analysis()`` of the partitioned module reports per-device FLOPs /
bytes, so compute and memory terms divide by the single-chip peaks.
Collective bytes are NOT in cost_analysis: we parse the compiled HLO text,
summing wire bytes per collective with ring-algorithm factors
((N-1)/N per all-gather / reduce-scatter pass, 2x for all-reduce) using
each op's replica-group size.
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / chip (one ICI link budget, conservative)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w+(?:\.\d+)?)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind, plus op counts.

    Shapes in the partitioned module are per-device, so each matched op
    contributes its per-device payload directly.
    """
    out: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    counts: Dict[str, int] = {k: 0 for k in out}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(2), m.group(3), m.group(4)
        if dtype == "tuple" or not dtype:
            continue
        size = _shape_bytes(dtype, dims)
        n = _group_size(m.group(0))
        if n <= 1:
            continue
        frac = (n - 1) / n
        if kind == "all-gather":
            wire = size * frac          # output is the gathered buffer
        elif kind == "reduce-scatter":
            wire = size                  # shape is the scattered output
        elif kind == "all-reduce":
            wire = 2 * size * frac
        elif kind == "all-to-all":
            wire = size * frac
        else:                            # collective-permute
            wire = size
        out[kind] += wire
        counts[kind] += 1
    out["total_wire_bytes"] = sum(v for k, v in out.items()
                                  if k != "total_wire_bytes")
    out["op_counts"] = counts  # type: ignore[assignment]
    return out


def model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params."""
    n = cfg.active_param_count()
    toks = shape.tokens if shape.kind != "decode" else shape.global_batch
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * toks


def roofline_terms(cost: Dict, coll: Dict, chips: int, cfg=None,
                   shape=None) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0) or 0.0)
    byts = float(cost.get("bytes accessed", 0.0) or 0.0)
    wire = float(coll.get("total_wire_bytes", 0.0))
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = wire / LINK_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    out = {
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(t_comp, t_mem, t_coll),
    }
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        out["model_flops_global"] = mf
        out["model_flops_per_chip"] = mf / chips
        out["useful_flop_ratio"] = (mf / chips) / flops if flops else 0.0
        # roofline fraction: useful work time at peak vs bound time
        ideal = (mf / chips) / PEAK_FLOPS
        out["roofline_fraction"] = ideal / out["bound_s"] if out["bound_s"] \
            else 0.0
    return out


def kernel_true_bytes(cfg, shape, chips: int) -> float:
    """Per-device HBM traffic of the Pallas kernels that replace the jnp
    inner loops on the TPU target (attention / WKV / RG-LRU): inputs +
    outputs only — block intermediates live in VMEM.

    fwd reads QKV + writes O (or r,k,v,w -> y); backward re-reads them and
    writes gradients: ~3.5 passes with remat."""
    toks_local = shape.tokens / chips if shape.kind != "decode" else \
        shape.global_batch / chips
    d = cfg.d_model
    passes = 3.5 if shape.kind == "train" else 1.0
    if cfg.family == "ssm":
        per_tok = 6 * d * 2                     # r,k,v,w,g,y bf16
    elif cfg.family == "hybrid":
        per_tok = 5 * cfg.rec_d_rnn * 2
    else:
        hd = cfg.hd
        per_tok = (2 * cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd) * 2
    n_layers = max(cfg.n_layers, 1)
    traffic = toks_local * per_tok * n_layers * passes
    if shape.kind == "decode":
        # decode additionally reads the whole KV cache / state once
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            cache = (shape.seq_len * 2 * cfg.n_kv_heads * cfg.hd * 2 *
                     n_layers * shape.global_batch / chips)
        elif cfg.family == "ssm":
            cache = (cfg.n_heads * cfg.hd * cfg.hd * 4 * n_layers *
                     shape.global_batch / chips)
        else:
            cache = ((cfg.window or 2048) * 2 * cfg.n_kv_heads * cfg.hd * 2 *
                     n_layers * shape.global_batch / chips)
        traffic += cache
    return traffic


def adjusted_terms(terms: Dict[str, float], tag_bytes: Dict[str, float],
                   cfg, shape, chips: int) -> Dict[str, float]:
    """Memory term with the jnp inner-loop traffic (attributed via HLO
    metadata) replaced by the Pallas kernels' true traffic.  Reported
    separately from the raw term (EXPERIMENTS.md §Dry-run bias note)."""
    attributed = sum(tag_bytes.values())
    measured = terms["memory_s"] * HBM_BW
    ktrue = kernel_true_bytes(cfg, shape, chips)
    adj_bytes = max(measured - attributed, 0.0) + ktrue
    t_mem = adj_bytes / HBM_BW
    bound = max(terms["compute_s"], t_mem, terms["collective_s"])
    out = {"memory_adjusted_s": t_mem,
           "attributed_kernel_bytes": attributed,
           "kernel_true_bytes": ktrue,
           "bound_adjusted_s": bound}
    if "model_flops_per_chip" in terms:
        ideal = terms["model_flops_per_chip"] / PEAK_FLOPS
        out["roofline_fraction_adjusted"] = ideal / bound if bound else 0.0
    return out


def summarize_memory(mem) -> Dict[str, float]:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = float(getattr(mem, k, 0) or 0)
    out["total_per_device_gb"] = (
        out.get("argument_size_in_bytes", 0) +
        out.get("temp_size_in_bytes", 0) -
        out.get("alias_size_in_bytes", 0)) / 1e9
    return out
