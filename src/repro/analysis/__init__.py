from . import hlo_stats, predict, roofline

__all__ = ["hlo_stats", "predict", "roofline"]
