"""Step-time prediction: dry-run artifacts -> ASTRA-sim-style simulation.

This is the paper's technique serving as the framework's performance-model
layer (DESIGN.md §2).  Two fidelity levels:

* ``predict_cell``        — closed-form: the three roofline terms plus
  collective times from the alpha-beta estimators over the InfraGraph TPU
  fabric, reported as no-overlap / perfect-overlap bounds;
* ``simulate_cell_fine``  — event-driven: build a Chakra-style per-layer
  trace (compute slice + the cell's dominant per-layer collective) and run
  it on the fine-grained Cluster at a scaled-down rank count, capturing
  contention + control-path latency that the closed form misses.
"""

from __future__ import annotations

from typing import Dict

from ..configs.base import SHAPES, get
from ..core.chakra import ExecutionTrace, TraceExecutor
from ..core.cluster import Cluster, NocConfig
from .roofline import LINK_BW, PEAK_FLOPS

ALPHA_ICI_NS = 1000.0     # per-hop collective launch latency (1 us)


def predict_cell(cell: Dict, overlap: bool = True) -> Dict[str, float]:
    """Closed-form step-time prediction from one dry-run JSON record."""
    rf = cell["roofline"]
    t_comp = max(rf["compute_s"], rf["memory_s"])
    # per-kind alpha-beta times: wire bytes already per-chip
    coll = cell["collectives"]
    counts = coll.get("op_counts", {})
    t_coll = 0.0
    for kind in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                 "collective-permute"):
        wire = coll.get(kind, 0.0)
        if not wire:
            continue
        t_coll += wire / LINK_BW
    # alpha term: one latency per collective op instance (counts are static
    # op counts; loop-carried ops fire once per layer — approximate with
    # the analyzer's multiplied byte totals over a mean op size)
    n_ops = sum(counts.values()) if counts else 16
    t_coll += n_ops * ALPHA_ICI_NS * 1e-9
    return {
        "t_compute_s": t_comp,
        "t_collective_s": t_coll,
        "step_no_overlap_s": t_comp + t_coll,
        "step_full_overlap_s": max(t_comp, t_coll),
        "tokens_per_s_no_overlap":
            _tokens(cell) / (t_comp + t_coll) if t_comp + t_coll else 0.0,
    }


def _tokens(cell: Dict) -> float:
    shape = SHAPES[cell["shape"]]
    return shape.global_batch * (shape.seq_len
                                 if shape.kind != "decode" else 1)


def simulate_cell_fine(cell: Dict, ranks: int = 8,
                       layers: int = 4) -> Dict[str, float]:
    """Fine-grained contention-aware mini-simulation of the cell's steady
    state: ``layers`` pipeline stages of (compute kernel -> collective) on
    ``ranks`` detailed GPUs, scaled so per-rank work matches the dry-run's
    per-chip numbers."""
    cfg = get(cell["arch"])
    rf = cell["roofline"]
    coll = cell["collectives"]
    # per-layer per-chip quantities
    n_layers = max(cfg.n_layers, 1)
    flops_layer = rf["compute_s"] * PEAK_FLOPS / n_layers
    wire_layer = coll.get("total_wire_bytes", 0.0) / n_layers
    et = ExecutionTrace(num_ranks=ranks)
    prev = {r: [] for r in range(ranks)}
    kind = "all_reduce" if coll.get("all-reduce", 0) >= \
        coll.get("all-gather", 0) else "all_gather"
    size = max(int(wire_layer), 4096)
    # cap the simulated volume so the event count stays CPU-friendly;
    # report the scale factor so times can be extrapolated
    cap = 1 << 20
    scale = max(1.0, size / cap)
    for li in range(layers):
        comps = {r: et.comp(r, f"L{li}.r{r}", flops=flops_layer / scale,
                            deps=prev[r]) for r in range(ranks)}
        colls = et.coll(li, kind, int(size / scale), "ring",
                        deps_by_rank={r: [comps[r]] for r in range(ranks)})
        prev = {r: [colls[r]] for r in range(ranks)}
    cl = Cluster(ranks, noc=NocConfig(mesh_x=2, mesh_y=2, cus_per_router=2,
                                      mem_channels=4, io_ports=4))
    res = TraceExecutor(et, cl, comp_workgroups=4, coll_workgroups=2).run()
    per_layer_ns = res.time_ns / layers
    return {
        "sim_time_per_layer_us": per_layer_ns / 1e3,
        "sim_scale_factor": scale,
        "extrapolated_step_s": per_layer_ns * scale * n_layers / 1e9,
        "events": res.events,
    }
