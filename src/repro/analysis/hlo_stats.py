"""HLO-text analyzer with while-loop trip-count multiplication.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — useless
for scan-over-layers models (verified by calibration; see EXPERIMENTS.md
§Dry-run).  This module parses the partitioned HLO text into computations,
builds the call graph (while bodies x trip count, fusions/calls x 1), and
accumulates:

  * ``flops``       — 2 * prod(out_dims) * contracted_size per dot (and a
    kernel-volume bound for convolutions);
  * ``bytes``       — operand + output bytes of every kernel-boundary
    instruction (fusions, dots, reduces, un-fused elementwise, collectives)
    — a standard HBM-traffic approximation;
  * ``collectives`` — wire bytes per collective kind with ring factors
    ((N-1)/N per AG/RS pass, 2x for AR) from each op's replica groups.

Operand shapes are resolved through a per-computation name -> shape table
(instruction results + typed header parameters), since this dump format
does not inline operand types.  Trip counts come from the loop condition's
``compare(iter, constant)``.  All numbers are per-device (the module is
the per-partition SPMD program).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([\d,]*)\]")
_CALL_ATTR_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_NEW_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*([a-z]+[0-9]*\[[\d,]*\])")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy-start", "copy-done", "after-all",
               "partition-id", "replica-id", "iota", "while", "conditional",
               "call", "custom-call", "copy",
               # layout/view ops: fused into neighbors on TPU, counting
               # them would double HBM traffic
               "reshape", "transpose", "broadcast", "convert", "slice"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _nelem(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _nelem(dims) * _DTYPE_BYTES.get(dtype, 4)


def _parse_instr(line: str):
    """-> (name, result_type_str, opcode, args_str) or None."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    # result type: tuple "(...)" or single token up to first space
    if rest.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype = rest[:i + 1]
        rest = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        rest = rest[sp + 1:]
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par].strip()
    # args: up to the matching ')'
    depth = 0
    end = par
    for j in range(par, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                end = j
                break
    args = rest[par + 1:end]
    attrs = rest[end + 1:]
    return name, rtype, opcode, args, attrs


class Computation:
    __slots__ = ("name", "header", "instrs", "shapes")

    def __init__(self, name: str, header: str):
        self.name = name
        self.header = header
        self.instrs: List[Tuple[str, str, str, str, str]] = []
        self.shapes: Dict[str, str] = {}   # name -> "dtype[dims]"


def _parse_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            st = line.strip()
            if st.endswith("{") and "->" in st and \
                    (st.startswith("%") or st.startswith("ENTRY")):
                is_entry = st.startswith("ENTRY")
                body = st[len("ENTRY"):].strip() if is_entry else st
                name = body.split()[0].lstrip("%")
                cur = Computation(name, st)
                if is_entry:
                    entry = name
                # typed parameters from the header
                for pn, ptype in _PARAM_RE.findall(st):
                    cur.shapes[pn] = ptype
        else:
            if line.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            parsed = _parse_instr(line)
            if parsed:
                name, rtype, opcode, args, attrs = parsed
                cur.instrs.append(parsed)
                if not rtype.startswith("("):
                    # strip layout {..}
                    m = _SHAPE_RE.match(rtype)
                    if m:
                        cur.shapes[name] = f"{m.group(1)}[{m.group(2)}]"
    if entry is None and comps:
        entry = max(comps, key=lambda n: len(comps[n].instrs))
    return comps, entry


def _operand_names(args: str) -> List[str]:
    out = []
    for tok in args.split(","):
        tok = tok.strip()
        if tok.startswith("%"):
            out.append(tok[1:])
        else:
            # possibly "f32[..] %name" (typed) — take trailing %name
            if "%" in tok:
                out.append(tok.split("%")[-1].strip())
    return out


def _lookup(comp: Computation, name: str) -> Optional[Tuple[str, str]]:
    t = comp.shapes.get(name)
    if t is None:
        return None
    m = _SHAPE_RE.match(t)
    return (m.group(1), m.group(2)) if m else None


def _group_size(attrs: str) -> int:
    m = _GROUPS_NEW_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD_RE.search(attrs)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> int:
    """Loop bound: the compare constant, searching through fusion bodies."""
    best = 1
    stack = [cond]
    seen = set()
    while stack:
        c = stack.pop()
        if c.name in seen:
            continue
        seen.add(c.name)
        for (_, rtype, opcode, args, attrs) in c.instrs:
            if opcode == "constant" and rtype.startswith("s32") and \
                    args.strip().isdigit():
                best = max(best, int(args.strip()))
            for v in _CONST_RE.findall(args + attrs):
                best = max(best, int(v))
            m = _CALL_ATTR_RE.search(attrs)
            if m and m.group(1) in comps:
                stack.append(comps[m.group(1)])
    return max(best, 1)


TAGS = ("wkv6_kernel", "attention_kernel", "rg_lru_kernel")


def _tag_of(attrs: str):
    if "op_name=" not in attrs:
        return None
    for t in TAGS:
        if t in attrs:
            return t
    return None


def analyze(text: str) -> Dict[str, object]:
    comps, entry = _parse_computations(text)

    per = {}
    children: Dict[str, List[Tuple[str, str]]] = defaultdict(list)
    fusion_bodies = set()
    coll_detail: List[Tuple[str, str, str, float]] = []  # (comp, op, shape, wire)
    dot_detail: List[Tuple[str, str, float]] = []        # (comp, shape, flops)
    tag_bytes_local: Dict[str, Dict[str, float]] = defaultdict(
        lambda: defaultdict(float))                      # comp -> tag -> bytes
    for comp in comps.values():
        flops = 0.0
        byts = 0.0
        coll: Dict[str, float] = defaultdict(float)
        unresolved = 0
        for (name, rtype, opcode, args, attrs) in comp.instrs:
            if opcode == "dot":
                shapes = _SHAPE_RE.findall(rtype)
                out_elems = _nelem(shapes[0][1]) if shapes else 0
                ops = _operand_names(args)
                contracted = 1
                mc = _CONTRACT_RE.search(attrs)
                lhs = _lookup(comp, ops[0]) if ops else None
                if lhs and mc is not None:
                    dims = [int(x) for x in lhs[1].split(",")] if lhs[1] \
                        else []
                    for idx in (mc.group(1).split(",") if mc.group(1)
                                else []):
                        i = int(idx)
                        if i < len(dims):
                            contracted *= dims[i]
                else:
                    unresolved += 1
                f = 2.0 * out_elems * contracted
                flops += f
                dot_detail.append((comp.name, rtype[:48], f))
                byts += sum(_shape_bytes(dt, dm)
                            for dt, dm in _SHAPE_RE.findall(rtype))
                for o in ops:
                    s = _lookup(comp, o)
                    if s:
                        byts += _shape_bytes(*s)
            elif opcode == "convolution":
                shapes = _SHAPE_RE.findall(rtype)
                out_elems = _nelem(shapes[0][1]) if shapes else 0
                ops = _operand_names(args)
                ker = _lookup(comp, ops[1]) if len(ops) > 1 else None
                flops += 2.0 * out_elems * (_nelem(ker[1]) if ker else 1)
            elif opcode in COLLECTIVES:
                shapes = _SHAPE_RE.findall(rtype)
                size = sum(_shape_bytes(dt, dm) for dt, dm in shapes)
                n = _group_size(attrs)
                if n > 1:
                    frac = (n - 1) / n
                    wire = {"all-gather": size * frac,
                            "reduce-scatter": size,
                            "all-reduce": 2 * size * frac,
                            "all-to-all": size * frac,
                            "collective-permute": size}[opcode]
                    coll[opcode] += wire
                    coll_detail.append((comp.name, opcode, rtype, wire))
                byts += size
            elif opcode == "while":
                mb = _CALL_ATTR_RE.search(attrs)
                mc = _COND_ATTR_RE.search(attrs)
                if mb:
                    children[comp.name].append(
                        (mb.group(1), "while:" + (mc.group(1) if mc else "")))
            elif opcode == "fusion":
                m = _CALL_ATTR_RE.search(attrs)
                if m:
                    children[comp.name].append((m.group(1), "fusion"))
                    fusion_bodies.add(m.group(1))
                bb = sum(_shape_bytes(dt, dm)
                         for dt, dm in _SHAPE_RE.findall(rtype))
                for o in _operand_names(args):
                    s = _lookup(comp, o)
                    if s:
                        bb += _shape_bytes(*s)
                byts += bb
                t = _tag_of(attrs)
                if t:
                    tag_bytes_local[comp.name][t] += bb
            elif opcode == "call":
                m = _CALL_ATTR_RE.search(attrs)
                if m:
                    children[comp.name].append((m.group(1), "call"))
            elif opcode == "conditional":
                for b in _BRANCH_RE.findall(attrs):
                    for nm in b.split(","):
                        children[comp.name].append(
                            (nm.strip().lstrip("%"), "cond"))
            elif opcode in ("reduce", "sort", "scatter", "gather",
                            "dynamic-slice", "dynamic-update-slice",
                            "select-and-scatter", "pad", "concatenate",
                            "broadcast", "reshape", "transpose", "slice",
                            "reverse", "reduce-window") or \
                    opcode not in _SKIP_BYTES:
                bb = sum(_shape_bytes(dt, dm)
                         for dt, dm in _SHAPE_RE.findall(rtype))
                for o in _operand_names(args):
                    s = _lookup(comp, o)
                    if s:
                        bb += _shape_bytes(*s)
                byts += bb
                t = _tag_of(attrs)
                if t:
                    tag_bytes_local[comp.name][t] += bb
        per[comp.name] = (flops, byts, dict(coll), unresolved)

    # propagate multipliers in topological order (parents first) so late
    # increments from a second caller still reach grandchildren
    topo: List[str] = []
    state: Dict[str, int] = {}
    stack = [(entry, iter([c for c, _ in children.get(entry, [])]))]
    state[entry] = 1
    while stack:
        node, it = stack[-1]
        advanced = False
        for child in it:
            if state.get(child, 0) == 0:
                state[child] = 1
                stack.append(
                    (child, iter([c for c, _ in children.get(child, [])])))
                advanced = True
                break
        if not advanced:
            topo.append(node)
            stack.pop()
    topo.reverse()

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    for name in topo:
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for child, kind in children.get(name, []):
            if kind.startswith("while:"):
                cond_name = kind.split(":", 1)[1]
                cond = comps.get(cond_name)
                factor = float(_trip_count(cond, comps)) if cond else 1.0
            else:
                factor = 1.0
            mult[child] += m * factor

    totals_f = 0.0
    totals_b = 0.0
    coll_tot: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    tag_bytes: Dict[str, float] = defaultdict(float)
    unresolved = 0
    for name, (f, b, c, u) in per.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        # fusion bodies: their bytes are internal to the fused kernel — the
        # caller already counted the fusion's operand/result traffic.  Their
        # dots (output fusions) still count.
        totals_f += m * f
        if name not in fusion_bodies:
            totals_b += m * b
            for t, tb in tag_bytes_local.get(name, {}).items():
                tag_bytes[t] += m * tb
        for k, v in c.items():
            coll_tot[k] += m * v
        unresolved += u
    coll_tot["total_wire_bytes"] = sum(coll_tot[k] for k in COLLECTIVES)
    detail = sorted(((cn, op, sh, w * mult.get(cn, 0.0))
                     for cn, op, sh, w in coll_detail),
                    key=lambda t: -t[3])
    dots = sorted(((cn, sh, f * mult.get(cn, 0.0))
                   for cn, sh, f in dot_detail), key=lambda t: -t[2])
    return {"flops": totals_f, "bytes": totals_b, "collectives": coll_tot,
            "tag_bytes": dict(tag_bytes),
            "num_computations": len(comps), "entry": entry,
            "unresolved_dots": unresolved,
            "coll_top": [(op, sh[:60], round(w / 1e9, 2))
                         for cn, op, sh, w in detail[:12]],
            "flops_top": [(cn[:28], sh, round(f / 1e12, 2))
                          for cn, sh, f in dots[:14]]}
