"""AdamW with optional int8 gradient compression (error feedback).

State layout mirrors the parameter pytree, so whatever sharding the
parameters carry, the optimizer states inherit (ZeRO-style sharded states
under the FSDP parameter sharding of distributed/sharding.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {"m": jax.tree.unflatten(tdef, [o[1] for o in out]),
                 "v": jax.tree.unflatten(tdef, [o[2] for o in out]),
                 "step": step}
    return new_p, new_state, {"grad_norm": gnorm}


# ---------------------------------------------------------------------------
# gradient compression (int8 + error feedback) — distributed-optimization
# trick toggled by distributed/sharding.py
# ---------------------------------------------------------------------------

def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_grads(grads, residuals):
    """Quantize grads with error feedback; returns (decompressed, new_res).

    In a shard_map DP loop the int8 tensors are what crosses the network;
    under plain pjit this still shrinks the all-reduce payload when placed
    around the gradient reduction.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = compress_int8(gf)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
