from .adamw import (AdamWConfig, apply_updates, compress_int8,
                    compressed_grads, decompress_int8, init_residuals,
                    init_state)

__all__ = ["AdamWConfig", "apply_updates", "init_state", "compress_int8",
           "decompress_int8", "compressed_grads", "init_residuals"]
