"""Fault-tolerant checkpointing with elastic restart (DESIGN.md §6).

* sharded save: each host writes its local shards as npz + a JSON manifest
  (here: single-host, full tree) — atomic via tmp + rename;
* keep-N rotation, crash-consistent (a partial write never shadows the
  previous checkpoint);
* **elastic restore**: the manifest records only the *global* array shapes,
  so a checkpoint written under one mesh restores onto any other mesh —
  resharding happens on load via jax.device_put with the new sharding.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        out = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (k,)))
        return out
    if isinstance(tree, (list, tuple)):
        out = {}
        for i, v in enumerate(tree):
            out.update(_flatten(v, prefix + (str(i),)))
        return out
    return {"/".join(prefix): tree}


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


class CheckpointManager:
    """save(step, tree) / restore(step|latest, shardings) with keep-N."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> str:
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
            return self._path(step)
        return self._write(step, host)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _write(self, step: int, host: Dict[str, np.ndarray]) -> str:
        final = self._path(step)
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step,
                "arrays": {k: {"shape": list(v.shape),
                               "dtype": str(v.dtype)}
                           for k, v in host.items()},
                "format": 1,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._rotate()
        return final

    def _rotate(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings=None):
        """Load a checkpoint; ``shardings`` (same pytree structure, or None)
        reshard onto the *current* mesh — elastic restart."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._path(step)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {k: data[k] for k in data.files}
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)
            tree = _unflatten({
                k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                for k, v in flat.items()})
        return step, tree
