"""In-model sharding constraints (GSPMD hints).

Model code calls ``constrain(x, "dp", None, "model")`` at layer boundaries;
when no mesh is active (CPU smoke tests) this is a no-op.  The dry-run and
distributed tests install the mesh via ``set_mesh``.

Axis tokens: "dp" = (pod, data) batch axes; "data"; "model"; None.  Tokens
are dropped automatically when the mesh lacks the axis or the dimension is
not divisible — so one call site serves every (arch, mesh) combination.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MESH: Optional[Mesh] = None
PLAN: str = "default"     # "default" (DPxTP) | "fsdp" (pure data parallel)


def set_mesh(mesh: Optional[Mesh]) -> None:
    global MESH
    MESH = mesh


def set_plan(plan: str) -> None:
    """"default": Megatron-style DPxTP.  "fsdp": weights/optimizer fully
    sharded over (data, model) treated as one big DP axis; activations
    sequence-parallel over 'model'; feature-dim TP disabled."""
    global PLAN
    PLAN = plan


@contextmanager
def use_mesh(mesh: Mesh):
    prev = MESH
    set_mesh(mesh)
    try:
        yield
    finally:
        set_mesh(prev)


def _resolve(ax, dim: int, mesh: Mesh):
    if ax is None:
        return None
    # plan-aware token translation:
    #   "model": feature-dim TP  -> dropped under fsdp
    #   "sp":    seq dim         -> 'model' under fsdp, unsharded by default
    #   "spm":   seq dim         -> 'model' under both plans
    if ax == "sp":
        ax = "model" if PLAN == "fsdp" else None
        if ax is None:
            return None
    elif ax == "spm":
        ax = "model"
    elif ax == "rep":
        return "PINNED_REPLICATED"
    elif ax == "model" and PLAN == "fsdp":
        return "PINNED_REPLICATED"
    if ax == "dp":
        ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    elif isinstance(ax, str):
        ax = (ax,) if ax in mesh.axis_names else ()
    else:
        ax = tuple(a for a in ax if a in mesh.axis_names)
    if not ax:
        return None
    size = int(np.prod([mesh.shape[a] for a in ax]))
    if dim % size != 0:
        # try the prefix that divides (e.g. "dp" -> just "data")
        for cut in range(len(ax) - 1, 0, -1):
            sub = ax[:cut]
            s = int(np.prod([mesh.shape[a] for a in sub]))
            if dim % s == 0:
                return sub if len(sub) > 1 else sub[0]
        return None
    return ax if len(ax) > 1 else ax[0]


def dp_size() -> int:
    """Product of the active mesh's data-parallel axis sizes (1 if none)."""
    if MESH is None:
        return 1
    return int(np.prod([MESH.shape[a] for a in ("pod", "data")
                        if a in MESH.axis_names]))


def divides(axis: str, n: int) -> bool:
    """True iff the active mesh has ``axis``, the plan keeps it, and it
    divides ``n``."""
    if MESH is None or axis not in MESH.axis_names:
        return False
    if PLAN == "fsdp" and axis == "model":
        return False       # feature-dim TP disabled under pure FSDP
    return n % MESH.shape[axis] == 0


def constrain(x, *axes):
    """with_sharding_constraint that pins ONLY the named axes.

    Dims given as None (or whose token is dropped by the plan /
    indivisibility) stay UNCONSTRAINED so GSPMD propagation remains free —
    pinning them to replicated would actively fight useful shardings
    (measured: a replicated-sequence MLP cost 16x flops under the fsdp
    plan before this used UNCONSTRAINED).
    """
    if MESH is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"constrain: {len(axes)} axes for rank-{x.ndim}")
    resolved = [_resolve(a, d, MESH) for a, d in zip(axes, x.shape)]
    if all(r is None for r in resolved):
        return x
    spec = P(*[None if r == "PINNED_REPLICATED" else
               (r if r is not None else P.UNCONSTRAINED)
               for r in resolved])
    return jax.lax.with_sharding_constraint(x, NamedSharding(MESH, spec))
