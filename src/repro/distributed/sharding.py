"""Sharding plans: map every parameter / batch / cache leaf to a
PartitionSpec over the production mesh (DESIGN.md §6).

Plan summary (axes: optional 'pod' (pure DP), 'data' (DP/FSDP), 'model'
(TP/EP/SP)):

* weights — Megatron pattern: column-parallel matrices shard their output
  dim over 'model', row-parallel their input dim; the other large dim
  shards over 'data' (FSDP / ZeRO-3: GSPMD all-gathers per layer inside the
  scan).  Optimizer moments inherit parameter specs => ZeRO sharded states.
* expert weights — grok "tp": per-expert hidden over 'model';
  moonshot "ep": expert dim over 'model' (all-to-all dispatch).
* batch — (pod, data) on the batch dim.
* decode caches — batch over 'data' when divisible, sequence/window over
  'model' (sequence-parallel decode: softmax partials are the only
  cross-device traffic).
"""

from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig

# parameter-name classification
_COL_PARALLEL = {"wq", "wk", "wv", "wg", "wu", "cm_k", "w_x", "w_y", "w_a",
                 "w_i", "wr", "cm_r", "maa_w1", "dec_w1"}
_ROW_PARALLEL = {"wo", "wd", "cm_v", "w_out"}


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh: Mesh, axis) -> bool:
    if isinstance(axis, tuple):
        size = int(np.prod([mesh.shape[a] for a in axis]))
    else:
        size = mesh.shape[axis]
    return n % size == 0


def _maybe(axis, dim: int, mesh: Mesh):
    """Use ``axis`` only if it divides dim."""
    return axis if _div(dim, mesh, axis) else None


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ArchConfig, mesh: Mesh, plan: str = "default") -> P:
    """Spec for one parameter leaf, given its dict path and shape."""
    name = path[-1]
    if plan == "fsdp":
        return _fsdp_spec(path, shape, mesh)
    stacked = path[0] in ("layers", "macro", "tail", "enc", "dec") or \
        (len(path) > 1 and path[0] in ("rec1", "rec2", "attn"))
    lead = (None,) if stacked and len(shape) >= 2 else ()
    body = shape[1:] if lead else shape
    nd = len(body)

    # expert tensors (E, D, F) / (E, F, D)
    if name in ("wg", "wu", "wd") and nd == 3 and cfg.moe:
        E, a, b = body
        if cfg.moe.sharding == "ep":
            return P(*lead, _maybe("model", E, mesh),
                     _maybe("data", a, mesh), None)
        return P(*lead, None, _maybe("data", a, mesh),
                 _maybe("model", b, mesh))
    if name == "router" and nd == 2:
        return P(*lead, _maybe("data", body[0], mesh), None)
    if name == "embed":
        return P(_maybe("model", shape[0], mesh),
                 _maybe("data", shape[1], mesh))
    if name == "lm_head":
        return P(_maybe("data", shape[0], mesh),
                 _maybe("model", shape[1], mesh))
    if name == "connector":
        return P(_maybe("data", shape[0], mesh),
                 _maybe("model", shape[1], mesh))
    if nd == 2:
        a, b = body
        if name in _ROW_PARALLEL:
            return P(*lead, _maybe("model", a, mesh), _maybe("data", b, mesh))
        if name in _COL_PARALLEL:
            return P(*lead, _maybe("data", a, mesh), _maybe("model", b, mesh))
        # misc 2-D (loras, conv weights, bonus): shard the bigger dim on data
        if a >= b:
            return P(*lead, _maybe("data", a, mesh), None)
        return P(*lead, None, _maybe("data", b, mesh))
    if nd == 1 and body[0] >= 4096:
        return P(*lead, _maybe("model", body[0], mesh))
    if nd == 0:
        return P()
    return P(*lead, *([None] * nd))


def _fsdp_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               mesh: Mesh) -> P:
    """Pure FSDP: shard the largest dimension over the whole (data, model)
    device plane; everything else replicated (ZeRO-3)."""
    if not shape:
        return P()
    big = max(range(len(shape)), key=lambda i: shape[i])
    axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    spec = [None] * len(shape)
    if shape[big] % size == 0:
        spec[big] = axes
    elif shape[big] % mesh.shape.get("data", 1) == 0 and "data" in \
            mesh.axis_names:
        spec[big] = "data"
    return P(*spec)


def _tree_specs_with_path(tree, fn):
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(t)
        return fn(path, node)
    return walk(tree, ())


def params_specs(abstract_params, cfg: ArchConfig, mesh: Mesh,
                 plan: str = "default"):
    return _tree_specs_with_path(
        abstract_params,
        lambda p, leaf: param_spec(p, leaf.shape, cfg, mesh, plan))


def state_specs(abstract_state, cfg: ArchConfig, mesh: Mesh,
                plan: str = "default"):
    """Specs for the full train state: optimizer moments inherit the
    parameter layout (ZeRO); step counter replicated."""
    out = {}
    for key, sub in abstract_state.items():
        if key == "params":
            out[key] = params_specs(sub, cfg, mesh, plan)
        elif key == "opt":
            out[key] = {
                "m": params_specs(sub["m"], cfg, mesh, plan),
                "v": params_specs(sub["v"], cfg, mesh, plan),
                "step": P(),
            }
        elif key == "residual":
            out[key] = params_specs(sub, cfg, mesh, plan)
        else:
            out[key] = _tree_specs_with_path(sub, lambda p, l: P())
    return out


def batch_specs(abstract_batch, cfg: ArchConfig, mesh: Mesh,
                plan: str = "default"):
    """Batch dim over (pod, data); under fsdp additionally sequence over
    'model' (sequence-parallel inputs)."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        b = leaf.shape[0]
        ax = dp if _div(b, mesh, dp) else \
            ("data",) if _div(b, mesh, "data") else None
        rest = [None] * (len(leaf.shape) - 1)
        if plan == "fsdp" and len(leaf.shape) >= 2 and \
                _div(leaf.shape[1], mesh, "model"):
            rest[0] = "model"
        return P(ax, *rest)

    return _tree_specs_with_path(abstract_batch, one)


def cache_specs(abstract_cache, cfg: ArchConfig, mesh: Mesh):
    """Decode caches: batch over 'data' if divisible; the long axis
    (cache sequence / window / state heads) over 'model'."""

    def one(path, leaf):
        shape = leaf.shape
        name = path[-1]
        if len(shape) == 0:
            return P()
        spec = [None] * len(shape)
        # leading L (stacked layers) then batch
        bdim = 1 if len(shape) >= 2 else 0
        if _div(shape[bdim], mesh, "data"):
            spec[bdim] = "data"
        if name in ("k", "v", "mk", "mv") and len(shape) == 5:
            if _div(shape[2], mesh, "model"):
                spec[2] = "model"          # cache sequence (SP decode)
        elif name == "S" and len(shape) == 5:
            if _div(shape[2], mesh, "model"):
                spec[2] = "model"          # rwkv heads
        elif name in ("h", "conv", "x_tm", "x_cm"):
            if _div(shape[-1], mesh, "model"):
                spec[-1] = "model"         # feature dim
        return P(*spec)

    return _tree_specs_with_path(abstract_cache, one)


def to_named(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def with_sharding(abstract_tree, spec_tree, mesh: Mesh):
    """Attach shardings to ShapeDtypeStructs (for .lower())."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        abstract_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
