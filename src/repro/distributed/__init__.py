from . import hints, sharding
from .checkpoint import CheckpointManager

__all__ = ["hints", "sharding", "CheckpointManager"]
