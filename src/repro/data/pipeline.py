"""Synthetic sharded data pipeline with prefetch + straggler mitigation.

Production data loading concerns modeled here:
  * deterministic, restart-safe iteration (the step index fully determines
    the batch — resuming from a checkpoint replays nothing and skips
    nothing);
  * host-sharded generation (each host materializes only its slice);
  * background prefetch with a bounded queue;
  * straggler mitigation: a slow generation is detected by timeout and the
    batch is re-synthesized from the deterministic seed (safe because
    generation is pure).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig, ShapeConfig
from ..models import api


@dataclass
class PipelineConfig:
    prefetch: int = 2
    host_count: int = 1
    host_index: int = 0
    straggler_timeout_s: float = 30.0


class TokenPipeline:
    """Deterministic synthetic LM batches, host-sharded on the batch dim."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig,
                 pipe: Optional[PipelineConfig] = None, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.pipe = pipe or PipelineConfig()
        self.seed = seed
        self._q: "queue.Queue" = queue.Queue(maxsize=self.pipe.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._next_step = 0

    # ------------------------------------------------------------- generation
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host) — restart-safe."""
        b = api.make_batch(self.cfg, self.shape,
                           seed=hash((self.seed, step)) % (1 << 31))
        hc, hi = self.pipe.host_count, self.pipe.host_index
        if hc > 1:
            out = {}
            for k, v in b.items():
                n = v.shape[0]
                sl = slice(hi * n // hc, (hi + 1) * n // hc)
                out[k] = v[sl]
            return out
        return b

    # --------------------------------------------------------------- prefetch
    def start(self, from_step: int = 0) -> None:
        self._next_step = from_step
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._next_step
        while not self._stop.is_set():
            batch = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> Dict[str, np.ndarray]:
        """Next prefetched batch; on straggler timeout, regenerate inline."""
        deadline = time.monotonic() + self.pipe.straggler_timeout_s
        while time.monotonic() < deadline:
            try:
                step, batch = self._q.get(timeout=0.25)
                self._next_step = step + 1
                return batch
            except queue.Empty:
                if self._thread is None or not self._thread.is_alive():
                    break
        # straggler path: deterministic re-synthesis
        batch = self.batch_at(self._next_step)
        self._next_step += 1
        return batch

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self._thread is None:
            self.start(self._next_step)
        while True:
            yield self.get()
