"""train_step / serve_step factories — the jit roots of the framework.

These are what ``launch/dryrun.py`` lowers for every (arch x shape x mesh)
cell and what ``launch/train.py`` runs for real on CPU smoke scales.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import api
from ..optim import adamw
from .loss import chunked_xent


def loss_fn(params, cfg: ArchConfig, batch: Dict, aux_weight: float = 0.01,
            remat: bool = True) -> Tuple[jnp.ndarray, Dict]:
    h, aux = api.forward_hidden(params, cfg, batch, remat=remat)
    w = api.lm_head(params, cfg)
    nll = chunked_xent(h, w, batch["labels"])
    loss = nll + aux_weight * aux
    return loss, {"nll": nll, "aux": aux}


def make_train_step(cfg: ArchConfig, opt: adamw.AdamWConfig,
                    compress_grads: bool = False, remat: bool = True):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", ["residual"]}.
    """

    def train_step(state, batch):
        grad_fn = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat), has_aux=True)
        (loss, parts), grads = grad_fn(state["params"])
        if compress_grads:
            grads, new_res = adamw.compressed_grads(grads, state["residual"])
        new_p, new_opt, om = adamw.apply_updates(state["params"], grads,
                                                 state["opt"], opt)
        new_state = {"params": new_p, "opt": new_opt}
        if compress_grads:
            new_state["residual"] = new_res
        metrics = {"loss": loss, **parts, **om}
        return new_state, metrics

    return train_step


def make_serve_step(cfg: ArchConfig):
    """decode: serve_step(params, cache, token, pos) -> (logits, cache)."""

    def serve_step(params, cache, token, pos):
        return api.decode_step(params, cfg, token, pos, cache)

    return serve_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return api.prefill(params, cfg, batch)

    return prefill_step


def init_train_state(key, cfg: ArchConfig, compress_grads: bool = False):
    params = api.init_params(key, cfg)
    state = {"params": params, "opt": adamw.init_state(params)}
    if compress_grads:
        state["residual"] = adamw.init_residuals(params)
    return state
