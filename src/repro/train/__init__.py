from .loss import chunked_xent
from .step import (init_train_state, loss_fn, make_prefill_step,
                   make_serve_step, make_train_step)

__all__ = ["chunked_xent", "init_train_state", "loss_fn", "make_train_step",
           "make_serve_step", "make_prefill_step"]
