"""Sequence-chunked softmax cross-entropy.

Materializing (B, S, V) logits for a 128k vocabulary at 4k sequence is
~17 GB/device — so the loss scans over sequence chunks, computing each
chunk's logits -> logsumexp -> label logit and discarding them.  Backward
recomputes per chunk (the scan is rematerialized), keeping live memory at
(B, chunk, V / model_shards).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..distributed import hints


def chunked_xent(h: jnp.ndarray, w_vocab: jnp.ndarray, labels: jnp.ndarray,
                 chunk: int = 16, tokens_per_chunk: int = 65_536
                 ) -> jnp.ndarray:
    """h: (B, S, D); w_vocab: (D, V); labels: (B, S) -> mean NLL (f32).

    Chunks over the BATCH dim (not sequence): batch is data-sharded and the
    sequence dim stays intact inside each chunk, so the logits chunk keeps
    both the data sharding (B) and any sequence sharding (S under the fsdp
    plan) — chunking over S would break sequence sharding and replicate the
    vocab matmul over the model axis."""
    B, S, D = h.shape
    dp = hints.dp_size()
    if B % dp:
        dp = 1
    bl = B // dp                       # per-device rows
    per_chunk = min(bl, max(1, tokens_per_chunk // S))
    nc = max(1, bl // per_chunk)
    while bl % nc:
        nc -= 1
    # scan must iterate an UNSHARDED axis: split B = (dp, nc, rest) and
    # bring nc to the front; dp (the sharded factor) stays inside each
    # chunk, so the vocab matmul keeps its batch sharding
    rest = bl // nc
    hc = h.reshape(dp, nc, rest, S, D).transpose(1, 0, 2, 3, 4) \
         .reshape(nc, dp * rest, S, D)
    lc = labels.reshape(dp, nc, rest, S).transpose(1, 0, 2, 3) \
        .reshape(nc, dp * rest, S)

    def body(acc, xs):
        hb, lb = xs                                   # (c, S, D), (c, S)
        logits = (hb @ w_vocab).astype(jnp.float32)   # (c, S, V)
        logits = hints.constrain(logits, "dp", "sp", "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        mask = (lb >= 0).astype(jnp.float32)
        nll = (lse - ll) * mask
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mask)), None

    fn = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(fn, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
