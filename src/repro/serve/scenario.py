"""Compile serving scenarios into ExecutionTraces.

Two serving topologies from the ASTRA-sim line of work:

* :func:`continuous_batching` — one TP group decodes a changing batch:
  per-iteration compute (roofline flops/bytes from the model config) plus
  a tensor-parallel all-reduce, batch membership evolving as requests
  arrive and finish.  Request arrival releases an iteration via
  ``start_after_ns`` — the deferred-start mechanism every tier honors —
  so arrival jitter propagates through interpreter semaphores instead of
  being flattened away.
* :func:`disaggregated` — dedicated prefill ranks and decode ranks
  (ASTRA-sim 2.0's serving topology): per request, a prefill compute
  node, a KV-cache point-to-point transfer collective between the chosen
  prefill and decode rank, and a decode compute node tagged with the
  request id for latency extraction.

The *plan* (which requests join which iteration, which rank serves which
request) is fixed at build time from a deterministic roofline estimate,
so a scenario is a plain static trace every fidelity tier runs
identically-shaped; the *timing* is whatever the tier simulates.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ..core.chakra import ExecutionTrace
from .metrics import attach_latency
from .traffic import Request

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8}


@dataclass(frozen=True)
class ServingModel:
    """Per-token serving costs of one model (all the scenario builders
    need; derive from an :class:`~repro.configs.base.ArchConfig` via
    :meth:`from_arch` or specify directly)."""
    name: str
    #: decode flops per generated token (≈ 2 * active params)
    flops_per_token: float
    #: weight bytes streamed per decode iteration (amortized over batch)
    weight_bytes: float
    #: tensor-parallel all-reduce payload per token (activations)
    coll_bytes_per_token: int
    #: KV-cache bytes per prompt token (prefill -> decode handoff)
    kv_bytes_per_token: int
    #: prefill flops per prompt token (defaults to flops_per_token)
    prefill_flops_per_token: float = 0.0

    def __post_init__(self):
        if self.prefill_flops_per_token <= 0:
            object.__setattr__(self, "prefill_flops_per_token",
                               self.flops_per_token)

    @staticmethod
    def from_arch(arch, dtype_bytes: Optional[int] = None) -> "ServingModel":
        """Derive serving costs from an ArchConfig (Megatron-style TP:
        two activation all-reduces per layer)."""
        db = dtype_bytes or _DTYPE_BYTES.get(arch.dtype, 2)
        p = arch.active_param_count()
        return ServingModel(
            name=arch.name,
            flops_per_token=2.0 * p,
            weight_bytes=float(p) * db,
            coll_bytes_per_token=2 * arch.n_layers * arch.d_model * db,
            kv_bytes_per_token=2 * arch.n_layers * arch.n_kv_heads
            * arch.hd * db)


@dataclass(frozen=True)
class _Plan:
    """Roofline constants for build-time admission/placement planning —
    deterministic estimates only; actual timing comes from the tier that
    runs the trace.  Defaults mirror ``CoarseConfig``."""
    flops_per_ns: float = 16384.0
    local_GBps: float = 1099.5
    link_GBps: float = 34.36 * 8
    link_lat_ns: float = 1000.0

    def comp_ns(self, flops: float, bytes_moved: float) -> float:
        return max(flops / self.flops_per_ns,
                   bytes_moved / self.local_GBps, 1.0)

    def all_reduce_ns(self, per_rank_bytes: int, nranks: int) -> float:
        if nranks < 2:
            return 0.0
        steps = 2 * (nranks - 1)
        return steps * (self.link_lat_ns
                        + per_rank_bytes / nranks / self.link_GBps)

    def p2p_ns(self, size_bytes: int) -> float:
        return self.link_lat_ns + size_bytes / self.link_GBps


@dataclass
class ServingScenario:
    """A compiled serving workload: the trace, its request stream, and
    build metadata.  ``simulate()`` runs it at any tier and attaches
    per-request :class:`~repro.serve.metrics.LatencyStats` to the
    result's ``latency`` field."""
    name: str
    trace: ExecutionTrace
    requests: List[Request]
    meta: Dict[str, object] = field(default_factory=dict)

    def simulate(self, infra=None, fidelity: str = "coarse", **kwargs):
        from ..core.backends import simulate as _simulate
        result = _simulate(self.trace, infra, fidelity=fidelity, **kwargs)
        attach_latency(self.trace, self.requests, result)
        return result


def continuous_batching(model: ServingModel, requests: List[Request],
                        tp: int = 4, tokens_per_iteration: int = 8,
                        max_batch: int = 16, algorithm: str = "ring",
                        plan: Optional[_Plan] = None,
                        name: str = "") -> ServingScenario:
    """Continuous-batching decode on one ``tp``-way tensor-parallel group.

    Each iteration is one comp node per rank (batch flops / TP share of
    the weights) chained into a TP all-reduce; requests join the batch at
    the first iteration after their arrival (release enforced by
    ``start_after_ns`` on the comp nodes) and leave when their decode
    budget is generated.  The all-reduce halves of a request's final
    iteration carry its ``req_done`` tag, so its latency is the moment
    the *last rank* finishes that iteration.
    """
    if tp < 2:
        raise ValueError(f"continuous batching needs tp >= 2, got {tp}")
    if tokens_per_iteration < 1:
        raise ValueError(f"tokens_per_iteration must be >= 1, "
                         f"got {tokens_per_iteration}")
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    pl = plan or _Plan()
    et = ExecutionTrace(num_ranks=tp)
    queue = sorted(requests, key=lambda r: (r.arrival_ns, r.req_id))
    remaining: Dict[int, int] = {}          # req_id -> decode tokens left
    prev_halves = None
    est_now, qi, it = 0.0, 0, 0
    while qi < len(queue) or remaining:
        if not remaining and queue[qi].arrival_ns > est_now:
            est_now = queue[qi].arrival_ns  # idle: jump to next arrival
        admitted = []
        while qi < len(queue) and len(remaining) < max_batch \
                and queue[qi].arrival_ns <= est_now:
            r = queue[qi]
            qi += 1
            remaining[r.req_id] = r.decode_tokens
            admitted.append(r)
        total_toks = sum(min(tokens_per_iteration, left)
                         for left in remaining.values())
        release = max((r.arrival_ns for r in admitted), default=0.0)
        flops = total_toks * model.flops_per_token / tp
        bytes_moved = model.weight_bytes / tp
        comp = [et.comp(rank, f"decode.it{it}.r{rank}", flops=flops,
                        bytes_moved=bytes_moved,
                        deps=[prev_halves[rank]] if prev_halves else None,
                        start_after_ns=release)
                for rank in range(tp)]
        finished = sorted(rid for rid, left in remaining.items()
                          if left <= tokens_per_iteration)
        coll_bytes = max(1, int(total_toks * model.coll_bytes_per_token))
        halves = et.coll(it, "all_reduce", coll_bytes, algorithm,
                         deps_by_rank={rank: [comp[rank]]
                                       for rank in range(tp)},
                         name=f"tp_ar.it{it}")
        for h in halves:
            h.req_done = list(finished)
        for rid in finished:
            del remaining[rid]
        for rid in remaining:
            remaining[rid] -= tokens_per_iteration
        est_now = max(est_now, release) \
            + pl.comp_ns(flops, bytes_moved) \
            + pl.all_reduce_ns(coll_bytes, tp)
        prev_halves = halves
        it += 1
    return ServingScenario(
        name=name or f"continuous_batching[{model.name},tp={tp}]",
        trace=et, requests=list(requests),
        meta={"model": model.name, "tp": tp, "iterations": it,
              "tokens_per_iteration": tokens_per_iteration,
              "max_batch": max_batch, "algorithm": algorithm})


def disaggregated(model: ServingModel, requests: List[Request],
                  prefill_ranks: int = 2, decode_ranks: int = 2,
                  plan: Optional[_Plan] = None,
                  name: str = "") -> ServingScenario:
    """Disaggregated prefill/decode serving.

    Ranks ``0..prefill_ranks-1`` prefill, the rest decode.  Per request:
    a prefill comp node on the least-loaded prefill rank (released at the
    request's arrival), a KV-cache p2p transfer to the least-loaded
    decode rank, and a decode comp node (memory-bound: the whole decode
    stream for batch size 1) tagged ``req_done``.  Work on one rank is
    chained, so placement is a real queueing decision.
    """
    if prefill_ranks < 1 or decode_ranks < 1:
        raise ValueError(f"need >= 1 prefill and decode rank, got "
                         f"{prefill_ranks}/{decode_ranks}")
    pl = plan or _Plan()
    et = ExecutionTrace(num_ranks=prefill_ranks + decode_ranks)
    pre_busy = [0.0] * prefill_ranks       # estimated rank-free times
    dec_busy = [0.0] * decode_ranks
    pre_last = [None] * prefill_ranks      # last node per rank (chaining)
    dec_last = [None] * decode_ranks
    for cid, r in enumerate(sorted(requests,
                                   key=lambda q: (q.arrival_ns, q.req_id))):
        pr = min(range(prefill_ranks),
                 key=lambda i: (max(pre_busy[i], r.arrival_ns), i))
        p_flops = r.prompt_tokens * model.prefill_flops_per_token
        pnode = et.comp(pr, f"prefill.req{r.req_id}", flops=p_flops,
                        bytes_moved=model.weight_bytes,
                        deps=[pre_last[pr]] if pre_last[pr] else None,
                        start_after_ns=r.arrival_ns)
        pre_last[pr] = pnode
        p_done = max(pre_busy[pr], r.arrival_ns) \
            + pl.comp_ns(p_flops, model.weight_bytes)
        pre_busy[pr] = p_done
        kv_bytes = max(1, int(r.prompt_tokens * model.kv_bytes_per_token))
        dr = min(range(decode_ranks),
                 key=lambda i: (max(dec_busy[i], p_done), i))
        dst = prefill_ranks + dr
        src_half, dst_half = et.p2p(cid, kv_bytes, pr, dst,
                                    deps_by_rank={pr: [pnode]},
                                    name=f"kv.req{r.req_id}")
        d_flops = r.decode_tokens * model.flops_per_token
        d_bytes = r.decode_tokens * model.weight_bytes
        deps = [dst_half] + ([dec_last[dr]] if dec_last[dr] else [])
        dnode = et.comp(dst, f"decode.req{r.req_id}", flops=d_flops,
                        bytes_moved=d_bytes, deps=deps)
        dnode.req_done = [r.req_id]
        dec_last[dr] = dnode
        dec_busy[dr] = max(dec_busy[dr], p_done + pl.p2p_ns(kv_bytes)) \
            + pl.comp_ns(d_flops, d_bytes)
    return ServingScenario(
        name=name or (f"disaggregated[{model.name},"
                      f"{prefill_ranks}p+{decode_ranks}d]"),
        trace=et, requests=list(requests),
        meta={"model": model.name, "prefill_ranks": prefill_ranks,
              "decode_ranks": decode_ranks})
