"""Traffic-driven inference serving scenarios (paper motivation:
latency-sensitive inference; ROADMAP "Production inference scenarios").

Compose a seeded arrival process (:mod:`.traffic`) with a scenario
builder (:mod:`.scenario`) to get an :class:`ExecutionTrace` that runs
through ``simulate()`` at every fidelity tier; per-request tail latency
(:mod:`.metrics`) is extracted from node times via request tags::

    from repro.serve import (PoissonArrivals, ServingModel,
                             continuous_batching, generate_requests)

    reqs = generate_requests(PoissonArrivals(2000.0), n=64, seed=7)
    model = ServingModel("toy", flops_per_token=2e6, weight_bytes=1e6,
                         coll_bytes_per_token=4096, kv_bytes_per_token=2048)
    scen = continuous_batching(model, reqs, tp=4)
    res = scen.simulate(fidelity="coarse")
    print(res.latency.p99_ns, res.latency.goodput_rps)
"""

from .metrics import (LatencyStats, attach_latency, latency_stats,
                      percentile, request_completions, request_latencies)
from .scenario import (ServingModel, ServingScenario, continuous_batching,
                       disaggregated)
from .traffic import (NS_PER_S, ArrivalProcess, DiurnalArrivals,
                      MMPPArrivals, PoissonArrivals, Request,
                      generate_requests)

__all__ = [
    "ArrivalProcess", "DiurnalArrivals", "LatencyStats", "MMPPArrivals",
    "NS_PER_S", "PoissonArrivals", "Request", "ServingModel",
    "ServingScenario", "attach_latency", "continuous_batching",
    "disaggregated", "generate_requests", "latency_stats", "percentile",
    "request_completions", "request_latencies",
]
