"""Seeded deterministic arrival processes for serving studies.

Realistic request traffic — not a single cold collective — is what makes
an inference-infrastructure study credible (network-infrastructure-testing
line, arxiv 2504.20854).  Every process here is a pure function of an
explicit ``seed``: no wall-clock reads, no global RNG, so two runs with
the same seed produce bit-identical arrival streams and scenarios are
reproducible and resumable.

Seeding idiom: ``random.Random(f"{seed}:{label}")`` — string seeds hash
through SHA-512 inside CPython's ``random``, which is stable across runs
and processes (unlike ``hash()``), and the label keeps independent streams
(arrivals vs. request shapes) from aliasing.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import List, Tuple, Union

#: nanoseconds per second (arrival processes are specified in req/s;
#: simulators run in ns)
NS_PER_S = 1e9

Seed = Union[int, str]


@dataclass(frozen=True)
class Request:
    """One inference request: arrival time plus prompt/decode token counts."""
    req_id: int
    arrival_ns: float
    prompt_tokens: int
    decode_tokens: int


class ArrivalProcess:
    """Base: ``arrivals(n, seed)`` returns the first ``n`` arrival times
    (ns, strictly increasing, deterministic in ``seed``)."""

    name = "arrivals"

    def arrivals(self, n: int, seed: Seed = 0) -> List[float]:
        raise NotImplementedError


class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson traffic at ``rate_rps`` requests/second."""

    def __init__(self, rate_rps: float):
        if rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
        self.rate_rps = rate_rps
        self.name = f"poisson[{rate_rps:g}rps]"

    def arrivals(self, n: int, seed: Seed = 0) -> List[float]:
        rng = random.Random(f"{seed}:poisson:{self.rate_rps!r}")
        t, out = 0.0, []
        for _ in range(n):
            t += rng.expovariate(self.rate_rps) * NS_PER_S
            out.append(t)
        return out


class DiurnalArrivals(ArrivalProcess):
    """Poisson traffic whose rate follows a sinusoidal day/night cycle.

    Instantaneous rate ``rate_rps * (1 + amplitude*sin(2*pi*t/period))``,
    sampled by thinning against the peak rate — the standard exact method
    for inhomogeneous Poisson processes.
    """

    def __init__(self, rate_rps: float, amplitude: float = 0.5,
                 period_s: float = 86_400.0, phase: float = 0.0):
        if rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
        if not (0.0 <= amplitude < 1.0):
            raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
        if period_s <= 0:
            raise ValueError(f"period_s must be > 0, got {period_s}")
        self.rate_rps = rate_rps
        self.amplitude = amplitude
        self.period_s = period_s
        self.phase = phase
        self.name = f"diurnal[{rate_rps:g}rps,a={amplitude:g}]"

    def rate_at(self, t_ns: float) -> float:
        w = 2.0 * math.pi * (t_ns / NS_PER_S) / self.period_s + self.phase
        return self.rate_rps * (1.0 + self.amplitude * math.sin(w))

    def arrivals(self, n: int, seed: Seed = 0) -> List[float]:
        rng = random.Random(f"{seed}:diurnal:{self.rate_rps!r}:"
                            f"{self.amplitude!r}:{self.period_s!r}:"
                            f"{self.phase!r}")
        lam_max = self.rate_rps * (1.0 + self.amplitude)
        t, out = 0.0, []
        while len(out) < n:
            t += rng.expovariate(lam_max) * NS_PER_S
            if rng.random() * lam_max <= self.rate_at(t):
                out.append(t)
        return out


class MMPPArrivals(ArrivalProcess):
    """Bursty traffic: a 2-state Markov-modulated Poisson process.

    The process alternates between a quiet state (``rate_low_rps``) and a
    burst state (``rate_high_rps``); dwell time in each state is
    exponential with mean ``mean_dwell_s``.  Exponential inter-arrivals
    are memoryless, so redrawing the gap after a state switch is exact.
    """

    def __init__(self, rate_low_rps: float, rate_high_rps: float,
                 mean_dwell_s: float = 1.0):
        for nm, v in (("rate_low_rps", rate_low_rps),
                      ("rate_high_rps", rate_high_rps),
                      ("mean_dwell_s", mean_dwell_s)):
            if v <= 0:
                raise ValueError(f"{nm} must be > 0, got {v}")
        self.rates = (rate_low_rps, rate_high_rps)
        self.mean_dwell_s = mean_dwell_s
        self.name = (f"mmpp[{rate_low_rps:g}/{rate_high_rps:g}rps,"
                     f"dwell={mean_dwell_s:g}s]")

    def arrivals(self, n: int, seed: Seed = 0) -> List[float]:
        rng = random.Random(f"{seed}:mmpp:{self.rates!r}:"
                            f"{self.mean_dwell_s!r}")
        t, state, out = 0.0, 0, []
        dwell_end = rng.expovariate(1.0 / self.mean_dwell_s) * NS_PER_S
        while len(out) < n:
            gap = rng.expovariate(self.rates[state]) * NS_PER_S
            if t + gap >= dwell_end:
                t = dwell_end
                state ^= 1
                dwell_end = t + rng.expovariate(
                    1.0 / self.mean_dwell_s) * NS_PER_S
                continue
            t += gap
            out.append(t)
        return out


def generate_requests(process: ArrivalProcess, n: int, seed: Seed = 0,
                      prompt_tokens: Tuple[int, int] = (64, 512),
                      decode_tokens: Tuple[int, int] = (16, 128),
                      ) -> List[Request]:
    """Draw ``n`` requests: arrivals from ``process``, token counts uniform
    over the given inclusive ranges.  Fully determined by ``seed``."""
    if n < 1:
        raise ValueError(f"need n >= 1 requests, got {n}")
    times = process.arrivals(n, seed)
    rng = random.Random(f"{seed}:requests:{process.name}")
    return [Request(req_id=i, arrival_ns=t,
                    prompt_tokens=rng.randint(*prompt_tokens),
                    decode_tokens=rng.randint(*decode_tokens))
            for i, t in enumerate(times)]
