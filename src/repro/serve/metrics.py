"""Per-request latency extraction from trace node times.

The serving scenario builders tag trace nodes with ``req_done`` — the ids
of requests whose completion that node marks.  After a run, a request's
completion time is the latest ``end_ns`` over its tagged nodes (for
continuous batching, the last rank's all-reduce half of the request's
final iteration; for disaggregated serving, its decode compute node), and
its latency is completion minus arrival.  ``LatencyStats`` condenses the
distribution into the tail percentiles serving studies actually report.
"""

from __future__ import annotations

import math
from dataclasses import asdict, dataclass
from typing import Dict, List, Sequence

from .traffic import NS_PER_S, Request


@dataclass(frozen=True)
class LatencyStats:
    """Tail-latency summary of one serving run (all times in ns)."""
    count: int
    mean_ns: float
    p50_ns: float
    p95_ns: float
    p99_ns: float
    p999_ns: float
    max_ns: float
    #: completed requests per simulated second (span: first arrival to
    #: last completion)
    goodput_rps: float

    def to_dict(self) -> Dict[str, float]:
        return asdict(self)


def percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a pre-sorted sequence."""
    if not sorted_vals:
        raise ValueError("percentile of empty sequence")
    if not (0.0 <= q <= 100.0):
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    if q == 0.0:
        return sorted_vals[0]
    rank = math.ceil(q / 100.0 * len(sorted_vals))
    return sorted_vals[rank - 1]


def request_completions(trace, node_times: Dict[int, tuple],
                        ) -> Dict[int, float]:
    """req_id -> completion time (ns): latest end over its tagged nodes."""
    done: Dict[int, float] = {}
    for n in trace.nodes:
        for rid in n.req_done:
            end = node_times[n.nid][1]
            if rid not in done or end > done[rid]:
                done[rid] = end
    return done


def request_latencies(trace, requests: List[Request],
                      node_times: Dict[int, tuple]) -> Dict[int, float]:
    """req_id -> latency (completion - arrival, ns).

    Raises if any request has no tagged completion node — a scenario
    builder bug that would otherwise silently drop the slowest requests
    from every percentile.
    """
    done = request_completions(trace, node_times)
    missing = [r.req_id for r in requests if r.req_id not in done]
    if missing:
        raise ValueError(
            f"requests {missing[:10]} have no req_done-tagged node in the "
            f"trace; cannot compute their latency")
    out = {}
    for r in requests:
        lat = done[r.req_id] - r.arrival_ns
        if lat < 0:
            raise ValueError(
                f"request {r.req_id} completes at {done[r.req_id]} ns, "
                f"before its arrival at {r.arrival_ns} ns — the scenario "
                f"failed to hold its nodes past the arrival")
        out[r.req_id] = lat
    return out


def latency_stats(requests: List[Request],
                  latencies: Dict[int, float]) -> LatencyStats:
    vals = sorted(latencies[r.req_id] for r in requests)
    n = len(vals)
    first_arrival = min(r.arrival_ns for r in requests)
    last_done = max(latencies[r.req_id] + r.arrival_ns for r in requests)
    span_s = max(last_done - first_arrival, 1.0) / NS_PER_S
    return LatencyStats(
        count=n, mean_ns=sum(vals) / n,
        p50_ns=percentile(vals, 50.0), p95_ns=percentile(vals, 95.0),
        p99_ns=percentile(vals, 99.0), p999_ns=percentile(vals, 99.9),
        max_ns=vals[-1], goodput_rps=n / span_s)


def attach_latency(trace, requests: List[Request], result) -> None:
    """Compute per-request latencies from ``result.node_times`` and attach
    :class:`LatencyStats` to ``result.latency`` (in place)."""
    lats = request_latencies(trace, requests, result.node_times)
    result.latency = latency_stats(requests, lats)
