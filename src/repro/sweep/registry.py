"""Sweep and suite registries with import-based auto-discovery.

Sweeps register themselves as a side effect of importing the module that
declares them (``register_sweep`` at module scope).  Two lookups layer on
top:

* ``SWEEPS`` — name -> :class:`~repro.sweep.grid.SweepSpec`.  The CLI and
  the worker both resolve through :func:`resolve`, which imports the
  declaring module on demand — so a worker process needs only the
  (module, name) pair to rebuild any point.

* ``SUITES`` — name -> no-argument callable.  The benchmark driver
  (``benchmarks/run.py``) discovers its suite list from here instead of a
  hand-maintained table, which is how new suites stop going missing from
  ``--all``.

:func:`discover` imports every known declaration site: the in-package
demo sweeps plus each ``benchmarks/*.py`` module (a namespace package —
located relative to the installed ``repro`` package, skipped gracefully
when the benchmarks tree isn't present, e.g. in a wheel install).
"""

from __future__ import annotations

import importlib
import sys
from pathlib import Path
from typing import Callable, Dict, List, Optional

from .grid import SweepSpec

SWEEPS: Dict[str, SweepSpec] = {}
SUITES: Dict[str, Callable[[], object]] = {}

#: modules inside this package that declare sweeps
_BUILTIN_MODULES = ("repro.sweep.demo",)

#: benchmarks/ modules that declare sweeps or suites (namespace package
#: at the repo root, importable as ``benchmarks.<mod>``)
_BENCHMARK_MODULES = (
    "fig4_protocols", "fig10_reduce_scatter", "fig11_all_gather",
    "fig12_unrolling", "fig13_outstanding", "fig14_scalability",
    "table1_clos_allreduce", "fidelity_compare", "roofline_table",
    "step_prediction", "engine_throughput", "trace_throughput",
    "serving_tail_latency",
)


def register_sweep(spec: SweepSpec) -> SweepSpec:
    """Register ``spec`` under its name (last registration wins — benchmark
    modules import under two names, ``fig10_allgather_bw`` from the CLI
    path and bare from test sys.path injection, and both define the same
    spec)."""
    SWEEPS[spec.name] = spec
    return spec


def register_suite(name: str):
    """Decorator: register a no-arg callable as a runnable benchmark suite."""
    def deco(fn):
        SUITES[name] = fn
        return fn
    return deco


def _repo_root() -> Optional[Path]:
    """The checkout root (where ``benchmarks/`` lives), or None when the
    package is installed without the benchmarks tree."""
    import repro
    root = Path(repro.__file__).resolve().parents[2]
    return root if (root / "benchmarks").is_dir() else None


def _add_root_to_path() -> None:
    root = _repo_root()
    if root is not None and str(root) not in sys.path:
        sys.path.insert(0, str(root))


def _import_quietly(module: str) -> bool:
    try:
        importlib.import_module(module)
        return True
    except ImportError:
        return False


def discover(include_benchmarks: bool = True) -> None:
    """Import every declaration site so SWEEPS/SUITES are populated."""
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)
    if not include_benchmarks or _repo_root() is None:
        return
    _add_root_to_path()
    for mod in _BENCHMARK_MODULES:
        _import_quietly(f"benchmarks.{mod}")


def resolve(name: str, module: str = "") -> SweepSpec:
    """Look up a sweep by name, importing its declaring module if needed.

    ``module`` (recorded on the spec at registration) lets a fresh worker
    process resolve without a full :func:`discover` sweep of every
    benchmark file.
    """
    if name in SWEEPS:
        return SWEEPS[name]
    if module:
        _add_root_to_path()
        _import_quietly(module)
        if name in SWEEPS:
            return SWEEPS[name]
    discover()
    if name in SWEEPS:
        return SWEEPS[name]
    raise KeyError(f"unknown sweep {name!r}; known: {sorted(SWEEPS)}")


def sweep_names() -> List[str]:
    discover()
    return sorted(SWEEPS)


def suite_names() -> List[str]:
    discover()
    return sorted(SUITES)
