"""Result persistence: the content-addressed cache + append-only JSONL.

Two stores, two jobs:

* :class:`ResultStore` — one small JSON file per point key, sharded by the
  first two hex digits (``<root>/ab/abcdef....json``).  Writes are atomic
  (tmp + rename) and happen only in the parent process, so concurrent
  sweeps against the same cache directory never torn-write.  Keys are the
  canonical content hashes from :mod:`repro.sweep.grid`, stable across
  processes and sessions — a resumed or re-declared sweep recomputes only
  the points whose inputs actually changed.

* JSONL stream — every finished point appends one self-describing row to
  ``<out>.jsonl`` (key, coordinates, provenance hashes, status, timings,
  result fields).  Append-only: resuming a run loads the keys already
  present and never writes a duplicate row.

:func:`validate_row` is the schema gate the tests and CI fold over every
emitted row.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Set

#: environment override for the cache root
CACHE_ENV = "REPRO_SWEEP_CACHE"
DEFAULT_CACHE = Path("results") / "sweep_cache"

ROW_STATUSES = ("ok", "timeout", "error")

#: fields every JSONL row must carry, whatever its status
ROW_REQUIRED = ("sweep", "key", "tier", "point", "status", "cached",
                "attempts", "point_wall_s", "provenance")

#: runner-added bookkeeping fields — :func:`payload` strips these to
#: recover what the point's measurement itself produced
ROW_ENVELOPE = frozenset(ROW_REQUIRED) | {"sim_wallclock_s", "fidelity",
                                          "key_mismatch"}


def payload(row: dict) -> dict:
    """The measurement fields of a row, minus the runner's envelope."""
    return {k: v for k, v in row.items() if k not in ROW_ENVELOPE}


def default_cache_dir() -> Path:
    return Path(os.environ.get(CACHE_ENV, str(DEFAULT_CACHE)))


class ResultStore:
    """Content-addressed point-result cache rooted at ``root``."""

    def __init__(self, root: Optional[Path] = None):
        self.root = Path(root) if root is not None else default_cache_dir()

    def _path(self, key: str) -> Path:
        return self.root / key[:2] / f"{key}.json"

    def get(self, key: str) -> Optional[dict]:
        p = self._path(key)
        try:
            with open(p) as f:
                return json.load(f)
        except FileNotFoundError:
            return None
        except (json.JSONDecodeError, OSError):
            # a corrupt entry is a miss, not a crash — it gets rewritten
            return None

    def put(self, key: str, row: dict) -> None:
        p = self._path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(p.parent), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(row, f, sort_keys=True)
            os.replace(tmp, p)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __contains__(self, key: str) -> bool:
        return self._path(key).exists()


# ----------------------------------------------------------------- JSONL
def read_jsonl(path: Path) -> Iterator[dict]:
    """Rows already in ``path`` (missing file -> empty; a truncated final
    line — e.g. from a killed run — is skipped, not fatal)."""
    try:
        f = open(path)
    except FileNotFoundError:
        return
    with f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError:
                continue


def existing_keys(path: Path) -> Set[str]:
    return {r["key"] for r in read_jsonl(path) if "key" in r}


def append_jsonl(path: Path, row: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
        f.flush()


# ---------------------------------------------------------------- schema
def validate_row(row: dict) -> List[str]:
    """Schema problems with one JSONL row (empty list = valid)."""
    errs = []
    for fld in ROW_REQUIRED:
        if fld not in row:
            errs.append(f"missing field {fld!r}")
    status = row.get("status")
    if status not in ROW_STATUSES:
        errs.append(f"status {status!r} not in {ROW_STATUSES}")
    if not isinstance(row.get("point"), dict):
        errs.append("point must be a coordinate dict")
    if not isinstance(row.get("provenance"), dict):
        errs.append("provenance must be a dict")
    if not isinstance(row.get("cached"), bool):
        errs.append("cached must be a bool")
    if not isinstance(row.get("attempts"), int) or row.get("attempts", 0) < 0:
        errs.append("attempts must be a non-negative int")
    if not isinstance(row.get("point_wall_s"), (int, float)):
        errs.append("point_wall_s must be a number")
    if status == "ok" and (isinstance(row.get("time_ns"), bool)
                           or not isinstance(row.get("time_ns"),
                                             (int, float))):
        errs.append("ok row must carry numeric time_ns")
    if status == "error" and not isinstance(row.get("error"), str):
        errs.append("error row must carry a traceback string")
    if status == "timeout" and not isinstance(row.get("timeout_s"),
                                              (int, float)):
        errs.append("timeout row must carry timeout_s")
    key = row.get("key")
    if not (isinstance(key, str) and len(key) == 64):
        errs.append("key must be a 64-hex sha256 string")
    return errs


def validate_jsonl(path: Path) -> Dict[int, List[str]]:
    """Line number -> schema problems, for every invalid row in a file."""
    out: Dict[int, List[str]] = {}
    for i, row in enumerate(read_jsonl(path), start=1):
        errs = validate_row(row)
        if errs:
            out[i] = errs
    return out
