"""Declarative sweep grids: typed axes, point specs, tier escalation.

A DSE study is a cross product of (workload x infrastructure x per-tier
config) *points*.  This module holds the pure-data half of the harness:

* :class:`SweepSpec` — the declaration: named axes (or an explicit point
  list), a ``build`` function that turns one coordinate dict into the
  simulation inputs for a tier, and optionally an :class:`Escalation`
  policy (cheap-tier prefilter over the full grid, fine tier only on the
  surviving frontier);
* :class:`PointSpec` — what ``build`` returns: workload + infra + config
  + per-run keywords + a metrics extractor;
* :func:`select_top_k` / :func:`select_pareto` — the escalation frontier
  selectors, pure functions over result rows so they unit-test without
  running anything.

Every point gets a *content-addressed key*: canonical hashes of the
built workload / infra / config / run keywords (:mod:`repro.core.
canonical`), stable across processes and sessions — the cache and the
JSONL provenance both key on it.  ``build`` must therefore be
deterministic (the worker process rebuilds the point and cross-checks
the key).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from ..core.canonical import combine_hashes, content_hash, hash_of

#: tiers a point may run at (matches repro.core.backends.FIDELITIES)
TIERS = ("fine", "coarse", "analytic")


def parse_objective(spec: str) -> Tuple[str, bool]:
    """``"min:time_ns"`` -> ("time_ns", False); ``"max:bw"`` -> ("bw", True)."""
    if ":" not in spec:
        raise ValueError(f"objective {spec!r}: expected 'min:FIELD' or "
                         f"'max:FIELD'")
    direction, _, fld = spec.partition(":")
    if direction not in ("min", "max") or not fld:
        raise ValueError(f"objective {spec!r}: expected 'min:FIELD' or "
                         f"'max:FIELD'")
    return fld, direction == "max"


@dataclass(frozen=True)
class Escalation:
    """Tier-escalation policy: run ``prefilter`` over the full grid, then
    ``final`` only on the frontier the selector keeps.

    ``mode="top_k"`` keeps the ``k`` best rows by ``objectives[0]``;
    ``mode="pareto"`` keeps the non-dominated set over all objectives.
    Objectives are ``"min:FIELD"`` / ``"max:FIELD"`` strings over row
    fields (``time_ns``, ``events``, any metric the spec extracts).
    """
    prefilter: str = "analytic"
    final: str = "fine"
    mode: str = "top_k"                     # "top_k" | "pareto"
    k: int = 4
    objectives: Tuple[str, ...] = ("min:time_ns",)

    def __post_init__(self):
        if self.mode not in ("top_k", "pareto"):
            raise ValueError(f"escalate mode {self.mode!r}: choose 'top_k' "
                             f"or 'pareto'")
        if not self.objectives:
            raise ValueError("escalation needs at least one objective")
        for o in self.objectives:
            parse_objective(o)

    def select(self, rows: List[dict]) -> List[dict]:
        """Frontier rows among ``rows`` (ok-status prefilter results)."""
        if self.mode == "top_k":
            return select_top_k(rows, self.k, self.objectives[0])
        return select_pareto(rows, self.objectives)


def select_top_k(rows: List[dict], k: int, objective: str) -> List[dict]:
    """The ``k`` best rows by one objective (stable order for ties)."""
    fld, maximize = parse_objective(objective)
    scored = [r for r in rows if isinstance(r.get(fld), (int, float))]
    scored.sort(key=lambda r: (-r[fld] if maximize else r[fld]))
    return scored[:max(k, 0)]


def select_pareto(rows: List[dict], objectives: Sequence[str]) -> List[dict]:
    """Non-dominated rows under the objective vector.

    Row A dominates B iff A is no worse on every objective and strictly
    better on at least one.  Duplicated objective vectors all survive
    (they tie), so the frontier is deterministic in input order.
    """
    parsed = [parse_objective(o) for o in objectives]

    def vec(r):
        out = []
        for fld, maximize in parsed:
            v = r.get(fld)
            if not isinstance(v, (int, float)):
                return None
            out.append(-v if maximize else v)      # lower is better
        return tuple(out)

    cand = [(r, vec(r)) for r in rows]
    cand = [(r, v) for r, v in cand if v is not None]
    front = []
    for r, v in cand:
        dominated = any(all(w[i] <= v[i] for i in range(len(v)))
                        and any(w[i] < v[i] for i in range(len(v)))
                        for _, w in cand)
        if not dominated:
            front.append(r)
    return front


@dataclass
class PointSpec:
    """The simulation inputs for one (point, tier) — what ``build`` returns.

    ``workload`` is a Program or ExecutionTrace; ``infra`` an InfraGraph
    Infrastructure or None (tier default); ``config`` a typed tier config
    or None; ``run_kw`` per-run keywords forwarded to ``simulate``.
    ``metrics(result)`` returns extra row fields (e.g. ``bus_GBps``).
    """
    workload: object
    infra: object = None
    config: object = None
    run_kw: Dict[str, object] = field(default_factory=dict)
    metrics: Optional[Callable[[object], Dict[str, object]]] = None
    check: str = "off"

    def fingerprint(self, tier: str) -> Dict[str, str]:
        """Canonical content hashes of each input (the cache provenance)."""
        return {
            "workload": hash_of(self.workload),
            "infra": hash_of(self.infra, none_token="default"),
            "config": hash_of(self.config, none_token=f"default:{tier}"),
            "run_kw": content_hash(self.run_kw),
        }


@dataclass
class SweepSpec:
    """One declarative sweep: axes x build -> points, plus run policy.

    ``axes`` maps axis name -> value tuple; the grid is the cross product
    in declaration order (or pass ``points`` for an explicit coordinate
    list).  ``build(coords, tier)`` returns a :class:`PointSpec`;
    alternatively ``run_point(coords, tier)`` returns a finished row dict
    for suites that need custom measurement loops (wall-clock trials,
    cross-mode asserts) — such rows are keyed by coordinates + ``version``
    instead of content hashes, so bump ``version`` to invalidate them.
    """
    name: str
    axes: Mapping[str, Sequence] = field(default_factory=dict)
    build: Optional[Callable[[dict, str], PointSpec]] = None
    run_point: Optional[Callable[[dict, str], dict]] = None
    tiers: Tuple[str, ...] = ("fine",)
    escalate: Optional[Escalation] = None
    points: Optional[List[dict]] = None
    version: int = 0
    timeout_s: float = 300.0
    retries: int = 1
    cacheable: bool = True
    #: filled by register_sweep (the module workers import to rebuild)
    module: str = ""

    def __post_init__(self):
        if (self.build is None) == (self.run_point is None):
            raise ValueError(f"sweep {self.name!r}: define exactly one of "
                             f"build= or run_point=")
        if self.run_point is not None:
            self.cacheable = False          # custom rows measure wall clock
        for t in self.tiers:
            if t not in TIERS:
                raise ValueError(f"sweep {self.name!r}: unknown tier {t!r}; "
                                 f"choose from {TIERS}")
        if not self.module:
            fn = self.build or self.run_point
            self.module = getattr(fn, "__module__", "") or ""

    # ------------------------------------------------------------- the grid
    def grid(self) -> List[dict]:
        """Every coordinate dict, cross product in axis declaration order."""
        if self.points is not None:
            return [dict(p) for p in self.points]
        if not self.axes:
            raise ValueError(f"sweep {self.name!r}: no axes and no points")
        names = list(self.axes)
        out = []
        for combo in itertools.product(*(self.axes[n] for n in names)):
            out.append(dict(zip(names, combo)))
        return out

    # -------------------------------------------------------------- keying
    def fingerprint(self, coords: dict, tier: str) -> Tuple[str, dict]:
        """(content-addressed point key, provenance dict).

        Calls ``build`` (cheap by contract: programs/graphs only, no
        simulation) so the key reflects *what would be simulated*, not
        how the grid happened to be spelled — renaming an axis keeps the
        cache warm; changing a buffer size misses exactly that point.
        """
        base = {"sweep": self.name, "version": str(self.version),
                "tier": tier}
        if self.run_point is not None:
            prov = dict(base, coords=content_hash(coords))
            return combine_hashes(**prov), prov
        ps = self.build(coords, tier)
        prov = dict(base, **ps.fingerprint(tier))
        return combine_hashes(**prov), prov
