"""Demo DSE sweeps: collective algorithm x fabric topology x link speed.

``demo_dse`` is the harness's acceptance sweep — a 48-point, 3-axis grid
(4 collectives x 3 topologies x 4 link bandwidths) over 8 ranks, with
tier escalation: the whole grid runs at the analytic tier, then the top-4
fastest points escalate to the fine (load-store) tier.  ``demo_smoke`` is
the CI-sized cut of the same study: 8 points, 4 ranks, one escalated
fine point — small enough for the tier-1 job.

Run either with::

    python -m repro.sweep demo_dse --jobs 4
    python -m repro.sweep demo_smoke --jobs 2
"""

from __future__ import annotations

from ..core.backends import AnalyticConfig, FineConfig
from ..core.cluster import NocConfig
from ..core.collectives import ALGORITHMS
from ..core.gpu_model import GpuConfig
from ..core.infragraph.blueprints import (ring_fabric, single_tier_fabric,
                                          torus2d_fabric)
from .grid import Escalation, PointSpec, SweepSpec
from .registry import register_sweep

#: tiny NoC + coarse cache lines: enough structure to exercise the fine
#: tier's contention model while keeping a 48-point sweep interactive
_DEMO_NOC = NocConfig(mesh_x=2, mesh_y=1, cus_per_router=1, mem_channels=2,
                      io_ports=2)
_DEMO_GPU = GpuConfig(cache_line=512)


def _fabric(topology: str, num_ranks: int, link_GBps: float):
    if topology == "switch":
        return single_tier_fabric(num_ranks, link_GBps=link_GBps)
    if topology == "ring":
        return ring_fabric(num_ranks, link_GBps=link_GBps)
    if topology == "torus":
        return torus2d_fabric(num_ranks // 2, 2, link_GBps=link_GBps)
    raise ValueError(f"unknown topology {topology!r}")


def _build_demo(num_ranks: int, shard_bytes: int):
    def build(coords: dict, tier: str) -> PointSpec:
        kind, _, algo = coords["collective"].partition(":")
        prog = ALGORITHMS[(kind, algo)](num_ranks, shard_bytes, 1)
        infra = _fabric(coords["topology"], num_ranks, coords["link_GBps"])
        if tier == "fine":
            cfg = FineConfig(noc=_DEMO_NOC, gpu_config=_DEMO_GPU)
        elif tier == "analytic":
            cfg = AnalyticConfig()
        else:
            cfg = None
        return PointSpec(workload=prog, infra=infra, config=cfg)
    return build


demo_dse = register_sweep(SweepSpec(
    name="demo_dse",
    axes={
        "collective": ("all_gather:ring", "all_reduce:ring",
                       "all_reduce:halving_doubling", "all_to_all:direct"),
        "topology": ("switch", "ring", "torus"),
        "link_GBps": (25.0, 50.0, 100.0, 200.0),
    },
    build=_build_demo(num_ranks=8, shard_bytes=128 * 1024),
    escalate=Escalation(prefilter="analytic", final="fine", mode="top_k",
                        k=4, objectives=("min:time_ns",)),
    timeout_s=300.0,
))

demo_smoke = register_sweep(SweepSpec(
    name="demo_smoke",
    axes={
        "collective": ("all_gather:ring", "all_reduce:ring"),
        "topology": ("switch", "ring"),
        "link_GBps": (50.0, 100.0),
    },
    build=_build_demo(num_ranks=4, shard_bytes=4 * 1024),
    escalate=Escalation(prefilter="analytic", final="fine", mode="top_k",
                        k=1, objectives=("min:time_ns",)),
    timeout_s=120.0,
))
