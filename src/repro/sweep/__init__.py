"""Sweep-at-scale DSE harness (this repo's experiment runner).

Declare a study as a :class:`SweepSpec` — typed axes, a ``build`` function
from coordinates to simulation inputs, an optional tier-:class:`Escalation`
policy — then execute it sharded across worker processes with per-point
timeout, bounded crash retry, content-addressed result caching, and
append-only JSONL streaming::

    from repro.sweep import SweepSpec, PointSpec, Escalation, run_sweep

    spec = SweepSpec(name="my_study",
                     axes={"bw": (50.0, 100.0)},
                     build=my_build,
                     escalate=Escalation(prefilter="analytic", final="fine"))
    result = run_sweep(spec, jobs=4)

or from the command line: ``python -m repro.sweep demo_dse --jobs 4``.
"""

from .grid import (Escalation, PointSpec, SweepSpec, select_pareto,
                   select_top_k)
from .registry import (SUITES, SWEEPS, discover, register_suite,
                       register_sweep, resolve)
from .runner import SweepResult, SweepRunner, run_sweep
from .store import (ResultStore, payload, read_jsonl, validate_jsonl,
                    validate_row)

__all__ = [
    "Escalation", "PointSpec", "SweepSpec", "select_pareto", "select_top_k",
    "SUITES", "SWEEPS", "discover", "register_suite", "register_sweep",
    "resolve", "SweepResult", "SweepRunner", "run_sweep",
    "ResultStore", "payload", "read_jsonl", "validate_jsonl", "validate_row",
]
