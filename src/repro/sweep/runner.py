"""The sweep runner: sharded execution with crash isolation + caching.

Execution model — one process per *point*, not a long-lived pool.  A
``ProcessPoolExecutor`` poisons itself when any worker dies (every queued
future collapses with BrokenProcessPool); here a dead worker fails
exactly one point and the run keeps going, which is the property the
whole harness is built around.  The parent keeps at most ``jobs`` live
children, each with a one-shot Pipe; completion, crash, and deadline are
all observed from the parent's poll loop:

* message arrived  -> ok row or error row (worker's own traceback);
  Python exceptions are deterministic, so they are **not** retried;
* deadline passed  -> terminate the child, ``status=timeout`` row;
* child exited with no message -> infrastructure crash (OOM-kill,
  segfault, ``os._exit``) -> retried up to ``retries`` times, then an
  ``status=error`` row recording the exit code.

``jobs=0`` runs points inline in the parent — same ``execute_point``
code path, no subprocess overhead — which is what the ported benchmark
suites use (their baselines must stay bit-identical and cheap).

Every finished point streams one JSONL row immediately (append-only;
resume skips keys already present) and — for ok rows of cacheable
sweeps — lands in the content-addressed :class:`ResultStore`, so a
second invocation replays cached points without simulating.
"""

from __future__ import annotations

import multiprocessing as mp
import sys
import time
from dataclasses import dataclass, field
from multiprocessing.connection import wait as conn_wait
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .grid import SweepSpec
from .store import ResultStore, append_jsonl, existing_keys, read_jsonl
from .worker import _child_entry, execute_point

#: default directory for sweep JSONL outputs
DEFAULT_OUT_DIR = Path("results") / "sweeps"


@dataclass
class SweepResult:
    """What a sweep run produced: rows in deterministic submission order."""
    name: str
    rows: List[dict] = field(default_factory=list)
    out_path: Optional[Path] = None
    wall_s: float = 0.0

    @property
    def ok(self) -> List[dict]:
        return [r for r in self.rows if r["status"] == "ok"]

    @property
    def failed(self) -> List[dict]:
        return [r for r in self.rows if r["status"] != "ok"]

    def counts(self) -> Dict[str, int]:
        out = {"ok": 0, "timeout": 0, "error": 0, "cached": 0}
        for r in self.rows:
            out[r["status"]] += 1
            if r.get("cached"):
                out["cached"] += 1
        return out


@dataclass
class _Job:
    index: int                  # submission order within the phase
    coords: dict
    tier: str
    key: str
    prov: dict
    attempts: int = 0


class _Active:
    """One live child: process + its result pipe + deadline bookkeeping."""

    def __init__(self, job: _Job, proc, conn, started: float):
        self.job, self.proc, self.conn, self.started = job, proc, conn, started


class SweepRunner:
    """Executes one :class:`SweepSpec`; see module docstring for model."""

    def __init__(self, spec: SweepSpec, *, jobs: int = 0,
                 out: Optional[Path] = None, cache: Optional[Path] = None,
                 use_cache: bool = True, fresh: bool = False,
                 timeout_s: Optional[float] = None,
                 retries: Optional[int] = None, progress: bool = True):
        self.spec = spec
        self.jobs = max(int(jobs), 0)
        self.out = Path(out) if out is not None else (
            DEFAULT_OUT_DIR / f"{spec.name}.jsonl")
        self.store = ResultStore(cache) if spec.cacheable else None
        self.use_cache = use_cache and spec.cacheable
        self.fresh = fresh
        self.timeout_s = float(timeout_s if timeout_s is not None
                               else spec.timeout_s)
        self.retries = int(retries if retries is not None else spec.retries)
        self.progress = progress
        if fresh and self.out.exists():
            self.out.unlink()           # --fresh starts the JSONL stream over
        self._done_keys = set() if fresh else existing_keys(self.out)
        self._resumed: Dict[str, dict] = {}
        if not fresh and self._done_keys:
            for row in read_jsonl(self.out):
                if row.get("status") == "ok" and "key" in row:
                    self._resumed[row["key"]] = row
        self._stats = {"done": 0, "total": 0, "ok": 0, "failed": 0,
                       "cached": 0}

    # ------------------------------------------------------------ plumbing
    def _emit(self, row: dict) -> None:
        """Stream one finished row: JSONL (no duplicates on resume) + cache."""
        if row["key"] not in self._done_keys:
            append_jsonl(self.out, row)
            self._done_keys.add(row["key"])
        elif row["status"] != "ok":
            # re-run of a previously failed point: record the fresh outcome
            append_jsonl(self.out, row)
        if (self.store is not None and row["status"] == "ok"
                and not row.get("cached")):
            self.store.put(row["key"], row)
        self._stats["done"] += 1
        self._stats["ok" if row["status"] == "ok" else "failed"] += 1
        if row.get("cached"):
            self._stats["cached"] += 1
        self._progress_line()

    def _progress_line(self, end: bool = False) -> None:
        if not self.progress:
            return
        s = self._stats
        line = (f"[{self.spec.name}] {s['done']}/{s['total']} points  "
                f"ok={s['ok']} failed={s['failed']} cached={s['cached']}")
        if sys.stderr.isatty():
            print("\r" + line + ("" if not end else "\n"), end="",
                  file=sys.stderr, flush=True)
        elif end:
            print(line, file=sys.stderr, flush=True)

    def _row_base(self, job: _Job) -> dict:
        return {"sweep": self.spec.name, "key": job.key, "tier": job.tier,
                "point": job.coords, "provenance": job.prov,
                "cached": False, "attempts": job.attempts}

    def _ok_row(self, job: _Job, fields: dict, wall: float) -> dict:
        row = self._row_base(job)
        worker_key = fields.pop("key", job.key)
        if worker_key != job.key:
            print(f"[{self.spec.name}] WARNING: point key mismatch for "
                  f"{job.coords} — build() is nondeterministic; caching "
                  f"disabled for this row", file=sys.stderr)
            row["key_mismatch"] = worker_key
        row.update(fields)
        row["status"] = "ok"
        row["point_wall_s"] = wall
        return row

    def _fail_row(self, job: _Job, status: str, wall: float,
                  **extra) -> dict:
        row = self._row_base(job)
        row["status"] = status
        row["point_wall_s"] = wall
        row.update(extra)
        return row

    # ----------------------------------------------------------- execution
    def _run_phase(self, jobs: List[_Job]) -> List[dict]:
        """Run one tier phase; rows come back in submission order."""
        results: Dict[int, dict] = {}
        pending: List[_Job] = []
        for job in jobs:
            row = self._serve_from_cache(job)
            if row is not None:
                results[job.index] = row
                self._emit(row)
            else:
                pending.append(job)
        if pending:
            if self.jobs == 0:
                for job in pending:
                    row = self._run_inline(job)
                    results[job.index] = row
                    self._emit(row)
            else:
                for idx, row in self._run_pool(pending):
                    results[idx] = row
                    self._emit(row)
        return [results[j.index] for j in jobs]

    def _serve_from_cache(self, job: _Job) -> Optional[dict]:
        if job.key in self._resumed:
            return dict(self._resumed[job.key])
        if not self.use_cache or self.fresh or self.store is None:
            return None
        hit = self.store.get(job.key)
        if hit is None or hit.get("status") != "ok":
            return None
        row = dict(hit)
        row["cached"] = True
        row["point_wall_s"] = 0.0
        return row

    def _run_inline(self, job: _Job) -> dict:
        import traceback
        job.attempts += 1
        t0 = time.perf_counter()
        try:
            fields = execute_point(self.spec.module, self.spec.name,
                                   job.coords, job.tier)
        except Exception:
            return self._fail_row(job, "error", time.perf_counter() - t0,
                                  error=traceback.format_exc())
        return self._ok_row(job, fields, time.perf_counter() - t0)

    def _spawn(self, ctx, job: _Job) -> _Active:
        job.attempts += 1
        parent_conn, child_conn = ctx.Pipe(duplex=False)
        proc = ctx.Process(
            target=_child_entry,
            args=(child_conn, self.spec.module, self.spec.name, job.coords,
                  job.tier, list(sys.path)),
            daemon=True)
        proc.start()
        child_conn.close()
        return _Active(job, proc, parent_conn, time.perf_counter())

    @staticmethod
    def _reap(act: _Active) -> None:
        try:
            act.conn.close()
        except OSError:
            pass
        if act.proc.is_alive():
            act.proc.terminate()
            act.proc.join(5.0)
            if act.proc.is_alive():
                act.proc.kill()
                act.proc.join(5.0)
        else:
            act.proc.join()

    def _run_pool(self, pending: List[_Job]):
        """Yield (index, row) as points finish; at most ``jobs`` children."""
        ctx = mp.get_context()
        queue = list(pending)
        active: Dict[object, _Active] = {}     # conn -> _Active
        try:
            while queue or active:
                while queue and len(active) < self.jobs:
                    act = self._spawn(ctx, queue.pop(0))
                    active[act.conn] = act
                ready = conn_wait(list(active), timeout=0.2)
                now = time.perf_counter()
                for conn in ready:
                    act = active.pop(conn)
                    wall = now - act.started
                    msg = None
                    try:
                        msg = act.conn.recv()
                    except (EOFError, OSError):
                        pass          # child died before sending anything
                    self._reap(act)
                    if msg is None:
                        exitcode = act.proc.exitcode
                        if act.job.attempts <= self.retries:
                            queue.append(act.job)       # crash -> retry
                            continue
                        yield act.job.index, self._fail_row(
                            act.job, "error", wall,
                            error=f"worker died without a result "
                                  f"(exit code {exitcode})")
                    elif msg[0] == "ok":
                        yield act.job.index, self._ok_row(act.job, msg[1],
                                                          wall)
                    else:
                        yield act.job.index, self._fail_row(
                            act.job, "error", wall, error=msg[1])
                # deadline check on whoever is still running
                for conn in [c for c, a in active.items()
                             if now - a.started > self.timeout_s]:
                    act = active.pop(conn)
                    wall = now - act.started
                    self._reap(act)
                    yield act.job.index, self._fail_row(
                        act.job, "timeout", wall, timeout_s=self.timeout_s)
        finally:
            for act in active.values():
                self._reap(act)

    # -------------------------------------------------------------- driver
    def _make_jobs(self, points: List[dict], tier: str,
                   base_index: int) -> List[_Job]:
        jobs = []
        for i, coords in enumerate(points):
            key, prov = self.spec.fingerprint(coords, tier)
            jobs.append(_Job(base_index + i, coords, tier, key, prov))
        return jobs

    def run(self, tier: Optional[str] = None,
            points: Optional[List[dict]] = None) -> SweepResult:
        """Execute the sweep: every (point x tier), escalating if declared.

        ``tier`` overrides the spec's tier plan (no escalation); ``points``
        overrides the grid (explicit coordinate list).
        """
        t0 = time.perf_counter()
        grid = points if points is not None else self.spec.grid()
        esc = self.spec.escalate if tier is None else None
        if esc is not None:
            phases: List[Tuple[str, List[dict]]] = [(esc.prefilter, grid)]
        else:
            tiers = (tier,) if tier is not None else self.spec.tiers
            phases = [(t, grid) for t in tiers]
        self._stats["total"] = sum(len(p) for _, p in phases)

        all_rows: List[dict] = []
        base = 0
        for phase_tier, phase_points in phases:
            jobs = self._make_jobs(phase_points, phase_tier, base)
            base += len(jobs)
            all_rows.extend(self._run_phase(jobs))

        if esc is not None:
            survivors = esc.select([r for r in all_rows
                                    if r["status"] == "ok"])
            chosen = [r["point"] for r in survivors]
            if self.progress:
                self._progress_line(end=True)
                print(f"[{self.spec.name}] escalating {len(chosen)}/"
                      f"{len(grid)} points: {esc.prefilter} -> {esc.final} "
                      f"({esc.mode})", file=sys.stderr, flush=True)
            self._stats["total"] += len(chosen)
            jobs = self._make_jobs(chosen, esc.final, base)
            all_rows.extend(self._run_phase(jobs))

        self._progress_line(end=True)
        return SweepResult(self.spec.name, all_rows, self.out,
                           time.perf_counter() - t0)


def run_sweep(spec: SweepSpec, **kw) -> SweepResult:
    """One-call façade: ``run_sweep(spec, jobs=4, tier="analytic", ...)``.

    ``tier=`` and ``points=`` forward to :meth:`SweepRunner.run`; the rest
    configure the runner (jobs, out, cache, use_cache, fresh, timeout_s,
    retries, progress).
    """
    tier = kw.pop("tier", None)
    points = kw.pop("points", None)
    return SweepRunner(spec, **kw).run(tier=tier, points=points)
