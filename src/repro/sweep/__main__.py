"""CLI: ``python -m repro.sweep NAME [--jobs N] [--tier T] ...``

Runs a registered sweep (``--list`` shows them), streaming JSONL rows to
``results/sweeps/<name>.jsonl`` and caching point results under
``results/sweep_cache`` (override with ``--cache`` or $REPRO_SWEEP_CACHE).
Exit status is 1 if any point finished as timeout/error.
"""

from __future__ import annotations

import argparse
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main(argv=None) -> int:
    from . import registry
    from .runner import SweepRunner

    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep",
        description="Run a registered DSE sweep (sharded, cached, "
                    "tier-escalating).")
    ap.add_argument("name", nargs="?", help="registered sweep name")
    ap.add_argument("--list", action="store_true",
                    help="list registered sweeps and exit")
    ap.add_argument("--jobs", type=int, default=0, metavar="N",
                    help="worker processes (0 = run inline in this process)")
    ap.add_argument("--tier", choices=("fine", "coarse", "analytic"),
                    help="force one tier (disables escalation)")
    ap.add_argument("--out", help="JSONL output path "
                    "(default results/sweeps/<name>.jsonl)")
    ap.add_argument("--cache", help="cache directory "
                    "(default results/sweep_cache or $REPRO_SWEEP_CACHE)")
    ap.add_argument("--no-cache", action="store_true",
                    help="neither read nor write the point cache")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore cache and any existing JSONL rows")
    ap.add_argument("--timeout", type=float, metavar="S",
                    help="per-point timeout (default: the spec's)")
    ap.add_argument("--retries", type=int, metavar="N",
                    help="crash retries per point (default: the spec's)")
    args = ap.parse_args(argv)

    if args.list:
        for name in registry.sweep_names():
            spec = registry.SWEEPS[name]
            esc = (f"  escalate {spec.escalate.prefilter}->"
                   f"{spec.escalate.final}" if spec.escalate else "")
            print(f"{name}: {len(spec.grid())} points{esc}")
        return 0
    if not args.name:
        ap.error("sweep name required (or --list)")

    try:
        spec = registry.resolve(args.name)
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 2

    runner = SweepRunner(spec, jobs=args.jobs, out=args.out,
                         cache=args.cache,
                         use_cache=not args.no_cache, fresh=args.fresh,
                         timeout_s=args.timeout, retries=args.retries)
    result = runner.run(tier=args.tier)
    c = result.counts()
    print(f"{spec.name}: {len(result.rows)} rows -> {result.out_path}  "
          f"(ok={c['ok']} timeout={c['timeout']} error={c['error']} "
          f"cached={c['cached']})  {result.wall_s:.2f}s")
    return 1 if (c["timeout"] or c["error"]) else 0


if __name__ == "__main__":
    sys.exit(main())
