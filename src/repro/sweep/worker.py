"""Child-process side of the sweep runner.

Each grid point runs in its own worker process: the parent sends only
picklable primitives — (module name, sweep name, coordinate dict, tier) —
and the worker *re-imports the spec and rebuilds the point* from scratch.
That keeps the parent/child contract trivially serializable (no pickling
of Programs, backends, or closures) and doubles as a determinism check:
the worker recomputes the point's content-addressed key and the parent
compares it against its own — a mismatch means ``build`` is
nondeterministic and the cache would lie.

``JAX_PLATFORMS=cpu`` is pinned before anything imports jax; without it,
forked workers re-probe accelerators, which masquerades as a hang.
"""

from __future__ import annotations

import os
import sys
import time
import traceback

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def execute_point(spec_module: str, spec_name: str, coords: dict,
                  tier: str) -> dict:
    """Run one (point, tier); returns the result fields for its JSONL row.

    Importable from both parent (``jobs=0`` inline mode) and worker
    processes — the single definition of "run a point" so escalated fine
    results are bit-identical to direct ``simulate()`` calls.
    """
    from . import registry
    spec = registry.resolve(spec_name, module=spec_module)
    key, _prov = spec.fingerprint(coords, tier)

    if spec.run_point is not None:
        t0 = time.perf_counter()
        fields = spec.run_point(coords, tier)
        if not isinstance(fields, dict):
            raise TypeError(f"sweep {spec.name!r}: run_point must return a "
                            f"dict, got {type(fields).__name__}")
        fields.setdefault("sim_wallclock_s", time.perf_counter() - t0)
        fields["key"] = key
        return fields

    from ..core.backends import simulate
    ps = spec.build(coords, tier)
    t0 = time.perf_counter()
    res = simulate(ps.workload, ps.infra, fidelity=tier, config=ps.config,
                   check=ps.check, **ps.run_kw)
    wall = time.perf_counter() - t0
    fields = {
        "key": key,
        # verbatim, not coerced: rows must be bit-identical to a direct
        # simulate() call (time_ns is int on most backends, float on some)
        "time_ns": res.time_ns,
        "events": int(getattr(res, "events", 0)),
        "fidelity": getattr(res, "fidelity", tier),
        "sim_wallclock_s": wall,
    }
    if ps.metrics is not None:
        extra = ps.metrics(res)
        if extra:
            fields.update(extra)
    return fields


def _child_entry(conn, spec_module: str, spec_name: str, coords: dict,
                 tier: str, parent_path: list) -> None:
    """multiprocessing target: run the point, ship the outcome, exit.

    With the ``spawn`` start method the child gets a fresh interpreter, so
    the parent's ``sys.path`` (src layout, benchmarks dir) rides along.
    """
    for p in parent_path:
        if p not in sys.path:
            sys.path.append(p)
    try:
        fields = execute_point(spec_module, spec_name, coords, tier)
        conn.send(("ok", fields))
    except BaseException:
        try:
            conn.send(("error", traceback.format_exc()))
        except BaseException:
            pass
    finally:
        try:
            conn.close()
        except BaseException:
            pass
