"""Production mesh definition (brief: MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many CPU devices exist (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
