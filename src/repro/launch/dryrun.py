import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the jit-ed step (train_step for train shapes,
prefill_step / serve_step for inference shapes), attaches the sharding
plan, lowers with ShapeDtypeStruct stand-ins (no allocation), compiles,
and records memory_analysis / cost_analysis / per-collective bytes for
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k \
      [--multi-pod] [--out results.json] [--plan default]
  python -m repro.launch.dryrun --all [--out dir/]
"""

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax

# persistent compilation cache: retries and perf iterations on unchanged
# cells hit the cache instead of recompiling
jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 10)
import jax.numpy as jnp

from ..analysis.hlo_stats import analyze as analyze_hlo
from ..analysis.roofline import (adjusted_terms, roofline_terms,
                                 summarize_memory)
from ..distributed import hints
from ..configs.base import SHAPES, get, registry
from ..distributed import sharding as shard
from ..models import api
from ..optim.adamw import AdamWConfig
from ..train.step import init_train_state, make_serve_step, make_train_step
from .mesh import make_production_mesh

REPLICATED = None  # alias for readability


def cell_applicable(arch: str, shape_name: str) -> bool:
    cfg = get(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False   # pure full-attention archs skip (DESIGN.md §5)
    return True


def build_cell(arch: str, shape_name: str, mesh, plan: str = "default"):
    """Returns (jitted_fn, example_args_with_shardings)."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    specs = api.input_specs(cfg, shape)

    if shape.kind == "train":
        step = make_train_step(cfg, AdamWConfig())
        state_abs = jax.eval_shape(
            partial(init_train_state, cfg=cfg), jax.random.PRNGKey(0))
        st_specs = shard.state_specs(state_abs, cfg, mesh, plan)
        b_specs = shard.batch_specs(specs, cfg, mesh, plan)
        state_in = shard.with_sharding(state_abs, st_specs, mesh)
        batch_in = shard.with_sharding(specs, b_specs, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(shard.to_named(st_specs, mesh),
                          shard.to_named(b_specs, mesh)),
            out_shardings=(shard.to_named(st_specs, mesh), REPLICATED),
            donate_argnums=(0,))
        return jitted, (state_in, batch_in)

    params_abs = jax.eval_shape(
        partial(api.init_params, cfg=cfg), jax.random.PRNGKey(0))
    p_specs = shard.params_specs(params_abs, cfg, mesh, plan)
    params_in = shard.with_sharding(params_abs, p_specs, mesh)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return api.prefill(params, cfg, batch)
        b_specs = shard.batch_specs(specs, cfg, mesh)
        batch_in = shard.with_sharding(specs, b_specs, mesh)
        jitted = jax.jit(
            prefill_fn,
            in_shardings=(shard.to_named(p_specs, mesh),
                          shard.to_named(b_specs, mesh)))
        return jitted, (params_in, batch_in)

    # decode: one new token against a cache of seq_len
    B, S = shape.global_batch, shape.seq_len
    cache_abs = jax.eval_shape(partial(api.init_cache, cfg, B, S))
    c_specs = shard.cache_specs(cache_abs, cfg, mesh)
    cache_in = shard.with_sharding(cache_abs, c_specs, mesh)
    tok_abs = specs["token"]
    t_spec = shard.batch_specs({"token": tok_abs}, cfg, mesh)["token"]
    tok_in = shard.with_sharding({"token": tok_abs},
                                 {"token": t_spec}, mesh)["token"]
    pos_in = jax.ShapeDtypeStruct((), jnp.int32)
    serve = make_serve_step(cfg)
    jitted = jax.jit(
        serve,
        in_shardings=(shard.to_named(p_specs, mesh),
                      shard.to_named(c_specs, mesh),
                      shard.to_named({"t": t_spec}, mesh)["t"], REPLICATED),
        out_shardings=(REPLICATED, shard.to_named(c_specs, mesh)),
        donate_argnums=(1,))
    return jitted, (params_in, cache_in, tok_in, pos_in)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             plan: str = "default") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    hints.set_mesh(mesh)
    hints.set_plan(plan)
    t0 = time.time()
    try:
        jitted, args = build_cell(arch, shape_name, mesh, plan)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost_raw = compiled.cost_analysis()
            hlo = compiled.as_text()
    finally:
        hints.set_mesh(None)
        hints.set_plan("default")
    stats = analyze_hlo(hlo)
    # analyzer numbers are per-device with while-trip multiplication
    # (cost_analysis counts loop bodies once — see EXPERIMENTS.md §Dry-run)
    cost = {"flops": stats["flops"], "bytes accessed": stats["bytes"]}
    coll = dict(stats["collectives"])
    cfg = get(arch)
    shape = SHAPES[shape_name]
    terms = roofline_terms(cost, coll, chips=chips, cfg=cfg, shape=shape)
    terms.update(adjusted_terms(terms, stats.get("tag_bytes", {}), cfg,
                                shape, chips))
    out = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "chips": chips,
        "plan": plan,
        "memory": summarize_memory(mem),
        "cost": cost,
        "cost_raw_xla": {k: cost_raw.get(k, 0.0) for k in
                         ("flops", "bytes accessed")},
        "collectives": coll,
        "hlo_computations": stats["num_computations"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "status": "ok",
        "roofline": terms,
    }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--plan", default="default")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in sorted(registry()):
            for shape in SHAPES:
                if cell_applicable(arch, shape):
                    cells.append((arch, shape))
    else:
        if not cell_applicable(args.arch, args.shape):
            print(json.dumps({"arch": args.arch, "shape": args.shape,
                              "status": "skipped",
                              "reason": "full-attention arch at 500k "
                                        "(DESIGN.md §5)"}))
            return 0
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        try:
            r = run_cell(arch, shape, args.multi_pod, args.plan)
        except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
            r = {"arch": arch, "shape": shape, "status": "error",
                 "error": f"{type(e).__name__}: {e}",
                 "trace": traceback.format_exc()[-2000:]}
        results.append(r)
        print(json.dumps(r if r["status"] != "error" else
                         {k: r[k] for k in ("arch", "shape", "status",
                                            "error")}))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
