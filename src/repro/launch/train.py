"""End-to-end training driver.

CPU-runnable (reduced configs) and production-shaped: sharded state, data
pipeline with prefetch + deterministic restart, checkpointing with keep-N
rotation, elastic restore onto a different mesh, optional int8 gradient
compression.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs.base import ShapeConfig, get, reduced
from ..data.pipeline import PipelineConfig, TokenPipeline
from ..distributed import hints
from ..distributed.checkpoint import CheckpointManager
from ..optim.adamw import AdamWConfig
from ..train.step import init_train_state, make_train_step
from .mesh import make_cpu_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    mesh = make_cpu_mesh(data=len(jax.devices()))
    hints.set_mesh(mesh)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=args.lr),
                                      compress_grads=args.compress_grads))

    state = init_train_state(jax.random.PRNGKey(0), cfg,
                             compress_grads=args.compress_grads)
    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        start_step, state = ckpt.restore()
        print(f"resumed from step {start_step}")

    pipe = TokenPipeline(cfg, shape, PipelineConfig(prefetch=2))
    pipe.start(from_step=start_step)
    t0 = time.time()
    losses = []
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.get().items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(json.dumps({"step": step,
                              "loss": round(losses[-1], 4),
                              "grad_norm":
                                  round(float(metrics["grad_norm"]), 3),
                              "tok_per_s": round(
                                  shape.tokens * (step - start_step + 1)
                                  / max(dt, 1e-9))}))
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
    pipe.stop()
    if ckpt:
        ckpt.save(args.steps, state)
        ckpt.wait()
    hints.set_mesh(None)
    print(json.dumps({"final_loss": losses[-1],
                      "initial_loss": losses[0],
                      "improved": losses[-1] < losses[0]}))
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
