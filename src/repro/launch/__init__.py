from .mesh import make_cpu_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_cpu_mesh"]
