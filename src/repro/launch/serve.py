"""Serving driver: batched prefill + decode with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ShapeConfig, get, reduced
from ..models import api
from ..train.step import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    max_len = args.prompt_len + args.gen + 8
    shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in api.make_batch(cfg, shape).items()
             if k != "labels"}

    t0 = time.time()
    cache, logits = api.prefill(params, cfg, batch)
    # move the collected prefill KV into a max_len cache for decode
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        full = api.init_cache(cfg, args.batch, max_len)
        S = cache["k"].shape[2]
        full["k"] = full["k"].at[:, :, :S].set(cache["k"])
        full["v"] = full["v"].at[:, :, :S].set(cache["v"])
        for key in ("mk", "mv"):
            if key in cache:
                full[key] = cache[key]
        cache = full
    t_prefill = time.time() - t0

    serve_step = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    pos = args.prompt_len
    t1 = time.time()
    for i in range(args.gen):
        logits, cache = serve_step(params, cache, tok,
                                   jnp.asarray(pos + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.time() - t1
    toks = np.concatenate(out_tokens, axis=1)
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch,
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round(args.gen * args.batch / max(t_decode,
                                                              1e-9), 1),
        "sample_tokens": toks[0, :8].tolist(),
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
