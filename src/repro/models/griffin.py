"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local
attention, pattern (rec, rec, attn) [arXiv:2402.19427].

RG-LRU:  a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t)),  c = 8
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(W_i x_t) * x_t)
computed in parallel over the sequence with ``jax.lax.associative_scan``
(prefill/train) or stepwise (decode).  The recurrent block is
conv1d(4, causal, depthwise) -> RG-LRU on one branch, GeLU on the other,
multiplied and projected back (Griffin Fig. 2).

38 layers = 12 x (rec, rec, attn) + (rec, rec): executed as a scan over 12
stacked macro-blocks plus a tail scan over the 2 leftover rec layers.
Decode state: per rec layer (h, conv tail), per attn layer a ring-buffer KV
cache of ``window`` entries — O(window) in context length (long_500k ✓).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers as L
from ..distributed import hints

Params = Dict[str, Any]
C_RGLRU = 8.0


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# RG-LRU + recurrent block
# ---------------------------------------------------------------------------

def rg_lru(x: jnp.ndarray, p: Params, h0: jnp.ndarray
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,T,R); h0: (B,R).  Parallel linear recurrence."""
    with jax.named_scope("rg_lru_kernel"):
        return _rg_lru_impl(x, p, h0)


def _rg_lru_impl(x, p, h0):
    xf = x.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r_gate
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_gate * xf)
    # h_t = a_t h_{t-1} + b_t  via associative scan on (a, b) pairs
    b = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def comb(u, v):
        return (u[0] * v[0], v[0] * u[1] + v[1])

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(x: jnp.ndarray, p: Params, h: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,R) one step."""
    xf = x.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32))
    a = jnp.exp(-C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32))
                * r_gate)
    h = a * h + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i_gate * xf)
    return h.astype(x.dtype), h


def conv1d_causal(x: jnp.ndarray, w: jnp.ndarray, tail: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv, width K.  x: (B,T,R); w: (K,R);
    tail: (B,K-1,R) — the previous K-1 inputs."""
    K = w.shape[0]
    xx = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xx[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out, xx[:, -(K - 1):]


def rec_block_init(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    d, R = cfg.d_model, cfg.rec_d_rnn
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.zeros((d,), dt),
        "w_x": L.dense_init(ks[0], d, R, dt),       # recurrent branch in
        "w_y": L.dense_init(ks[1], d, R, dt),       # gate branch in
        "w_out": L.dense_init(ks[2], R, d, dt),
        "conv_w": (jax.random.normal(ks[3], (cfg.rec_conv, R)) * 0.1
                   ).astype(dt),
        "w_a": L.dense_init(ks[4], R, R, dt),
        "w_i": L.dense_init(ks[5], R, R, dt),
        "lam": jnp.ones((R,), jnp.float32) * 0.7,
        "ln2": jnp.zeros((d,), dt),
        "mlp": L.glu_mlp_init(jax.random.fold_in(key, 7), d, cfg.d_ff, dt, cfg.act),
    }


def rec_block_fwd(p: Params, x, cfg: ArchConfig, st: Dict
                  ) -> Tuple[jnp.ndarray, Dict]:
    x = hints.constrain(x, "dp", None, None)
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(h @ p["w_y"])
    u = h @ p["w_x"]
    gate = hints.constrain(gate, "dp", None, "model")
    u = hints.constrain(u, "dp", None, "model")
    if x.shape[1] == 1:
        K = p["conv_w"].shape[0]
        xx = jnp.concatenate([st["conv"].astype(u.dtype), u], axis=1)  # (B,K,R)
        c = sum(xx[:, i] * p["conv_w"][i] for i in range(K))           # (B,R)
        new_tail = xx[:, 1:]
        y, hstate = rg_lru_step(c, p, st["h"])
        y = y[:, None]
    else:
        c, new_tail = conv1d_causal(u, p["conv_w"], st["conv"])
        y, hstate = rg_lru(c, p, st["h"])
    x = x + (y * gate) @ p["w_out"]
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + L.glu_mlp(h2, p["mlp"], cfg.act)
    return x, {"h": hstate, "conv": new_tail}


def rec_state_init(cfg: ArchConfig, batch: int, n: int) -> Dict:
    R = cfg.rec_d_rnn
    dt = _dtype(cfg)
    return {"h": jnp.zeros((n, batch, R), jnp.float32),
            "conv": jnp.zeros((n, batch, cfg.rec_conv - 1, R), dt)}


# ---------------------------------------------------------------------------
# local-attention block
# ---------------------------------------------------------------------------

def attn_block_init(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "attn": L.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.hd, dt),
        "mlp": L.glu_mlp_init(k2, cfg.d_model, cfg.d_ff, dt, cfg.act),
    }


def attn_block_fwd(p: Params, x, cfg: ArchConfig, positions,
                   cache: Optional[Dict] = None, pos=None):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = L.gqa_project(h, p["attn"], cfg.n_heads, cfg.n_kv_heads,
                            cfg.hd, positions, cfg.rope_theta)
    if cache is None:
        o = L.attention(q, k, v, causal=True, window=cfg.window)
        # build the ring-buffer window cache from the last W positions so a
        # following decode_step sees exactly the reachable keys
        W = cfg.window
        S = k.shape[1]
        take = min(W, S)
        slots = (jnp.arange(S - take, S) % W)
        B = k.shape[0]
        kc0 = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(
            k[:, -take:])
        vc0 = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(
            v[:, -take:])
        new_cache = {"k": kc0, "v": vc0}
    else:
        # ring-buffer window cache: slot = pos % window
        W = cfg.window
        slot = pos % W
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        # decode: attend to the window's entries; ring positions
        ring_pos = ring_positions(pos, W)
        o = attention_ring(q, kc, vc, ring_pos, pos)
        new_cache = {"k": kc, "v": vc}
    x = x + o.reshape(*o.shape[:2], -1) @ p["attn"]["wo"]
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + L.glu_mlp(h2, p["mlp"], cfg.act), new_cache


def ring_positions(pos, W: int):
    """Absolute position stored in each ring slot after writing at
    ``pos % W``: slot i holds position  pos - ((pos % W - i) mod W)."""
    i = jnp.arange(W)
    return pos - jnp.mod(pos % W - i, W)


def attention_ring(q, kc, vc, ring_pos, pos):
    """Decode attention over a ring-buffer window cache.

    q: (B,1,H,Dh); kc/vc: (B,W,Kh,Dh); ring_pos: (W,) absolute positions
    (<= pos valid, > pos means not yet written)."""
    B, T, H, Dh = q.shape
    Kh = kc.shape[2]
    G = H // Kh
    qs = (q / math.sqrt(Dh)).reshape(B, T, Kh, G, Dh)
    s = jnp.einsum("btkgd,bskd->bkgts", qs, kc,
                   preferred_element_type=jnp.float32)
    valid = (ring_pos >= 0) & (ring_pos <= pos)
    s = jnp.where(valid[None, None, None, None, :], s, L.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", p.astype(vc.dtype), vc)
    return o.reshape(B, T, H, Dh)


def attn_state_init(cfg: ArchConfig, batch: int, n: int) -> Dict:
    dt = _dtype(cfg)
    shape = (n, batch, cfg.window, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _counts(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(#macro blocks, #tail rec layers) for pattern (rec, rec, attn)."""
    nmacro = cfg.n_layers // 3
    tail = cfg.n_layers - 3 * nmacro
    return nmacro, tail, 2 * nmacro + tail   # last = total rec layers


def init_params(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    ke, k1, k2, k3, kh = jax.random.split(key, 5)
    nmacro, tail, _ = _counts(cfg)

    def macro_init(k):
        ka, kb, kc = jax.random.split(k, 3)
        return {"rec1": rec_block_init(ka, cfg),
                "rec2": rec_block_init(kb, cfg),
                "attn": attn_block_init(kc, cfg)}

    p = {
        "embed": L.embed_init(ke, cfg.vocab, cfg.d_model, dt),
        "macro": jax.vmap(macro_init)(jax.random.split(k1, nmacro)),
        "norm_f": jnp.zeros((cfg.d_model,), dt),
        "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, dt),
    }
    if tail:
        p["tail"] = jax.vmap(lambda k: rec_block_init(k, cfg))(
            jax.random.split(k2, tail))
    return p


def init_state(cfg: ArchConfig, batch: int) -> Dict:
    nmacro, tail, _ = _counts(cfg)
    st = {
        "rec1": rec_state_init(cfg, batch, nmacro),
        "rec2": rec_state_init(cfg, batch, nmacro),
        "attn": attn_state_init(cfg, batch, nmacro),
        "pos": jnp.zeros((), jnp.int32),
    }
    if tail:
        st["tail"] = rec_state_init(cfg, batch, tail)
    return st


def forward(params: Params, cfg: ArchConfig, tokens, state=None, *,
            remat: bool = True, decode_pos=None):
    B, S = tokens.shape
    x = params["embed"][tokens] * jnp.asarray(
        math.sqrt(cfg.d_model), _dtype(cfg))
    if state is None:
        state = init_state(cfg, B)
    decode = decode_pos is not None
    positions = (decode_pos + jnp.arange(S)) if decode else jnp.arange(S)

    def macro_body(x, layer_in):
        pl, s1, s2, sa = layer_in
        x, s1n = rec_block_fwd(pl["rec1"], x, cfg, s1)
        x, s2n = rec_block_fwd(pl["rec2"], x, cfg, s2)
        if decode:
            x, can = attn_block_fwd(pl["attn"], x, cfg, positions,
                                    cache=sa, pos=decode_pos)
        else:
            x, can = attn_block_fwd(pl["attn"], x, cfg, positions)
        return x, (s1n, s2n, can)

    fn = jax.checkpoint(macro_body,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else macro_body
    x, (s1, s2, sa) = jax.lax.scan(
        fn, x, (params["macro"], state["rec1"], state["rec2"],
                state["attn"]))
    new_state = {"rec1": s1, "rec2": s2, "attn": sa,
                 "pos": state["pos"] + S}
    if "tail" in params:
        def tail_body(x, layer_in):
            pl, st = layer_in
            x, stn = rec_block_fwd(pl, x, cfg, st)
            return x, stn
        tfn = jax.checkpoint(tail_body,
                             policy=jax.checkpoint_policies.nothing_saveable
                             ) if remat else tail_body
        x, st_t = jax.lax.scan(tfn, x, (params["tail"], state["tail"]))
        new_state["tail"] = st_t
    x = L.rmsnorm(x, params["norm_f"], cfg.norm_eps)
    return x, new_state


def prefill(params, cfg, tokens, patches=None):
    """Prefill via forward; the ring-buffer window caches are built from
    the final ``window`` positions inside attn_block_fwd."""
    x, st = forward(params, cfg, tokens, remat=False)
    return st, x[:, -1:] @ params["lm_head"]


def decode_step(params, cfg, token, pos, state):
    x, st = forward(params, cfg, token, state, remat=False, decode_pos=pos)
    return x @ params["lm_head"], st
