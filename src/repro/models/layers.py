"""Shared JAX building blocks for the model zoo.

Pure functions over explicit parameter pytrees (dicts of jnp arrays) — no
framework dependency.  Attention is blockwise (online softmax over KV
chunks) so the S x S score matrix is never materialized; on TPU the Pallas
flash-attention kernel (src/repro/kernels) implements the same contract.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed import hints

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    n = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (n * (1.0 + g.astype(jnp.float32))).astype(dt)


def glu_mlp(x: jnp.ndarray, p: Params, act: str) -> jnp.ndarray:
    """SwiGLU / GeGLU: (act(x W_g) * (x W_u)) W_d — or, when the params
    carry no gate matrix ("gelu" archs like StarCoder2), a plain 2-matrix
    act(x W_u) W_d."""
    u = x @ p["wu"]
    if "wg" not in p:
        if u.ndim == 3:
            u = hints.constrain(u, "dp", None, "model")
        return jax.nn.gelu(u) @ p["wd"]
    g = x @ p["wg"]
    h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    if h.ndim == 3:
        h = hints.constrain(h, "dp", None, "model")
    return h @ p["wd"]


def glu_mlp_init(key, d: int, f: int, dtype, act: str = "swiglu") -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wu": dense_init(k2, d, f, dtype),
         "wd": dense_init(k3, f, d, dtype)}
    if act != "gelu":
        p["wg"] = dense_init(k1, d, f, dtype)
    return p


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, Dh); positions: (T,) or broadcastable."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (T, half)
    cos = jnp.cos(ang)[..., None, :]                            # (T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    return jnp.concatenate([
        (x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin),
        (x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin),
    ], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# blockwise attention (the jnp reference contract for the Pallas kernel)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, q_offset=0, window: int = 0,
              kv_len=None, block: int = 1024) -> jnp.ndarray:
    """Online-softmax attention.

    q: (B, T, H, Dh);  k, v: (B, S, Kh, Dh) with H % Kh == 0 (GQA).
    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``window`` > 0: sliding-window (local) attention.
    ``kv_len``: scalar/array — keys at positions >= kv_len are masked
    (partially-filled cache).
    Never materializes (T, S) for S > block: scans KV blocks.
    """
    B, T, H, Dh = q.shape
    S, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    # decode (tiny T, long cache): keep the cache SEQUENCE-sharded and
    # compute partial softmax per shard — resharding the cache to head
    # sharding would all-gather S x Kh x Dh every step (measured: 64 GB
    # per decode step on llama3-8b/decode_32k before this branch existed)
    if T <= 16 and S >= 4096:
        q = hints.constrain(q, "dp", None, None, None)
        k = hints.constrain(k, "dp", "spm", None, None)
        v = hints.constrain(v, "dp", "spm", None, None)
        scale = 1.0 / math.sqrt(Dh)
        qs = (q * scale).reshape(B, T, Kh, G, Dh)
        s = jnp.einsum("btkgd,bskd->bkgts", qs, k,
                       preferred_element_type=jnp.float32)
        pos_k = jnp.arange(S)
        q_pos = q_offset + jnp.arange(T)
        mask = jnp.ones((T, S), dtype=bool)
        if causal:
            mask = mask & (pos_k[None, :] <= q_pos[:, None])
        if window:
            mask = mask & (pos_k[None, :] > q_pos[:, None] - window)
        if kv_len is not None:
            mask = mask & (pos_k[None, :] < kv_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgts,bskd->btkgd", (p / l).astype(v.dtype), v)
        return o.reshape(B, T, H, Dh)
    # sharding: heads over 'model' when divisible (Megatron attention);
    # otherwise fall back to sequence parallelism — shard the query rows
    # and let K/V be gathered per layer (cheap relative to replicating
    # the whole attention compute 'model'-fold)
    if hints.divides("model", H):
        if not hints.divides("model", Kh):
            # GQA with kv_heads < TP degree: duplicate each KV head so the
            # head dim shards cleanly (MaxText-style) — removes the KV
            # all-gather + replicated-KV gradient all-reduce entirely at
            # the cost of r-fold duplicate KV projections
            import math as _m
            msize = hints.MESH.shape["model"]
            r = msize // _m.gcd(Kh, msize)
            if r > 1 and G % r == 0:
                k = jnp.repeat(k, r, axis=2)
                v = jnp.repeat(v, r, axis=2)
                Kh, G = Kh * r, G // r
        q = hints.constrain(q, "dp", None, "model", None)
        k = hints.constrain(k, "dp", None, "model", None)
        v = hints.constrain(v, "dp", None, "model", None)
        head_sharded = True
    else:
        q = hints.constrain(q, "dp", "spm", None, None)
        k = hints.constrain(k, "dp", None, None, None)
        v = hints.constrain(v, "dp", None, None, None)
        head_sharded = False
    return _attention_inner(q, k, v, causal=causal, q_offset=q_offset,
                            window=window, kv_len=kv_len, block=block,
                            head_sharded=head_sharded)


def _attention_inner(*args, **kw):
    with jax.named_scope("attention_kernel"):
        return _attention_inner_impl(*args, **kw)


def _attention_inner_impl(q, k, v, *, causal, q_offset, window, kv_len,
                          block, head_sharded):
    """The part the Pallas flash kernel replaces on TPU — wrapped in a
    named scope so the HLO analyzer can attribute its traffic."""
    B, T, H, Dh = q.shape
    S, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    scale = 1.0 / math.sqrt(Dh)
    qs = (q * scale).reshape(B, T, Kh, G, Dh)
    q_pos = q_offset + jnp.arange(T)

    def block_scores(kb, pos_k):
        # kb: (B, Sb, Kh, Dh) -> scores (B, Kh, G, T, Sb), fp32
        s = jnp.einsum("btkgd,bskd->bkgts", qs, kb,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((T, kb.shape[1]), dtype=bool)
        if causal:
            mask &= pos_k[None, :] <= q_pos[:, None]
        if window:
            mask &= pos_k[None, :] > q_pos[:, None] - window
        if kv_len is not None:
            mask &= pos_k[None, :] < kv_len
        return jnp.where(mask[None, None, None], s, NEG_INF)

    if S <= 2 * block:
        pos_k = jnp.arange(S)
        s = block_scores(k, pos_k)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jax.lax.stop_gradient(m))
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgts,bskd->btkgd", (p / l).astype(v.dtype), v)
        o = o.reshape(B, T, H, Dh)
        return hints.constrain(o, "dp", None, "model", None) \
            if head_sharded else hints.constrain(o, "dp", "spm", None, None)

    nb = (S + block - 1) // block
    pad = nb * block - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, block, Kh, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block, Kh, Dh).transpose(1, 0, 2, 3, 4)
    eff_len = kv_len if kv_len is not None else S

    def step(carry, blk):
        m, l, acc, i = carry
        kblk, vblk = blk
        if head_sharded:
            kblk = hints.constrain(kblk, "dp", None, "model", None)
            vblk = hints.constrain(vblk, "dp", None, "model", None)
        pos_k = i * block + jnp.arange(block)
        s = block_scores(kblk, jnp.where(pos_k < eff_len, pos_k, 1 << 30))
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr[..., 0][..., None] + jnp.einsum(
            "bkgts,bskd->bkgtd", p.astype(vblk.dtype), vblk
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new, i + 1), None

    m0 = jnp.full((B, Kh, G, T, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kh, G, T, 1), jnp.float32)
    a0 = jnp.zeros((B, Kh, G, T, Dh), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, 0), (kb, vb))
    o = (acc / l).astype(q.dtype)                     # (B, Kh, G, T, Dh)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, T, H, Dh)
    return hints.constrain(o, "dp", None, "model", None) \
        if head_sharded else hints.constrain(o, "dp", "spm", None, None)


def gqa_init(key, d: int, n_heads: int, n_kv: int, hd: int, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"wq": dense_init(k1, d, n_heads * hd, dtype),
            "wk": dense_init(k2, d, n_kv * hd, dtype),
            "wv": dense_init(k3, d, n_kv * hd, dtype),
            "wo": dense_init(k4, n_heads * hd, d, dtype)}


def gqa_project(x: jnp.ndarray, p: Params, n_heads: int, n_kv: int, hd: int,
                positions, theta: float, use_rope: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, T, _ = x.shape
    q = (x @ p["wq"]).reshape(B, T, n_heads, hd)
    k = (x @ p["wk"]).reshape(B, T, n_kv, hd)
    v = (x @ p["wv"]).reshape(B, T, n_kv, hd)
    if use_rope:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity-based dense dispatch)
# ---------------------------------------------------------------------------

def moe_init(key, d: int, num_experts: int, d_ff: int, dtype) -> Params:
    kr, kg, ku, kd = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": dense_init(kr, d, num_experts, dtype),
        "wg": (jax.random.normal(kg, (num_experts, d, d_ff)) * s_in
               ).astype(dtype),
        "wu": (jax.random.normal(ku, (num_experts, d, d_ff)) * s_in
               ).astype(dtype),
        "wd": (jax.random.normal(kd, (num_experts, d_ff, d)) * s_out
               ).astype(dtype),
    }


def moe_mlp(x: jnp.ndarray, p: Params, top_k: int, capacity_factor: float,
            act: str = "swiglu", group_size: int = 512,
            expert_sharding: str = "tp") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routed MoE, GShard-style grouped capacity dispatch.

    Tokens are split into groups of ``group_size``; each group dispatches
    its tokens to per-expert buffers of capacity ``cf * k * group / E`` via
    one-hot contractions (GSPMD-canonical: the group axis shards over
    'data', the expert axis over 'model' for "ep" sharding; over-capacity
    tokens are dropped as in GShard).  Returns (output, aux_loss).
    """
    B, S, D = x.shape
    E = p["router"].shape[-1]
    T = B * S
    Sg = min(group_size, T)
    G = T // Sg
    assert G * Sg == T, f"tokens {T} not divisible by group {Sg}"
    xg = x.reshape(G, Sg, D)
    logits = jnp.einsum("gsd,de->gse", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, Sg, E)
    gate_vals, idx = jax.lax.top_k(probs, top_k)             # (G, Sg, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    # decode-sized groups: give every assignment a slot (no drops)
    cap = min(Sg * top_k,
              max(top_k, int(capacity_factor * top_k * Sg / E) + 1))

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)         # (G, Sg, k, E)
    flat = onehot.reshape(G, Sg * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                    # (G, Sg*k, E)
    pos_in_e = jnp.sum(pos * flat, axis=-1).reshape(G, Sg, top_k)
    keep = pos_in_e < cap
    gate_vals = gate_vals * keep

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, cap), cap + 1,
                            dtype=x.dtype)[..., :cap]        # (G, Sg, k, cap)
    # dispatch (G, Sg, E, cap): a token occupies each expert at most once.
    # one-hots are piecewise-constant: stop_gradient prevents XLA from
    # materializing (and all-reducing) their identically-zero cotangents —
    # measured 2.6 TB/device of f32 all-reduce on grok-1 before this
    disp = jax.lax.stop_gradient(
        jnp.einsum("gske,gskc->gsec", onehot.astype(x.dtype), pos_oh))
    xin = jnp.einsum("gsd,gsec->gecd", xg, disp)             # (G, E, cap, D)
    e_ax = "model" if expert_sharding == "ep" else None
    f_ax = None if expert_sharding == "ep" else "model"
    xin = hints.constrain(xin, "dp", e_ax, None, None)       # EP: all-to-all
    g = jnp.einsum("gecd,edf->gecf", xin, p["wg"])
    u = jnp.einsum("gecd,edf->gecf", xin, p["wu"])
    g = hints.constrain(g, "dp", e_ax, None, f_ax)
    h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    out_e = jnp.einsum("gecf,efd->gecd", h, p["wd"])         # (G, E, cap, D)
    # NOTE: pinning this psum point to replicated was tried and REFUTED
    # (collective 174 -> 189 s on grok-1; see EXPERIMENTS.md §Perf) —
    # UNCONSTRAINED lets the solver place the reduction better
    out_e = hints.constrain(out_e, "dp", e_ax, None, None)
    comb = jnp.einsum("gsec,gske,gsk->gsec", disp,
                      jax.lax.stop_gradient(onehot.astype(x.dtype)),
                      gate_vals.astype(x.dtype))
    out = jnp.einsum("gecd,gsec->gsd", out_e, comb)
    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                       axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * router_prob)
    return out.reshape(B, S, D), aux
