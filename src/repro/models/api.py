"""Unified model API: dispatch by architecture family.

Functions every family provides (shapes in family modules):
  init_params(key, cfg)                          -> params
  forward_hidden(params, cfg, batch)             -> (hidden, aux)   [train]
  prefill(params, cfg, batch)                    -> (cache, logits)
  decode_step(params, cfg, token, pos, cache)    -> (logits, cache)
  init_cache(cfg, batch, max_len)                -> cache           [decode]
plus ``input_specs`` / ``make_batch`` describing the inputs of each shape
kind (tokens, labels, stub frame/patch embeddings).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig
from . import encdec, griffin, rwkv6, transformer

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs for the dry-run; numpy for smoke tests)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Stand-ins for every model input of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    f32 = jnp.float32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            # source frames (stub) scale with the shape's sequence length;
            # decoder text is S//8 tokens (ASR-ish compression)
            return {"frames": sds((B, S, cfg.d_model), f32),
                    "tokens": sds((B, max(S // 8, 16)), jnp.int32),
                    "labels": sds((B, max(S // 8, 16)), jnp.int32)}
        if cfg.family == "vlm":
            P = cfg.frontend_len
            return {"patches": sds((B, P, cfg.d_model), f32),
                    "tokens": sds((B, S - P), jnp.int32),
                    "labels": sds((B, S - P), jnp.int32)}
        return {"tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}
    # decode: one new token against a cache of S
    return {"token": sds((B, 1), jnp.int32)}


def make_batch(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0
               ) -> Dict[str, np.ndarray]:
    """Synthetic concrete batch matching input_specs.

    Token streams are LEARNABLE: each sequence is an affine cycle
    ``tok[t+1] = (tok[t] + stride) % vocab`` with a per-sequence random
    start/stride, and ``labels`` are the next-token shift — so a real
    training run shows decreasing loss instead of noise around
    ln(vocab)."""
    rng = np.random.default_rng(seed)
    out = {}
    specs = input_specs(cfg, shape)
    for name, spec in specs.items():
        if spec.dtype != jnp.int32:
            out[name] = rng.normal(size=spec.shape).astype(np.float32) * 0.1
        elif name == "tokens" or name == "token":
            B = spec.shape[0]
            S = spec.shape[1] if len(spec.shape) > 1 else 1
            start = rng.integers(0, cfg.vocab, size=(B, 1))
            stride = rng.integers(1, min(cfg.vocab, 17), size=(B, 1))
            toks = (start + stride * np.arange(S)[None, :]) % cfg.vocab
            out[name] = toks.astype(np.int32)
    if "labels" in specs:
        toks = out["tokens"]
        out["labels"] = np.roll(toks, -1, axis=1).astype(np.int32)
        out["labels"][:, -1] = -1      # no target for the final position
    return out


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig) -> Params:
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_params(key, cfg)
    if cfg.family == "ssm":
        return rwkv6.init_params(key, cfg)
    if cfg.family == "hybrid":
        return griffin.init_params(key, cfg)
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg)
    raise ValueError(cfg.family)


def forward_hidden(params: Params, cfg: ArchConfig, batch: Dict, *,
                   remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Hidden states for the training loss (+ MoE aux)."""
    zero = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "moe"):
        h, _, aux = transformer.forward(params, cfg, batch["tokens"],
                                        remat=remat)
        return h, aux
    if cfg.family == "vlm":
        h, _, aux = transformer.forward(params, cfg, batch["tokens"],
                                        patches=batch["patches"],
                                        remat=remat)
        # loss only over the text positions
        P = cfg.frontend_len
        return h[:, P:], aux
    if cfg.family == "ssm":
        h, _ = rwkv6.forward(params, cfg, batch["tokens"], remat=remat)
        return h, zero
    if cfg.family == "hybrid":
        h, _ = griffin.forward(params, cfg, batch["tokens"], remat=remat)
        return h, zero
    if cfg.family == "encdec":
        h = encdec.forward_train(params, cfg, batch["frames"],
                                 batch["tokens"], remat=remat)
        return h, zero
    raise ValueError(cfg.family)


def lm_head(params: Params, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.lm_head(params, cfg)
    return params["lm_head"]


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return rwkv6.init_state(cfg, batch)
    if cfg.family == "hybrid":
        return griffin.init_state(cfg, batch)
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_len, mem_len=4096)
    raise ValueError(cfg.family)


def prefill(params: Params, cfg: ArchConfig, batch: Dict):
    if cfg.family in ("dense", "moe"):
        return transformer.prefill(params, cfg, batch["tokens"])
    if cfg.family == "vlm":
        return transformer.prefill(params, cfg, batch["tokens"],
                                   patches=batch["patches"])
    if cfg.family == "ssm":
        return rwkv6.prefill(params, cfg, batch["tokens"])
    if cfg.family == "hybrid":
        return griffin.prefill(params, cfg, batch["tokens"])
    if cfg.family == "encdec":
        return encdec.prefill(params, cfg, batch["frames"], batch["tokens"])
    raise ValueError(cfg.family)


def decode_step(params: Params, cfg: ArchConfig, token, pos, cache):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.decode_step(params, cfg, token, pos, cache)
    if cfg.family == "ssm":
        return rwkv6.decode_step(params, cfg, token, pos, cache)
    if cfg.family == "hybrid":
        return griffin.decode_step(params, cfg, token, pos, cache)
    if cfg.family == "encdec":
        return encdec.decode_step(params, cfg, token, pos, cache)
    raise ValueError(cfg.family)
