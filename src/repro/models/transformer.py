"""Decoder-only transformer family: dense GQA, MoE, and VLM-backbone.

Covers llama3-8b, phi3-medium-14b, starcoder2-7b, gemma-2b (dense),
grok-1-314b, moonshot-v1-16b-a3b (MoE), internvl2-1b (vision-stub prefix).

Layer parameters are stacked on a leading L axis and executed with
``jax.lax.scan`` (+ ``jax.checkpoint`` for train) so compile time and HLO
size are depth-independent — essential for the 512-device dry-run.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers as L
from ..distributed import hints

Params = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p: Params = {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "attn": L.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.hd, dt),
    }
    if cfg.moe:
        p["moe"] = L.moe_init(k2, cfg.d_model, cfg.moe.num_experts,
                              cfg.moe.d_ff_expert, dt)
    else:
        p["mlp"] = L.glu_mlp_init(k2, cfg.d_model, cfg.d_ff, dt, cfg.act)
    return p


def init_params(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    ke, kl, kh, kf = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p: Params = {
        "embed": L.embed_init(ke, cfg.vocab, cfg.d_model, dt),
        "layers": stacked,
        "norm_f": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab, dt)
    if cfg.frontend == "vision":
        # connector from stub patch embeddings (at d_model) into the LM
        p["connector"] = L.dense_init(kf, cfg.d_model, cfg.d_model, dt)
    return p


def lm_head(params: Params, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# one layer
# ---------------------------------------------------------------------------

def layer_fwd(p: Params, x: jnp.ndarray, cfg: ArchConfig,
              positions: jnp.ndarray, *, causal: bool = True,
              kv_override: Optional[Tuple] = None,
              kv_len=None, q_offset=0
              ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray],
                         jnp.ndarray]:
    """Pre-norm block.  Returns (x_out, (k, v) of THIS segment, aux_loss)."""
    x = hints.constrain(x, "dp", "sp", None)
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = L.gqa_project(h, p["attn"], cfg.n_heads, cfg.n_kv_heads,
                            cfg.hd, positions, cfg.rope_theta)
    if kv_override is not None:
        k_all, v_all = kv_override
    else:
        k_all, v_all = k, v
    o = L.attention(q, k_all, v_all, causal=causal, q_offset=q_offset,
                    window=cfg.window, kv_len=kv_len)
    x = x + o.reshape(*o.shape[:2], -1) @ p["attn"]["wo"]
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        m, aux = L.moe_mlp(h2, p["moe"], cfg.moe.top_k,
                           cfg.moe.capacity_factor,
                           act=cfg.act,
                           group_size=cfg.moe.group_size,
                           expert_sharding=cfg.moe.sharding)
    else:
        m = L.glu_mlp(h2, p["mlp"], cfg.act)
    return x + m, (k, v), aux


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_inputs(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                 patches: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = params["embed"][tokens]
    if cfg.family == "dense" and cfg.tie_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)   # gemma scale
    if patches is not None:
        pref = patches.astype(x.dtype) @ params["connector"]
        x = jnp.concatenate([pref, x], axis=1)
    return x


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            patches: Optional[jnp.ndarray] = None, *,
            collect_cache: bool = False, remat: bool = True
            ) -> Tuple[jnp.ndarray, Optional[Tuple], jnp.ndarray]:
    """Returns (hidden (B,S,D), optional stacked (k, v) cache, aux_loss)."""
    x = embed_inputs(params, cfg, tokens, patches)
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(carry, pl):
        x, aux = carry
        x, (k, v), a = layer_fwd(pl, x, cfg, positions)
        ys = (k, v) if collect_cache else None
        return (x, aux + a), ys

    fn = jax.checkpoint(body,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    (x, aux), kv = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                params["layers"])
    x = L.rmsnorm(x, params["norm_f"], cfg.norm_eps)
    return x, kv, aux


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    dt = _dtype(cfg)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def prefill(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            patches: Optional[jnp.ndarray] = None
            ) -> Tuple[Params, jnp.ndarray]:
    """Run the prompt, return (cache, last-token logits)."""
    x, kv, _ = forward(params, cfg, tokens, patches, collect_cache=True,
                       remat=False)
    logits = x[:, -1:] @ lm_head(params, cfg)
    return {"k": kv[0], "v": kv[1]}, logits


def decode_step(params: Params, cfg: ArchConfig, token: jnp.ndarray,
                pos, cache: Params) -> Tuple[jnp.ndarray, Params]:
    """One-token decode against a KV cache.

    token: (B, 1) int32; pos: scalar int32 — current length (same for the
    batch; per-request lengths are handled by the serving layer's bucketing).
    """
    x = params["embed"][token]
    if cfg.family == "dense" and cfg.tie_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    positions = pos + jnp.arange(1)

    def body(x, layer_in):
        pl, kc, vc = layer_in
        kc = hints.constrain(kc, "dp", "model", None, None)
        vc = hints.constrain(vc, "dp", "model", None, None)
        h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
        q, k, v = L.gqa_project(h, pl["attn"], cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos,
                                                 axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos,
                                                 axis=1)
        o = L.attention(q, kc, vc, causal=False, q_offset=pos,
                        window=cfg.window, kv_len=pos + 1)
        x = x + o.reshape(*o.shape[:2], -1) @ pl["attn"]["wo"]
        h2 = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
        if cfg.moe:
            m, _ = L.moe_mlp(h2, pl["moe"], cfg.moe.top_k,
                             cfg.moe.capacity_factor, act=cfg.act,
                             group_size=cfg.moe.group_size,
                             expert_sharding=cfg.moe.sharding)
        else:
            m = L.glu_mlp(h2, pl["mlp"], cfg.act)
        return x + m, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["norm_f"], cfg.norm_eps)
    logits = x @ lm_head(params, cfg)
    return logits, {"k": k_new, "v": v_new}
