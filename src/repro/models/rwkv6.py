"""RWKV-6 "Finch" (attention-free, data-dependent decay) [arXiv:2404.05892].

Per head of size N: state S in R^{NxN};
    y_t = (S_{t-1} + (u * k_t) v_t^T)^T r_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(ww_t))
with token-shift data-dependent mixing (LoRA) for r/k/v/w/g and a gated
GroupNorm output, plus the squared-ReLU channel-mix FFN.

Prefill/train uses the **chunked parallel form** (the contract of the
Pallas kernel in src/repro/kernels/rwkv6_wkv.py): within a chunk all decay
factors appear as exp(c_i - c_j) with i >= j, which is <= 1 — numerically
safe; across chunks the state is carried with exp(c_end - c_j) <= 1.
Decode carries (S, shift states) — O(1) memory in context length.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers as L
from ..distributed import hints

Params = Dict[str, Any]
LORA = 32


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_layer(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    H = cfg.n_heads
    N = cfg.hd
    s = 1.0 / math.sqrt(d)
    return {
        "ln1": jnp.zeros((d,), dt),
        "ln2": jnp.zeros((d,), dt),
        # token-shift mixing params (maa = "mix with shifted")
        "maa_x": jnp.zeros((d,), dt),
        "maa_rkvwg": jnp.zeros((5, d), dt),
        "maa_w1": (jax.random.normal(ks[0], (d, 5 * LORA)) * s).astype(dt),
        "maa_w2": (jax.random.normal(ks[1], (5, LORA, d)) * 0.01).astype(dt),
        # decay base + LoRA
        "decay": jnp.zeros((d,), jnp.float32) - 4.0,
        "dec_w1": (jax.random.normal(ks[2], (d, 2 * LORA)) * s).astype(dt),
        "dec_w2": (jax.random.normal(ks[3], (2 * LORA, d)) * 0.01).astype(dt),
        "bonus": jnp.zeros((H, N), jnp.float32) + 0.5,        # u
        "wr": L.dense_init(ks[4], d, d, dt),
        "wk": L.dense_init(ks[5], d, d, dt),
        "wv": L.dense_init(ks[6], d, d, dt),
        "wg": L.dense_init(ks[7], d, d, dt),
        "wo": L.dense_init(ks[8], d, d, dt),
        "gn": jnp.ones((d,), dt),                             # group norm
        # channel mix
        "cm_mix_k": jnp.zeros((d,), dt),
        "cm_mix_r": jnp.zeros((d,), dt),
        "cm_k": L.dense_init(ks[9], d, cfg.d_ff, dt),
        "cm_v": L.dense_init(ks[10], cfg.d_ff, d, dt),
        "cm_r": L.dense_init(ks[11], d, d, dt),
    }


def init_params(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    ke, kl, kh = jax.random.split(key, 3)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(
        jax.random.split(kl, cfg.n_layers))
    return {
        "embed": L.embed_init(ke, cfg.vocab, cfg.d_model, dt),
        "layers": stacked,
        "norm_f": jnp.zeros((cfg.d_model,), dt),
        "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, dt),
    }


# ---------------------------------------------------------------------------
# WKV6 core (chunked parallel form — reference for the Pallas kernel)
# ---------------------------------------------------------------------------

def wkv6_chunked(r, k, v, logw, u, state, chunk: int = 32):
    """r,k,v: (B,T,H,N); logw: (B,T,H,N) (log decay, < 0); u: (H,N);
    state: (B,H,N,N).  Returns (y (B,T,H,N), new state).
    """
    with jax.named_scope("wkv6_kernel"):
        return _wkv6_chunked_impl(r, k, v, logw, u, state, chunk)


def _wkv6_chunked_impl(r, k, v, logw, u, state, chunk: int = 32):
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        # pad tail with k=0 (no state contribution), logw=0 (w=1: state
        # passes through unchanged); padded outputs are sliced off below
        zeros = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(a, zeros) for a in (r, k, v))
        logw = jnp.pad(logw, zeros)
        T_out, T = T, T + pad
    else:
        T_out = T
    nc = T // chunk
    rc = r.reshape(B, nc, chunk, H, N)
    kc = k.reshape(B, nc, chunk, H, N)
    vc = v.reshape(B, nc, chunk, H, N)
    wc = logw.reshape(B, nc, chunk, H, N).astype(jnp.float32)

    def chunk_step(S, xs):
        rch, kch, vch, wch = xs                 # (B, C, H, N)
        S = hints.constrain(S, "dp", "model", None, None)
        c = jnp.cumsum(wch, axis=1)             # inclusive logs
        c_prev = c - wch                        # exclusive
        c_end = c[:, -1:]                       # (B,1,H,N)
        # intra-chunk: scores[t,s] = sum_n r[t]k[s]exp(c_prev[t]-c[s]), s<t
        rt = rch.astype(jnp.float32) * jnp.exp(c_prev)
        # mask strictly-lower triangular; bound each factor via the masked
        # product trick: exp(c_prev[t]-c[s]) <= 1 for s <= t-1, but the
        # factorized exps individually can overflow — so fold the bound in:
        # compute scores via a (C,C,N) product with the exponent clamped.
        expo = c_prev[:, :, None] - c[:, None]          # (B,C,C,H,N)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        expo = jnp.where(mask[None, :, :, None, None], expo, -jnp.inf)
        scores = jnp.einsum("bthn,bshn,btshn->bhts",
                            rch.astype(jnp.float32),
                            kch.astype(jnp.float32), jnp.exp(expo))
        y = jnp.einsum("bhts,bshn->bthn", scores, vch.astype(jnp.float32))
        # bonus (diagonal) term
        y += jnp.einsum("bthn,hn,bthn,bthm->bthm".replace("m", "z"),
                        rch.astype(jnp.float32), u,
                        kch.astype(jnp.float32),
                        vch.astype(jnp.float32))
        # inter-chunk: contribution of the carried state
        y += jnp.einsum("bthn,bhnz->bthz", rt, S)
        # state update: S' = diag(e^{c_end}) S + sum_s (k_s e^{c_end-c_s}) v_s
        khat = kch.astype(jnp.float32) * jnp.exp(c_end - c)
        S = S * jnp.exp(c_end[:, 0])[..., None] + \
            jnp.einsum("bshn,bshz->bhnz", khat, vch.astype(jnp.float32))
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3, 4) for a in (rc, kc, vc, wc))
    state, ys = jax.lax.scan(chunk_step, state.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, N)[:, :T_out]
    return y.astype(r.dtype), state


def wkv6_step(r, k, v, logw, u, state):
    """Single-token recurrence (decode).  r,k,v,logw: (B,H,N)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))                   # (B,H,N)
    kv = jnp.einsum("bhn,bhz->bhnz", kf, vf)
    y = jnp.einsum("bhn,bhnz->bhz", rf, state + u[..., None] * kv)
    state = state * w[..., None] + kv
    return y.astype(r.dtype), state


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _ddlerp(x, x_prev, p):
    """Data-dependent token-shift mixing -> (5, B, T, D) mixed inputs."""
    xx = x_prev - x
    xxx = x + xx * p["maa_x"]
    z = jnp.tanh(xxx @ p["maa_w1"])                  # (B,T,5*LORA)
    B, T, _ = z.shape
    z = z.reshape(B, T, 5, LORA)
    mix = jnp.einsum("btfk,fkd->btfd", z, p["maa_w2"].astype(z.dtype))
    mix = mix + p["maa_rkvwg"].astype(z.dtype)       # (B,T,5,D)
    out = x[:, :, None, :] + xx[:, :, None, :] * mix
    return [out[:, :, i, :].astype(x.dtype) for i in range(5)]


def _decay(xw, p):
    z = jnp.tanh(xw @ p["dec_w1"][:, :LORA])
    lora = z @ p["dec_w2"][:LORA].astype(z.dtype)
    ww = p["decay"].astype(jnp.float32) + lora.astype(jnp.float32)
    return -jnp.exp(ww)                              # log decay, < 0


def _shift(x, last):
    """Token shift: x_prev[t] = x[t-1]; position 0 gets ``last``."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def time_mix(p, x, last_x, state, cfg: ArchConfig, chunk: int = 32):
    B, T, D = x.shape
    H, N = cfg.n_heads, cfg.hd
    xp = _shift(x, last_x)
    xr, xk, xv, xw, xg = _ddlerp(x, xp, p)
    r = hints.constrain((xr @ p["wr"]).reshape(B, T, H, N),
                        "dp", None, "model", None)
    k = hints.constrain((xk @ p["wk"]).reshape(B, T, H, N),
                        "dp", None, "model", None)
    v = hints.constrain((xv @ p["wv"]).reshape(B, T, H, N),
                        "dp", None, "model", None)
    g = jax.nn.silu(xg @ p["wg"])
    logw = _decay(xw, p).reshape(B, T, H, N)
    u = p["bonus"]
    if T == 1:
        y, state = wkv6_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], u, state)
        y = y[:, None]
    else:
        y, state = wkv6_chunked(r, k, v, logw, u, state, chunk=chunk)
    # per-head group norm
    yf = y.reshape(B, T, H, N).astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = ((yf - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, T, D)
    out = (yn.astype(x.dtype) * p["gn"]) * g
    return out @ p["wo"], x[:, -1, :], state


def channel_mix(p, x, last_x):
    xp = _shift(x, last_x)
    xk = x + (xp - x) * p["cm_mix_k"]
    xr = x + (xp - x) * p["cm_mix_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    return jax.nn.sigmoid(xr @ p["cm_r"]) * (kk @ p["cm_v"]), x[:, -1, :]


def layer_fwd(p, x, cfg: ArchConfig, st: Dict[str, jnp.ndarray]
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, last_tm, S = time_mix(p, h, st["x_tm"], st["S"], cfg)
    x = x + o
    h2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    o2, last_cm = channel_mix(p, h2, st["x_cm"])
    return x + o2, {"S": S, "x_tm": last_tm, "x_cm": last_cm}


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def init_state(cfg: ArchConfig, batch: int) -> Dict[str, jnp.ndarray]:
    H, N, D, Lr = cfg.n_heads, cfg.hd, cfg.d_model, cfg.n_layers
    dt = _dtype(cfg)
    return {"S": jnp.zeros((Lr, batch, H, N, N), jnp.float32),
            "x_tm": jnp.zeros((Lr, batch, D), dt),
            "x_cm": jnp.zeros((Lr, batch, D), dt)}


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            state: Optional[Dict] = None, *, remat: bool = True,
            collect_state: bool = False):
    B, S = tokens.shape
    x = params["embed"][tokens]
    if state is None:
        state = init_state(cfg, B)

    def body(x, layer_in):
        pl, st = layer_in
        x, st_new = layer_fwd(pl, x, cfg, st)
        return x, st_new

    fn = jax.checkpoint(body,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    x, st = jax.lax.scan(fn, x, (params["layers"], state))
    x = L.rmsnorm(x, params["norm_f"], cfg.norm_eps)
    return x, st


def prefill(params, cfg, tokens, patches=None):
    x, st = forward(params, cfg, tokens, remat=False)
    return st, x[:, -1:] @ params["lm_head"]


def decode_step(params, cfg, token, pos, state):
    x, st = forward(params, cfg, token, state, remat=False)
    return x @ params["lm_head"], st
