from . import api, encdec, griffin, layers, rwkv6, transformer

__all__ = ["api", "layers", "transformer", "rwkv6", "griffin", "encdec"]
