"""Encoder-decoder backbone (seamless-m4t-large-v2).

The audio frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings (B, S_src, d_model).  Encoder: bidirectional
self-attention stack.  Decoder: causal self-attention + cross-attention to
the encoder memory.  Decode caches both the self-attn KV and the
(precomputed) per-layer cross-attn K/V of the memory.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers as L

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _enc_layer_init(key, cfg):
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": L.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, dt),
            "mlp": L.glu_mlp_init(k2, cfg.d_model, cfg.d_ff, dt, cfg.act)}


def _dec_layer_init(key, cfg):
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = _enc_layer_init(k1, cfg)
    p["ln_x"] = jnp.zeros((cfg.d_model,), dt)
    p["xattn"] = L.gqa_init(k3, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.hd, dt)
    return p


def init_params(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    ke, k1, k2, kh = jax.random.split(key, 4)
    return {
        "embed": L.embed_init(ke, cfg.vocab, cfg.d_model, dt),
        "enc": jax.vmap(lambda k: _enc_layer_init(k, cfg))(
            jax.random.split(k1, cfg.enc_layers)),
        "dec": jax.vmap(lambda k: _dec_layer_init(k, cfg))(
            jax.random.split(k2, cfg.dec_layers)),
        "norm_enc": jnp.zeros((cfg.d_model,), dt),
        "norm_f": jnp.zeros((cfg.d_model,), dt),
        "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab, dt),
    }


def encode(params: Params, cfg: ArchConfig, frames: jnp.ndarray, *,
           remat: bool = True) -> jnp.ndarray:
    """frames: (B, S_src, D) stub embeddings -> encoder memory."""
    S = frames.shape[1]
    positions = jnp.arange(S)

    def body(x, pl):
        h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
        q, k, v = L.gqa_project(h, pl["attn"], cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, positions, cfg.rope_theta)
        o = L.attention(q, k, v, causal=False)
        x = x + o.reshape(*o.shape[:2], -1) @ pl["attn"]["wo"]
        h2 = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
        return x + L.glu_mlp(h2, pl["mlp"], cfg.act), None

    fn = jax.checkpoint(body,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    x, _ = jax.lax.scan(fn, frames.astype(_dtype(cfg)), params["enc"])
    return L.rmsnorm(x, params["norm_enc"], cfg.norm_eps)


def _dec_layer(pl, x, cfg, positions, memory=None, mem_kv=None,
               self_cache=None, pos=None):
    """One decoder layer; returns (x, new self-kv segment or cache)."""
    h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
    q, k, v = L.gqa_project(h, pl["attn"], cfg.n_heads, cfg.n_kv_heads,
                            cfg.hd, positions, cfg.rope_theta)
    if self_cache is not None:
        kc = jax.lax.dynamic_update_slice_in_dim(
            self_cache[0], k.astype(self_cache[0].dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            self_cache[1], v.astype(self_cache[1].dtype), pos, axis=1)
        o = L.attention(q, kc, vc, causal=False, q_offset=pos,
                        kv_len=pos + 1)
        new_kv = (kc, vc)
    else:
        o = L.attention(q, k, v, causal=True)
        new_kv = (k, v)
    x = x + o.reshape(*o.shape[:2], -1) @ pl["attn"]["wo"]
    # cross attention to the encoder memory
    hx = L.rmsnorm(x, pl["ln_x"], cfg.norm_eps)
    B, T, _ = hx.shape
    qx = (hx @ pl["xattn"]["wq"]).reshape(B, T, cfg.n_heads, cfg.hd)
    if mem_kv is not None:
        mk, mv = mem_kv
    else:
        Sm = memory.shape[1]
        mk = (memory @ pl["xattn"]["wk"]).reshape(B, Sm, cfg.n_kv_heads,
                                                  cfg.hd)
        mv = (memory @ pl["xattn"]["wv"]).reshape(B, Sm, cfg.n_kv_heads,
                                                  cfg.hd)
    ox = L.attention(qx, mk, mv, causal=False)
    x = x + ox.reshape(B, T, -1) @ pl["xattn"]["wo"]
    h2 = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
    return x + L.glu_mlp(h2, pl["mlp"], cfg.act), new_kv, (mk, mv)


def decode_train(params: Params, cfg: ArchConfig, memory, tokens, *,
                 remat: bool = True, collect_cache: bool = False):
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])

    def body(x, pl):
        x, kv, mkv = _dec_layer(pl, x, cfg, positions, memory=memory)
        return x, (kv, mkv) if collect_cache else None

    fn = jax.checkpoint(body,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    x, caches = jax.lax.scan(fn, x, params["dec"])
    return L.rmsnorm(x, params["norm_f"], cfg.norm_eps), caches


def forward_train(params, cfg, frames, tokens, remat=True):
    memory = encode(params, cfg, frames, remat=remat)
    h, _ = decode_train(params, cfg, memory, tokens, remat=remat)
    return h


def init_cache(cfg: ArchConfig, batch: int, max_len: int, mem_len: int):
    dt = _dtype(cfg)
    kv = (cfg.dec_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    mem = (cfg.dec_layers, batch, mem_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
            "mk": jnp.zeros(mem, dt), "mv": jnp.zeros(mem, dt)}


def prefill(params, cfg, frames, tokens):
    """Encode the source and run the decoder prompt; returns cache."""
    memory = encode(params, cfg, frames, remat=False)
    h, caches = decode_train(params, cfg, memory, tokens, remat=False,
                             collect_cache=True)
    (k, v), (mk, mv) = caches
    logits = h[:, -1:] @ params["lm_head"]
    return {"k": k, "v": v, "mk": mk, "mv": mv}, logits


def decode_step(params, cfg, token, pos, cache):
    x = params["embed"][token]
    positions = pos + jnp.arange(1)

    def body(x, layer_in):
        pl, kc, vc, mk, mv = layer_in
        x, (kn, vn), _ = _dec_layer(pl, x, cfg, positions,
                                    mem_kv=(mk, mv),
                                    self_cache=(kc, vc), pos=pos)
        return x, (kn, vn)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["mk"],
                  cache["mv"]))
    x = L.rmsnorm(x, params["norm_f"], cfg.norm_eps)
    return x @ params["lm_head"], {"k": k_new, "v": v_new,
                                   "mk": cache["mk"], "mv": cache["mv"]}
