"""System layer: execute collective programs / workloads on a backend.

Three fidelity tiers, selected by ``fidelity=`` on the single
:func:`repro.core.backends.simulate` entry point (re-exported here), which
accepts an MSCCL++ ``Program`` *or* a Chakra-style ``ExecutionTrace``:

* ``"fine"``     — lower the workload to Load-Store kernels and run them
  on the detailed Cluster (NoC-level network, CU contention, cache-line
  Wavefront Requests).  Paper §4.2-§4.4.
* ``"coarse"``   — ASTRA-sim 2.0 style: interpret the same workload at
  chunk granularity over the alpha-beta SimpleNetwork (one message per
  put/get, zero-cost local ops; trace compute nodes costed roofline).
* ``"analytic"`` — closed-form collective estimators / contention-free
  alpha-beta interpretation (near event-free), for pod-scale sweeps.

The historical helpers :func:`simulate_collective` (fine) and
:func:`simulate_collective_coarse` are thin wrappers kept for callers and
notebooks; new code should use ``simulate(workload, infra, fidelity=...,
config=...)`` with a typed per-tier config.
"""

from __future__ import annotations

import warnings
from typing import List, Optional

from .backends import CollectiveResult, CoarseBackend, FineBackend
from .cluster import Cluster, NocConfig
from .gpu_model import GpuConfig
from .mscclpp import Program
from .network.simple import SimpleTopology

__all__ = [
    "CollectiveResult", "SimResult", "payload_bytes", "simulate",
    "simulate_collective", "simulate_collective_coarse",
]


# ---------------------------------------------------------------------------
# Fine-grained path (ASTRA-sim 3.0)
# ---------------------------------------------------------------------------

def simulate_collective(program: Program,
                        cluster: Optional[Cluster] = None,
                        noc: Optional[NocConfig] = None,
                        gpu_config: Optional[GpuConfig] = None,
                        unroll: Optional[int] = None,
                        topology: str = "switch",
                        rank_delay_ns: Optional[List[float]] = None,
                        until_ns: float = 5e10) -> CollectiveResult:
    """Run a collective program at Load-Store granularity end to end.

    ``rank_delay_ns`` injects per-rank kernel-launch skew (straggler study).
    Deprecated: use ``simulate(program, fidelity="fine",
    config=FineConfig(noc=..., gpu_config=..., topology=...), ...)``.
    """
    warnings.warn(
        "simulate_collective() is deprecated; use simulate(program, "
        "fidelity='fine', config=FineConfig(noc=..., gpu_config=..., "
        "topology=...), unroll=..., ...) from repro.core.backends",
        DeprecationWarning, stacklevel=2)
    backend = FineBackend(noc=noc, gpu_config=gpu_config, topology=topology)
    return backend.run(program, cluster=cluster, unroll=unroll,
                       rank_delay_ns=rank_delay_ns, until_ns=until_ns)


# ---------------------------------------------------------------------------
# Coarse path (ASTRA-sim 2.0 baseline)
# ---------------------------------------------------------------------------

def simulate_collective_coarse(program: Program,
                               topo: Optional[SimpleTopology] = None,
                               link_GBps: float = 34.36 * 8,
                               link_lat_ns: float = 1000.0,
                               local_GBps: float = 1099.5,
                               reduce_GBps: float = 4398.0,
                               rank_delay_ns: Optional[List[float]] = None,
                               until_ns: float = 5e10) -> CollectiveResult:
    """ASTRA-sim 2.0-fidelity simulation of the same program.

    Deprecated: use ``simulate(program, fidelity="coarse",
    config=CoarseConfig(...), ...)``.
    """
    warnings.warn(
        "simulate_collective_coarse() is deprecated; use simulate(program, "
        "fidelity='coarse', config=CoarseConfig(...), ...) from "
        "repro.core.backends",
        DeprecationWarning, stacklevel=2)
    backend = CoarseBackend(topo=topo, link_GBps=link_GBps,
                            link_lat_ns=link_lat_ns, local_GBps=local_GBps,
                            reduce_GBps=reduce_GBps)
    return backend.run(program, rank_delay_ns=rank_delay_ns,
                       until_ns=until_ns)
