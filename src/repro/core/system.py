"""System layer: execute collective programs / workloads on a backend.

Two fidelity levels, mirroring the paper's 2.0 → 3.0 step:

* ``simulate_collective``        — fine-grained: lower the MSCCL++ program to
  Load-Store kernels and run them on the detailed Cluster (NoC-level network,
  CU contention, cache-line Wavefront Requests).  Paper §4.2–§4.4.
* ``simulate_collective_coarse`` — ASTRA-sim 2.0 style: interpret the same
  program at chunk granularity over the alpha-beta SimpleNetwork (one message
  per put/get, zero-cost local ops).  Used to quantify what fidelity buys.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .cluster import Cluster, NocConfig
from .engine import Engine
from .gpu_model import GpuConfig
from .mscclpp import Program, lower_program
from .network.fabric import CONTROL, DATA
from .network.simple import SimpleNetwork, SimpleTopology


@dataclass
class CollectiveResult:
    program: str
    collective: str
    nranks: int
    time_ns: float
    moved_bytes: int               # payload bytes defined by the collective
    events: int
    wallclock_s: float
    requests: int = 0
    per_rank_done_ns: Optional[List[float]] = None

    @property
    def bus_GBps(self) -> float:
        """Collective bandwidth: buffer size / collective time (paper §5.2)."""
        return self.moved_bytes / self.time_ns if self.time_ns > 0 else 0.0


def payload_bytes(program: Program) -> int:
    """The 'buffer size' the paper divides by: per-rank output payload."""
    return program.buffers.get("output", 0)


# ---------------------------------------------------------------------------
# Fine-grained path (ASTRA-sim 3.0)
# ---------------------------------------------------------------------------

def simulate_collective(program: Program,
                        cluster: Optional[Cluster] = None,
                        noc: Optional[NocConfig] = None,
                        gpu_config: Optional[GpuConfig] = None,
                        unroll: Optional[int] = None,
                        topology: str = "switch",
                        rank_delay_ns: Optional[List[float]] = None,
                        until_ns: float = 5e10) -> CollectiveResult:
    """Run a collective program at Load-Store granularity end to end.

    ``rank_delay_ns`` injects per-rank kernel-launch skew (straggler study).
    """
    if cluster is None:
        cluster = Cluster(program.num_ranks, gpu_config=gpu_config, noc=noc,
                          topology=topology)
    kernels = lower_program(program, unroll=unroll)
    done_at: Dict[int, float] = {}

    def on_done(kernel, t, rank=None):
        done_at[kernel.gpu] = t

    for k in kernels:
        k.on_done = on_done
        delay = rank_delay_ns[k.gpu] if rank_delay_ns else 0.0
        if delay > 0:
            cluster.engine.schedule(delay, cluster.dispatch, k)
        else:
            cluster.dispatch(k)
    cluster.run(until_ns)
    if len(done_at) != program.num_ranks:
        missing = [r for r in range(program.num_ranks) if r not in done_at]
        raise RuntimeError(
            f"collective did not complete: ranks {missing} still running "
            f"at {cluster.engine.now} ns (deadlock or until_ns too small)")
    t = max(done_at.values())
    return CollectiveResult(
        program=program.name, collective=program.collective,
        nranks=program.num_ranks, time_ns=t,
        moved_bytes=payload_bytes(program),
        events=cluster.engine.events_processed,
        wallclock_s=cluster.engine.wallclock_seconds(),
        requests=cluster.request_count,
        per_rank_done_ns=[done_at[r] for r in range(program.num_ranks)])


# ---------------------------------------------------------------------------
# Coarse path (ASTRA-sim 2.0 baseline)
# ---------------------------------------------------------------------------

class _CoarseExec:
    """Chunk-granularity interpreter of an MSCCL++ program.

    Semantics: put/get = one network message of `size`; signal = one small
    control message; copy/reduce = local, modeled with a memory-bandwidth
    cost; wait/barrier = ordering only.  This is deliberately the 2.0-level
    model — no CU contention, no per-cache-line control path.
    """

    HDR = 64  # control message bytes

    def __init__(self, program: Program, net: SimpleNetwork,
                 local_GBps: float, reduce_GBps: float,
                 rank_delay_ns: Optional[List[float]] = None):
        self.p = program
        self.net = net
        self.e = net.engine
        self.local_GBps = local_GBps
        self.reduce_GBps = reduce_GBps
        self.sems: Dict[Tuple[int, int], int] = {}
        self.pcs: Dict[Tuple[int, int], int] = {}
        self.blocked: Dict[Tuple[int, int], bool] = {}
        self.done_at: Dict[int, float] = {}
        self.live = 0
        for r in range(program.num_ranks):
            for w in range(len(program.gpus[r])):
                self.pcs[(r, w)] = 0
                self.blocked[(r, w)] = False
                self.live += 1
                delay = rank_delay_ns[r] if rank_delay_ns else 0.0
                self.e.schedule(delay, self._advance, r, w)

    # each (rank, wg) cursor advances op by op; ops take simulated time
    def _advance(self, r: int, w: int) -> None:
        ops = self.p.gpus[r][w]
        pc = self.pcs[(r, w)]
        if pc >= len(ops):
            self._wg_done(r, w)
            return
        o = ops[pc]
        if o.op in ("put", "get"):
            peer = o.remote_rank
            src, dst = (r, peer) if o.op == "put" else (peer, r)
            self.pcs[(r, w)] = pc + 1
            self.net.send(src, dst, o.size, lambda: self._advance(r, w),
                          cls=DATA)
        elif o.op == "copy":
            self.pcs[(r, w)] = pc + 1
            self.e.schedule(o.size / self.local_GBps, self._advance, r, w)
        elif o.op == "reduce":
            nsrc = max(1, len(o.srcs or []))
            cost = o.size * nsrc / self.reduce_GBps
            # remote sources pay a network round trip too
            remote = [s for s in (o.srcs or []) if len(s) > 2 and s[2] >= 0
                      and s[2] != r]
            self.pcs[(r, w)] = pc + 1
            if remote:
                pend = {"n": len(remote)}

                def got_one():
                    pend["n"] -= 1
                    if pend["n"] == 0:
                        self.e.schedule(cost, self._advance, r, w)
                for s in remote:
                    self.net.send(s[2], r, o.size, got_one, cls=DATA)
            else:
                self.e.schedule(cost, self._advance, r, w)
        elif o.op == "signal":
            self.pcs[(r, w)] = pc + 1
            peer, sem = o.remote_rank, o.sem

            def deliver():
                key = (peer, sem)
                self.sems[key] = self.sems.get(key, 0) + 1
                self._wake_waiters(peer)
            self.net.send(r, peer, self.HDR, deliver, cls=CONTROL)
            self.e.schedule(0, self._advance, r, w)
        elif o.op == "wait":
            if self.sems.get((r, o.sem), 0) >= o.expected:
                self.pcs[(r, w)] = pc + 1
                self.e.schedule(0, self._advance, r, w)
            else:
                self.blocked[(r, w)] = True
        elif o.op == "barrier":
            # coarse: barrier when every wg of the rank is at one
            self.blocked[(r, w)] = True
            if all(self.pcs[(r, w2)] >= len(self.p.gpus[r][w2]) or
                   (self.blocked[(r, w2)] and
                    self.p.gpus[r][w2][self.pcs[(r, w2)]].op == "barrier")
                   for w2 in range(len(self.p.gpus[r]))):
                for w2 in range(len(self.p.gpus[r])):
                    pc2 = self.pcs[(r, w2)]
                    if pc2 < len(self.p.gpus[r][w2]) and \
                            self.p.gpus[r][w2][pc2].op == "barrier":
                        self.pcs[(r, w2)] = pc2 + 1
                        self.blocked[(r, w2)] = False
                        self.e.schedule(0, self._advance, r, w2)
        else:  # nop / flush: free at coarse granularity
            self.pcs[(r, w)] = pc + 1
            self.e.schedule(0, self._advance, r, w)

    def _wake_waiters(self, rank: int) -> None:
        for w in range(len(self.p.gpus[rank])):
            if not self.blocked[(rank, w)]:
                continue
            pc = self.pcs[(rank, w)]
            ops = self.p.gpus[rank][w]
            if pc < len(ops) and ops[pc].op == "wait" and \
                    self.sems.get((rank, ops[pc].sem), 0) >= ops[pc].expected:
                self.blocked[(rank, w)] = False
                self.pcs[(rank, w)] = pc + 1
                self.e.schedule(0, self._advance, rank, w)

    def _wg_done(self, r: int, w: int) -> None:
        self.live -= 1
        if all(self.pcs[(r, w2)] >= len(self.p.gpus[r][w2])
               for w2 in range(len(self.p.gpus[r]))):
            self.done_at.setdefault(r, self.e.now)


def simulate_collective_coarse(program: Program,
                               topo: Optional[SimpleTopology] = None,
                               link_GBps: float = 34.36 * 8,
                               link_lat_ns: float = 1000.0,
                               local_GBps: float = 1099.5,
                               reduce_GBps: float = 4398.0,
                               rank_delay_ns: Optional[List[float]] = None,
                               until_ns: float = 5e10) -> CollectiveResult:
    """ASTRA-sim 2.0-fidelity simulation of the same program."""
    if topo is None:
        topo = SimpleTopology([(program.num_ranks, link_GBps, link_lat_ns,
                                "switch")])
    net = SimpleNetwork(topo)
    ex = _CoarseExec(program, net, local_GBps, reduce_GBps, rank_delay_ns)
    net.run(until_ns)
    if len(ex.done_at) != program.num_ranks:
        missing = [r for r in range(program.num_ranks) if r not in ex.done_at]
        raise RuntimeError(f"coarse sim incomplete: ranks {missing}")
    t = max(ex.done_at.values())
    return CollectiveResult(
        program=program.name + ".coarse", collective=program.collective,
        nranks=program.num_ranks, time_ns=t,
        moved_bytes=payload_bytes(program),
        events=net.engine.events_processed,
        wallclock_s=net.engine.wallclock_seconds(),
        per_rank_done_ns=[ex.done_at[r] for r in range(program.num_ranks)])
