"""Coarse Simple network backend (ASTRA-sim 2.0's alpha-beta model, §2.1).

Two modes:

* **event-driven** — ``SimpleNetwork``: GPU-granularity nodes over a Fabric;
  one message per chunk transfer (this is what ASTRA-sim 2.0 did, and is the
  low-fidelity baseline the paper's Fig. 4 argues against);
* **closed-form** — ``alpha_beta_time`` and the ``collective_time_*``
  estimators used by the step-time predictor at pod scale (256+ chips),
  where event simulation of every chunk is unnecessary.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from ..engine import Engine
from .fabric import DATA, Fabric


def alpha_beta_time(size_bytes: float, alpha_ns: float, beta_GBps: float) -> float:
    """Classic Hockney model: latency + size/bandwidth, in ns."""
    return alpha_ns + (size_bytes / beta_GBps if beta_GBps > 0 else 0.0)


@dataclass
class SimpleTopology:
    """A (possibly multi-dimensional) GPU-level topology description.

    ``dims``: list of (size, bandwidth_GBps, latency_ns, kind) per dimension,
    innermost first — mirroring ASTRA-sim 2.0's hierarchical Simple backend.
    kind: "ring" | "fc" (fully connected) | "switch".
    """
    dims: List[Tuple[int, float, float, str]]

    @property
    def num_gpus(self) -> int:
        n = 1
        for d, *_ in self.dims:
            n *= d
        return n


class SimpleNetwork:
    """Event-driven coarse backend: chunk-granularity transfers on a Fabric."""

    def __init__(self, topo: SimpleTopology, engine: Optional[Engine] = None,
                 policy: str = "fifo", mode: str = "coalesce",
                 coalesce_window_ns: Optional[float] = None):
        self.engine = engine or Engine()
        self.topo = topo
        self.fabric = Fabric(self.engine, default_policy=policy, mode=mode,
                             coalesce_window_ns=coalesce_window_ns)
        self._gpu_nodes: List[int] = []
        self._build()

    def _build(self) -> None:
        fab = self.fabric
        n = self.topo.num_gpus
        self._gpu_nodes = [fab.add_node(f"gpu{g}") for g in range(n)]
        # build links dimension by dimension: GPUs whose coordinates differ
        # only in dim k are connected per that dim's kind
        stride = 1
        for k, (size, bw, lat, kind) in enumerate(self.topo.dims):
            groups: Dict[int, List[int]] = {}
            for g in range(n):
                base = (g // (stride * size)) * (stride * size) + g % stride
                groups.setdefault(base, []).append(g)
            for base, members in groups.items():
                members = sorted(members)
                if kind == "ring":
                    if len(members) > 1:
                        for i, g in enumerate(members):
                            nxt = members[(i + 1) % len(members)]
                            fab.add_bidi(self._gpu_nodes[g],
                                         self._gpu_nodes[nxt], bw, lat)
                elif kind == "fc":
                    for i, g in enumerate(members):
                        for h in members[i + 1:]:
                            fab.add_bidi(self._gpu_nodes[g],
                                         self._gpu_nodes[h], bw, lat)
                elif kind == "switch":
                    sw = fab.add_node(f"sw.d{k}.{base}")
                    for g in members:
                        fab.add_bidi(self._gpu_nodes[g], sw, bw, lat / 2)
                else:
                    raise ValueError(f"unknown dim kind {kind!r}")
            stride *= size

    # ------------------------------------------------------------------ API
    def send(self, src_gpu: int, dst_gpu: int, size: int,
             on_done: Callable[[], None], cls: int = DATA) -> None:
        route = self.fabric.route(self._gpu_nodes[src_gpu],
                                  self._gpu_nodes[dst_gpu])
        self.fabric.send(route, size, cls, lambda f: on_done())

    def run(self, until_ns: Optional[float] = None) -> float:
        return self.engine.run(until_ns)


# --------------------------------------------------------------------------
# Closed-form collective estimators (used at pod scale by the step predictor)
# --------------------------------------------------------------------------

def collective_time_ring(kind: str, size_bytes: float, n: int,
                         link_GBps: float, alpha_ns: float) -> float:
    """Ring algorithm time for a collective over ``n`` ranks.

    ``size_bytes`` is the *global* payload (e.g. full gradient buffer for an
    all-reduce, full gathered output for an all-gather).
    """
    if n <= 1:
        return 0.0
    if kind == "all_reduce":       # reduce-scatter + all-gather
        steps = 2 * (n - 1)
        bytes_per_step = size_bytes / n
    elif kind in ("all_gather", "reduce_scatter"):
        steps = n - 1
        bytes_per_step = size_bytes / n
    elif kind == "all_to_all":     # pairwise exchange schedule
        steps = n - 1
        bytes_per_step = size_bytes / n
    else:
        raise ValueError(kind)
    return steps * alpha_beta_time(bytes_per_step, alpha_ns, link_GBps)


def collective_time_hd(kind: str, size_bytes: float, n: int,
                       link_GBps: float, alpha_ns: float) -> float:
    """Recursive halving-doubling estimate (power-of-two ranks)."""
    if n <= 1:
        return 0.0
    rounds = math.ceil(math.log2(n))
    if kind == "all_reduce":
        # RS (halving) + AG (doubling): each moves size*(n-1)/n total
        vol = 2 * size_bytes * (n - 1) / n
        return 2 * rounds * alpha_ns + vol / link_GBps
    if kind in ("all_gather", "reduce_scatter"):
        vol = size_bytes * (n - 1) / n
        return rounds * alpha_ns + vol / link_GBps
    return collective_time_ring(kind, size_bytes, n, link_GBps, alpha_ns)


def best_collective_time(kind: str, size_bytes: float, n: int,
                         link_GBps: float, alpha_ns: float) -> Tuple[float, str]:
    """Pick the faster of ring vs halving-doubling (what a tuned CCL does)."""
    ring = collective_time_ring(kind, size_bytes, n, link_GBps, alpha_ns)
    hd = collective_time_hd(kind, size_bytes, n, link_GBps, alpha_ns)
    return (ring, "ring") if ring <= hd else (hd, "halving_doubling")
