"""Static transit tables for the reservation-ledger clock kernel.

``build_static_floors(links)`` computes, per link, a lower bound on the
delay beyond *now* before any not-yet-committed traffic can emerge from
the link's feeder cone — valid at *every* future query, so the clock
kernel (:func:`fabric._clock_terms`) can accept a small-margin probe with
one integer compare instead of walking the feeder DAG.

The bound is the shortest path, in minimum-transit edge weights, from any
*entry* link to each link's input over the feeder graph.  A link is an
entry — floor 0 — wherever traffic can appear at its input at an
arbitrary tick:

* it heads a publicly-routed path (``_inj_fed``: an injector can act at
  any event tick),
* it is classic/fair or fed by a classic/fair link (event-driven queue
  advances the ledger cannot see),
* it is *parkable* — not sole-fed, so a chained walk may schedule an
  arrival (and push a reservation) at any tick, or
* it is a reservation-push target: its (sole) feeder can be entered via
  ``enqueue`` — the feeder heads a route or is itself fed by a classic
  link — whose admission pushes the successor's reservation directly.

Reservations and injections *at the link itself* remain dynamic terms of
the clock query; the static floor only summarizes the cone upstream of
the link's input, which is exactly the part the recursion walks.

The relaxation runs vectorized over flat link-id-indexed int64 arrays
(numpy Bellman-Ford to the fixpoint, which is also sound for cyclic
censuses).  Set ``REPRO_LEDGER_JAX=1`` to run the same relaxation as a
jitted JAX loop (consistent with ``repro.kernels``; numerically
identical, useful only for very large topologies).
"""

from __future__ import annotations

import os
from collections import deque
from typing import Iterable, List

import numpy as np

_FAR = 1 << 62


def _is_entry(link) -> bool:
    """Can traffic appear at ``link``'s input at an arbitrary tick?"""
    if link._inj_fed or not link.fast or not link.led:
        return True
    sf = link._sole_feed
    if sf is None or sf is False:
        return True                 # parkable: ambiguous feeder order
    # sole-fed: reservation pushes reach this link only via enqueue() on
    # the sole feeder (route heads and classic handoffs)
    if sf._inj_fed or not sf.fast:
        return True
    return any(not u.fast for u in sf._feeders)


def _edges(links: List):
    """Feeder-graph edge arrays (src link-id, dst link-id, transit), plus
    the set of links with a feeder outside this fabric (no static claim
    can be made about such a cone — their floor pins to 0)."""
    lid = {id(l): i for i, l in enumerate(links)}
    src, dst, w = [], [], []
    foreign_fed = set()
    for i, l in enumerate(links):
        for f in l._feeders:
            j = lid.get(id(f))
            if j is None:
                foreign_fed.add(i)
                continue
            src.append(j)
            dst.append(i)
            w.append(f._xfer_lb if f.fast else 0)
    return (np.asarray(src, dtype=np.int64),
            np.asarray(dst, dtype=np.int64),
            np.asarray(w, dtype=np.int64), foreign_fed)


def _relax_numpy(entry: np.ndarray, src: np.ndarray, dst: np.ndarray,
                 w: np.ndarray) -> np.ndarray:
    floor = np.where(entry, np.int64(0), np.int64(_FAR))
    if src.size == 0:
        return floor
    for _ in range(len(entry)):
        cand = np.full_like(floor, _FAR)
        np.minimum.at(cand, dst, floor[src] + w)
        nxt = np.minimum(floor, cand)
        # entry links stay pinned at 0 (they already are the minimum)
        if np.array_equal(nxt, floor):
            break
        floor = nxt
    return floor


def _relax_jax(entry: np.ndarray, src: np.ndarray, dst: np.ndarray,
               w: np.ndarray) -> np.ndarray:
    import jax
    import jax.numpy as jnp

    @jax.jit
    def run(entry_, src_, dst_, w_):
        floor0 = jnp.where(entry_, jnp.int64(0), jnp.int64(_FAR))

        def body(state):
            floor, _ = state
            cand = jnp.full_like(floor, _FAR).at[dst_].min(floor[src_] + w_)
            nxt = jnp.minimum(floor, cand)
            return nxt, jnp.any(nxt != floor)

        def cond(state):
            return state[1]

        floor, _ = jax.lax.while_loop(cond, body, (floor0, jnp.bool_(True)))
        return floor

    with jax.experimental.enable_x64():
        return np.asarray(run(jnp.asarray(entry), jnp.asarray(src),
                              jnp.asarray(dst), jnp.asarray(w)))


def build_static_floors(links: List) -> List[int]:
    """Per-link static feeder-cone transit floor (plain ints, same order
    as ``links``).  ``_FAR`` means the cone is provably empty (no feeders
    and no entry) — traffic can only ever reach the link via its dynamic
    terms."""
    n = len(links)
    if n == 0:
        return []
    entry = np.fromiter((_is_entry(l) for l in links), dtype=bool, count=n)
    src, dst, w, foreign_fed = _edges(links)
    if foreign_fed:
        entry[list(foreign_fed)] = True
    use_jax = os.environ.get("REPRO_LEDGER_JAX") == "1"
    relax = _relax_numpy
    if use_jax:
        try:
            relax = _relax_jax
        except Exception:           # pragma: no cover - defensive
            relax = _relax_numpy
    try:
        floor = relax(entry, src, dst, w)
    except Exception:               # pragma: no cover - jax unavailable
        floor = _relax_numpy(entry, src, dst, w)
    # the per-link result is the cone floor at the link's *input*: min
    # over feeder edges of (feeder floor + feeder transit), independent of
    # the link's own entry status (its own resv/inj terms stay dynamic)
    slb = np.full(n, _FAR, dtype=np.int64)
    if src.size:
        np.minimum.at(slb, dst, floor[src] + w)
    out = []
    for i, l in enumerate(links):
        if i in foreign_fed:
            out.append(0)           # cone not fully visible: no claim
        elif l._feeders:
            out.append(int(min(slb[i], _FAR)))
        else:
            out.append(_FAR)        # empty cone: census-complete vacuity
    return out


def _eff(f) -> int:
    """A feeder's contribution to its successors' cone floors: 0 once
    traffic can enter at it at an arbitrary tick, else its own stored
    cone floor."""
    if _is_entry(f):
        return 0
    lb = f._static_lb
    return lb if lb < _FAR else _FAR


def refresh_static_floors(changed: Iterable) -> None:
    """Incrementally refresh ``_static_lb`` after a census epoch.

    ``changed`` is the set of links whose feeder census mutated since the
    last commit (new feeder appended, sole-feed corridor broken, or head
    marked injection-fed).  Registering routes only ever *adds* ways for
    traffic to reach a link, so the true cone floor is monotonically
    non-increasing across commits — a decrease-only worklist over the
    reverse feeder edges (``Link._deps``) reaches the exact fixpoint
    without re-relaxing the whole fabric.

    Two wrinkles keep it exact rather than merely sound:

    * a mutated link's floor *contribution* can drop to zero without its
      own stored floor changing (entry status is not part of ``slb``), so
      every mutated link force-propagates to its deps once; and
    * a link's entry status also reads its *sole feeder*'s direct state
      (``_inj_fed``, non-fast feeders), so deps sole-fed by a mutated
      link are force-propagated too.  One level suffices: past that, the
      effect is an ordinary floor decrease.

    Where a contribution *increases* (a previously feeder-less interior
    segment head gaining its first feeder), stale downstream floors are
    left as under-estimates — a smaller lower bound is still a lower
    bound, and floors only steer chain-vs-park probe decisions, never
    timing, so soundness and bit-exactness both survive.
    """
    work = deque(changed)
    mutated = {id(l) for l in work}
    forced = set(mutated)
    pending = set(mutated)
    while work:
        l = work.popleft()
        lid = id(l)
        pending.discard(lid)
        feeders = l._feeders
        inf = _FAR
        for f in feeders:
            v = _eff(f) + (f._xfer_lb if f.fast else 0)
            if v < inf:
                inf = v
        if inf > _FAR:
            inf = _FAR
        dec = inf < l._static_lb
        if dec:
            l._static_lb = inf
        if dec or lid in forced:
            forced.discard(lid)
            is_mut = lid in mutated
            for d in l._deps:
                if is_mut and d._sole_feed is l:
                    forced.add(id(d))
                if id(d) not in pending:
                    pending.add(id(d))
                    work.append(d)
