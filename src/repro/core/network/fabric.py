"""Event-driven network fabric (paper §4.5).

One implementation serves both granularities:

* the **NoC-level detailed backend** — nodes are CUs, NoC routers, HBM
  channels and I/O ports; messages are cache-line-sized Wavefront Requests —
  and
* the **coarse Simple backend** — nodes are GPUs/NICs/switches; messages are
  chunk-sized collective transfers.

Links are store-and-forward servers with bandwidth, latency, and a two-class
(control vs. data) arbitration policy; ``fifo`` lets large data messages
block control traffic (the paper's Fig. 11 pathology), ``fair`` round-robins
between the classes.

Scheduling modes (``Fabric(mode=...)``)
---------------------------------------

``MODE_CLASSIC``
    The reference implementation: every link hop costs two heap events (one
    when serialization finishes, one when the message arrives at the next
    node after propagation).  Same-tick link-service ties resolve by the
    deterministic route tie-break key (:class:`Route`) in every mode, so
    classic, exact and coalesce produce bit-identical schedules — even on
    symmetric workloads whose flights collide at equal ticks.

``MODE_EXACT``
    FIFO links keep an absolute ``free_at`` clock in integer picoseconds.
    Because FIFO service order equals arrival order, a flight's serialization
    window is fully determined the moment it arrives, so each hop needs only
    ONE heap event (the arrival at the next node).  Timing is identical to
    classic down to the picosecond.

``MODE_COALESCE`` (default)
    ``MODE_EXACT`` plus *trains*: back-to-back flights queued on the same
    link toward the same remaining route ride one shared hop event.  At each
    hop the train commits as many member lines as the engine's lookahead
    horizon (``Engine.peek_ps``) proves safe — no other event can fire before
    the horizon, hence no competing arrival can interleave — and re-schedules
    the rest.  Arrival times are bit-identical to the un-coalesced path; only
    the heap-event count drops.

``fair``-policy links always use the classic machinery (their round-robin
pick depends on queue contents at serialization-finish time, which cannot be
precomputed at arrival).

Per-link reservation ledgers (``Fabric(ledger=True)``, default on)
------------------------------------------------------------------

Region horizons alone cannot chain a flight through interior NoC hops: the
next pending event of a busy region is always about a cycle away, so every
hop of a multi-hop route costs one "park" event.  With the ledger enabled,
every FIFO link additionally keeps a full Chandy-Misra *channel clock* — a
sound lower bound on the earliest tick at which any *not-yet-committed*
traffic could still arrive at its input queue — assembled per query from

* its **reservation heap** ``_resv``: arrival ticks of trains already
  scheduled (parked or injected) whose next service commit is this link;
* its **feeder census** ``_feeders``: every upstream link that any
  registered route enters it from.  Traffic still upstream must clear the
  feeder's server first, so it arrives no earlier than
  ``max(chan_clock(feeder), feeder._free_ps) + min_serialization + latency``
  (the recursion is depth-limited and memoized per event);
* its **injection sources**: links that head a registered route take the
  earliest tick their attached injector can act — a compute unit's wake
  floor (scheduled issue slot, pending response deliveries, semaphore
  releases), or a memory endpoint's inbound clock plus its access latency
  (``Fabric.set_injection_source``); untagged/global events floor every
  source;
* the **region horizon** (``Engine.horizon_ps``) as the conservative base:
  the ledger clock is never below it, so ledger chaining strictly
  generalizes region-horizon chaining.

``_propel``/``_propel_multi`` then commit a hop ahead of real time whenever
the arrival tick beats the link's channel clock — chaining a flight through
every interior hop (and across region boundaries) in one heap event, roughly
one event per flight leg instead of one per hop.  The per-link FIFO monitor
(``order_violations``) still certifies every run: zero violations means the
schedule is bit-identical to the classic arrival order.

The compiled clock kernel (ISSUE 6)
-----------------------------------

The clock query is *value-returning* (:func:`_clock_eval`): instead of a
boolean proof it computes the clock bound itself, in two grades —
``v_ledger``, assembled purely from the deterministic future schedule and
therefore valid *across* events while ``Engine._led_gen`` stands still
(cached per link in ``_geL_g``/``_geL_v``), and ``v_assisted``, which
additionally rides the per-event region horizon (memoized per event in
``_ge_e``/``_ge_v``).  Callers pre-check the generation cache and thread
the engine context (epoch, now, mid-batch flag, generation) through the
recursion, and the region horizon is recomputed inline from two heap
peeks rather than memoized.

:mod:`.ledger_tables` builds **static transit tables** at route-warming
time: a vectorized (numpy, optionally jitted JAX) Bellman-Ford over the
feeder census yields each link's minimum cone transit, letting small-
margin queries accept with one integer compare instead of a cone walk.

Everything above is bit-exact by construction.  The remaining cost knob
is *which probes to attempt*, and refusing a probe is always sound (the
train just parks), which legitimizes two heuristics: exponential
**failure backoff** per link (a refuted full evaluation suppresses the
next ``_bko`` probes, any success resets it), and the
``fabric_ledger="auto"`` policy, which disables proof search entirely on
links whose measured success rate cannot pay for the walks
(:func:`_probe`).  On saturated workloads (the tracked ring all-reduce)
the proof search still costs more CPython time than the parks it saves —
``results/BENCH_engine.json`` tracks probes, chained legs, cache hit
rates and the depth histogram per mode so the trade stays visible.
"""

from __future__ import annotations

from collections import deque
from heapq import heappop as _heappop, heappush as _heappush
from typing import Callable, Dict, List, Optional, Tuple

from ..engine import Engine

CONTROL = 0
DATA = 1

MODE_CLASSIC = "classic"
MODE_EXACT = "exact"
MODE_COALESCE = "coalesce"

_PS_PER_NS = 1000
_NS_PER_PS = 0.001

_FAR = 1 << 62                  # "no bound" sentinel tick

#: default channel-clock recursion depth (``NocConfig.ledger_depth`` /
#: ``Fabric(ledger_depth=...)`` override it per engine): how many feeder
#: levels upstream the clock query walks before falling back to the region
#: horizon.  Each level adds at least one link latency of lookahead; routes
#: are short, so a small depth captures nearly all of the win at bounded
#: query cost.
LEDGER_DEPTH = 4

#: auto-policy hysteresis: a link's proof search is disabled once it has
#: failed this many top-level probes with fewer than a third of them
#: succeeding (``fabric_ledger="auto"``; parks still record reservations,
#: so other links' clocks stay sound).  The threshold is a measured
#: break-even: in CPython a park costs ~2 heap ops while a refuted proof
#: walk costs several times that, so links that mostly refuse are a net
#: loss even with failure backoff
_AUTO_MIN_FAILS = 128

#: failure-backoff ceiling: at most this many consecutive probes are
#: skipped on a link after refuted evaluations (see _probe)
_BKO_CAP = 32

# The batch flags (a CU issue batch on the stack blinds region-horizon
# proofs to the batch's own upcoming traffic; see Engine) live on the
# Engine instance (``_batch``/``_no_hz``), so two clusters simulated in
# one process can never cross-pollute state or inherit a stale mid-batch
# flag.  Clock queries recompute the region horizon inline from the heap
# tops (two peeks) rather than memoizing it — the memo dict cost more
# than the peeks.


class InjectionSource:
    """Interface for a route-head link's injection-bound provider.

    ``inj_pair(need, depth)`` returns ``(v_ledger, v_assisted)`` lower
    bounds on the earliest tick this injector can put a *new* (not yet
    committed) message onto the link — the two proof grades of
    :func:`_clock_eval` — or ``(-1, -1)`` when it cannot prove ``need``
    (``v_assisted >= need`` is the success criterion; partial values of a
    refuted query must not be used).  ``depth`` is the remaining
    channel-clock recursion budget for providers that consult upstream
    links.  Must be conservative.

    Sources that can only answer the historical threshold query may
    implement ``inj_ge(need, depth) -> bool`` instead and inherit the
    adapter below: a ``True`` is treated as per-event (assisted-grade)
    evidence and never cached across events.
    """

    __slots__ = ()

    def inj_pair(self, need: int, depth: int) -> Tuple[int, int]:
        if self.inj_ge(need, depth):
            return 0, need
        return -1, -1

    def inj_ge(self, need: int, depth: int) -> bool:  # pragma: no cover
        raise NotImplementedError


class EndpointSource(InjectionSource):
    """Injection bound for a memory-endpoint node (request/response turn).

    Every injection by the endpoint is the fixed-latency consequence of a
    request *delivered* to it, and deliveries commit eagerly — so any
    injection not yet committed corresponds to a request not yet committed
    on one of the node's inbound links, bounded by those links' channel
    clocks plus the endpoint's access latency.
    """

    __slots__ = ("in_links", "lat_ps")

    def __init__(self, in_links: List["Link"], lat_ps: int):
        self.in_links = in_links
        self.lat_ps = lat_ps

    def inj_pair(self, need: int, depth: int) -> Tuple[int, int]:
        lat = self.lat_ps
        t = need - lat
        vl = va = _FAR
        links = self.in_links
        if links:
            eng = links[0].engine
            gen = eng._led_gen
            ep = eng.events_processed
            now = eng._now_ps
            no_hz = eng._no_hz
            d1 = depth - 1
            for l in links:
                if l._geL_g == gen and t <= l._geL_v:
                    eng.led_hits += 1
                    fl = fa = l._geL_v
                else:
                    fl, fa = _clock_eval(l, t, d1, eng, ep, now, no_hz, gen)
                    if fa < t:
                        return -1, -1
                if fl < vl:
                    vl = fl
                if fa < va:
                    va = fa
        if vl < _FAR:
            vl += lat
        if va < _FAR:
            va += lat
        return vl, va

    def inj_ge(self, need: int, depth: int) -> bool:
        return self.inj_pair(need, depth)[1] >= need


class Route(list):
    """A route (list of links) with a deterministic tie-break identity.

    ``key`` is assigned in route *registration* order — ``Fabric`` hands the
    keys out as routes enter its caches, and registration order is fixed by
    the model builder (``Cluster.warm_routes`` pre-registers the whole route
    space), not by the scheduling mode.  Every link-service heap event
    (classic per-hop arrivals, fast-path parks, deliveries) carries its
    route's key, so same-tick service ties resolve identically across
    classic/exact/coalesce × ledger on/off instead of by each mode's
    incidental event insertion order (the one schedule-noise class the FIFO
    monitor cannot see).
    """
    __slots__ = ("key",)


def _rkey(route) -> int:
    """Tie-break key of a route (0 for ad-hoc plain-list routes)."""
    return route.key if type(route) is Route else 0


class Flight:
    """A message in transit along a precomputed route of links.

    ``eager`` marks deliveries whose callback is *time-stamp driven*: it
    reads the arrival tick from ``eta_ps`` and only schedules absolute-time
    effects, so it may run early, at the moment the final hop's service is
    committed (saving the delivery heap event).  Endpoint callbacks that
    mutate state as of "now" (e.g. a CU receiving a response) must keep
    ``eager=False``.
    """
    __slots__ = ("size", "cls", "route", "hop", "on_arrive", "payload",
                 "eager", "eta_ps")

    def __init__(self, size: int, cls: int, route: List["Link"],
                 on_arrive: Callable[["Flight"], None], payload=None,
                 eager: bool = False):
        self.size = size
        self.cls = cls
        self.route = route
        self.hop = 0
        self.on_arrive = on_arrive
        self.payload = payload
        self.eager = eager
        self.eta_ps = -1


class _Train:
    """Flights riding one shared hop event.

    ``lines[i]`` arrives at node ``route[hop]``'s entry at absolute tick
    ``at_ps[i]`` (non-decreasing); the single heap event fires at
    ``at_ps[0]``.  Formed by :meth:`Link._admit` when a flight lands on a
    link whose pending tail train shares the same remaining route.

    ``tailed`` marks trains that were ever stored in a link's ``_tails``
    joinability map.  Those entries are never removed eagerly, so a tailed
    train may be referenced long after it delivered; it is therefore
    excluded from the free-list below (recycling it could let a stale
    ``_tails`` entry alias a fresh train and wrongly accept a joiner).
    """
    __slots__ = ("route", "hop", "lines", "at_ps", "tailed")

    def __init__(self, route: List["Link"], hop: int):
        self.route = route
        self.hop = hop              # index of the link just serialized
        self.lines: List[Flight] = []
        self.at_ps: List[int] = []
        self.tailed = False


# Free-list for train shells (steady-state event processing allocates one
# train per leg; the shells are plain containers, fully re-armed on reuse,
# so one process-wide pool is safe across engines).  Only never-tailed
# trains are recycled — see _Train.tailed.
_TRAIN_POOL: List[_Train] = []
_TRAIN_POOL_CAP = 1024


def _train_new(route: List["Link"], hop: int) -> _Train:
    pool = _TRAIN_POOL
    if pool:
        t = pool.pop()
        t.route = route
        t.hop = hop
        t.tailed = False
        return t
    return _Train(route, hop)


def _train_free(t: _Train) -> None:
    if not t.tailed and len(_TRAIN_POOL) < _TRAIN_POOL_CAP:
        t.route = None
        t.lines.clear()
        t.at_ps.clear()
        _TRAIN_POOL.append(t)


class Link:
    """Directed link: a serialization server + propagation latency.

    ``policy``: "fifo" (single queue, arrival order) or "fair" (round-robin
    between the control and data queues — paper §5.2's arbitration fix).
    """
    __slots__ = ("name", "bw", "lat_ns", "policy", "engine", "_q", "_busy",
                 "_rr", "bytes_moved", "_busy_ps", "min_ser_ns",
                 "fast", "coalesce", "_free_ps", "_lat_ps", "_ser_ps_cache",
                 "_tails", "_win_ps", "_last_arr_ps", "order_violations",
                 "region", "_rguard_ps", "_sole_feed",
                 "led", "_feeders", "_deps", "_inj_fed", "_inj_src", "_sink",
                 "_resv", "_xfer_lb", "_ge_e", "_ge_v", "_geL_g", "_geL_v",
                 "_lt_e", "_lt_v", "_ltr_v", "_ltr_u", "_busy_e",
                 "_static_lb", "_auto", "_probe_on", "_probe_ok",
                 "_probe_fail", "_bko", "_skip")

    def __init__(self, engine: Engine, name: str, bandwidth_GBps: float,
                 latency_ns: float, policy: str = "fifo",
                 min_ser_ns: float = 0.0, mode: str = MODE_COALESCE,
                 coalesce_window_ns: float = 0.0, region: int = 0,
                 ledger: bool = True, min_msg_bytes: int = 0,
                 auto: bool = False):
        self.name = name
        self.bw = bandwidth_GBps  # GB/s == bytes/ns
        self.lat_ns = latency_ns
        self.policy = policy
        self.engine = engine
        self._q: Tuple[deque, deque] = (deque(), deque())  # control, data
        self._busy = False
        self._rr = 0
        self.bytes_moved = 0
        self._busy_ps = 0           # integer-ps busy time (see busy_ns)
        self.min_ser_ns = min_ser_ns
        # ---- fast path state ------------------------------------------
        self.fast = mode != MODE_CLASSIC and policy == "fifo"
        self.coalesce = self.fast and mode == MODE_COALESCE
        self._free_ps = 0                   # absolute tick the server frees
        self._lat_ps = int(round(latency_ns * _PS_PER_NS))
        self._ser_ps_cache: Dict[int, int] = {}
        # pending trains by route identity: joinable until their event fires
        self._tails: Dict[int, _Train] = {}
        # optimistic ahead-of-time commits are a coalescing feature; exact
        # mode must stay strictly one event per hop
        self._win_ps = (int(round(coalesce_window_ns * _PS_PER_NS))
                        if self.coalesce else 0)
        self._last_arr_ps = 0         # latest admitted arrival (FIFO monitor)
        self.order_violations = 0     # admissions that broke arrival order
        self.region = region          # lookahead region (0 = global)
        self._rguard_ps = 0           # region entry transit (set by builder)
        # unique upstream feeder link, if every registered route entering
        # this link comes through the same predecessor (False = ambiguous /
        # injection-fed).  FIFO order is then inherited from the feeder, so
        # admissions can chain through unconditionally.
        self._sole_feed = None
        # ---- reservation ledger (channel clock) -----------------------
        self.led = ledger and self.fast
        self._feeders: List["Link"] = []  # distinct upstream feeder links
        self._deps: List["Link"] = []     # links this one feeds (reverse
                                          # census edges, for incremental
                                          # static-floor refresh)
        self._inj_fed = False             # heads a publicly-routed path
        self._inj_src: Optional[InjectionSource] = None
        self._sink = None                 # endpoint wake heap (list) or None
        self._resv: List[int] = []        # scheduled future arrivals here
        # minimum transit through this link's server for a future message:
        # smallest possible serialization plus propagation
        self._xfer_lb = self._ser_ps(min_msg_bytes) + self._lat_ps
        # channel-clock caches, two-sided (see _clock_pair): clock >= _ge_v
        # proven for the current event (horizon-assisted grade, tagged by
        # event epoch _ge_e); clock >= _geL_v proven eternally (ledger-only
        # grade, tagged by ledger generation _geL_g — valid across events
        # until the generation bumps); clock < refuted for need >= _lt_v
        # this event, and for need >= _ltr_v while now <= _ltr_u (a parked
        # reservation witnessed at tick _ltr_u cannot fire earlier)
        self._ge_e = -1
        self._ge_v = 0
        self._geL_g = -1
        self._geL_v = 0
        self._lt_e = -1
        self._lt_v = 0
        self._ltr_v = 0
        self._ltr_u = -1
        self._busy_e = -1                 # cycle guard for the recursion
        # static feeder-cone transit floor (Fabric.build_transit_tables);
        # 0 = not built / no static guarantee
        self._static_lb = 0
        # fabric_ledger="auto" policy: top-level probe outcome counters,
        # and the per-link kill switch they drive (see _probe)
        self._auto = auto
        self._probe_on = True
        self._probe_ok = 0
        self._probe_fail = 0
        # failure backoff: after a full-evaluation refusal, skip the next
        # ``_bko`` probes outright (a skipped probe just parks — always
        # timing-sound) so a hot cone is not re-walked on every train
        self._bko = 0
        self._skip = 0

    @property
    def busy_ns(self) -> float:
        """Cumulative serialization time (stats; stored in integer ps)."""
        return self._busy_ps / _PS_PER_NS

    # ------------------------------------------------------------ fast path
    def _ser_ps(self, size: int) -> int:
        """Serialization delay in ticks, rounded exactly like classic mode."""
        ps = self._ser_ps_cache.get(size)
        if ps is None:
            ser = max(size / self.bw if self.bw > 0 else 0.0, self.min_ser_ns)
            ps = int(round(ser * _PS_PER_NS))
            self._ser_ps_cache[size] = ps
        return ps

    def _service(self, size: int, arrive_ps: int) -> int:
        """Commit FIFO service for a message arriving at ``arrive_ps``;
        returns the tick at which it lands on the next node.

        FIFO service order equals arrival order, so the serialization window
        is fully determined at arrival time.  Callers guarantee that no other
        message can still arrive at this link before ``arrive_ps`` — either
        ``arrive_ps`` is *now*, or it lies strictly before the engine's
        lookahead horizon (every admission is made by some heap event, and
        none is pending earlier than the horizon).
        """
        ser = self._ser_ps(size)
        if arrive_ps < self._last_arr_ps:
            # an optimistic ahead-of-time commit beat this (earlier) arrival
            # to the server: FIFO order is broken by at most the coalescing
            # window.  Counted so runs can certify themselves exact.
            self.order_violations += 1
        else:
            self._last_arr_ps = arrive_ps
        start = self._free_ps if self._free_ps > arrive_ps else arrive_ps
        fin = start + ser
        self._free_ps = fin
        self.bytes_moved += size
        self._busy_ps += ser
        return fin + self._lat_ps

    # --------------------------------------------------------------- classic
    def enqueue(self, flight: Flight) -> None:
        if self.fast:
            # injection / classic handoff: a real arrival at *now*.  The
            # flight starts chaining at its first hop event — committing
            # ahead from inside an arbitrary callback would be unsound (the
            # callback may still push earlier events after we return).
            watermark = self._free_ps + self._lat_ps
            next_at = self._service(flight.size, self.engine._now_ps)
            if self.coalesce:
                key = id(flight.route)
                tail = self._tails.get(key)
                if (tail is not None and tail.hop == flight.hop
                        and self.engine._now_ps < tail.at_ps[0]
                        and tail.at_ps[-1] == watermark):
                    # pending train on the same remaining route whose last
                    # member was this link's most recent service (nothing
                    # foreign serviced in between, so the members stay
                    # service-consecutive and downstream sole-feed chaining
                    # cannot commit past an interleaved flight): ride along
                    tail.lines.append(flight)
                    tail.at_ps.append(next_at)
                    if len(flight.route) == 1 and not flight.eager \
                            and self._sink is not None:
                        _heappush(self._sink, next_at)
                    return
                train = _train_new(flight.route, flight.hop)
                train.lines.append(flight)
                train.at_ps.append(next_at)
                train.tailed = True
                self._tails[key] = train
            else:
                train = _train_new(flight.route, flight.hop)
                train.lines.append(flight)
                train.at_ps.append(next_at)
            route = flight.route
            nxt = flight.hop + 1
            if nxt < len(route):
                nlink = route[nxt]
                if nlink.led:
                    _heappush(nlink._resv, next_at)
                    if next_at < nlink._geL_v:
                        nlink._geL_v = next_at
                reg1 = nlink.region
            else:
                last = route[-1]
                if last._sink is not None and not flight.eager:
                    _heappush(last._sink, next_at)
                reg1 = last.region
            self.engine.schedule_abs_ps(next_at, _propel, train, region=reg1,
                                        key=_rkey(route))
            return
        if self.policy == "fair":
            self._q[flight.cls].append(flight)
        else:
            self._q[0].append(flight)
        if not self._busy:
            self._start_next()

    def _pick(self) -> Optional[Flight]:
        if self.policy == "fair":
            for i in range(2):
                c = (self._rr + i) % 2
                q = self._q[c]
                if q:
                    self._rr = (c + 1) % 2  # other class goes first next time
                    return q.popleft()
            return None
        q = self._q[0]
        return q.popleft() if q else None

    def _start_next(self) -> None:
        flight = self._pick()
        if flight is None:
            self._busy = False
            return
        self._busy = True
        ser = max(flight.size / self.bw if self.bw > 0 else 0.0, self.min_ser_ns)
        self.bytes_moved += flight.size
        self._busy_ps += int(round(ser * _PS_PER_NS))
        self.engine.schedule(ser, self._finish, flight)

    def _finish(self, flight: Flight) -> None:
        # serialization done: link free for the next message; this message
        # propagates for lat_ns then arrives at the next node.  The arrival
        # event carries the route's tie-break key so same-tick arrivals at
        # the next link are serviced in the same order as the fast paths.
        self._start_next()
        self.engine.schedule(self.lat_ns, _advance, flight,
                             key=_rkey(flight.route))


def _clock_ge(link: "Link", need: int, depth: int) -> bool:
    """Channel-clock threshold query: True iff no not-yet-committed traffic
    can arrive at ``link``'s input queue before tick ``need``.  Thin
    boolean wrapper over :func:`_clock_pair`, the value-returning kernel."""
    eng = link.engine
    if link._geL_g == eng._led_gen and need <= link._geL_v:
        eng.led_hits += 1
        return True
    return _clock_pair(link, need, depth)[1] >= need


def _clock_pair(link: "Link", need: int, depth: int) -> Tuple[int, int]:
    """Value-returning channel-clock query (gen-cache fast path plus one
    context load, then :func:`_clock_eval`)."""
    eng = link.engine
    gen = eng._led_gen
    if link._geL_g == gen and need <= link._geL_v:
        eng.led_hits += 1
        v = link._geL_v
        return v, v
    return _clock_eval(link, need, depth, eng, eng.events_processed,
                       eng._now_ps, eng._no_hz, gen)


def _probe(link: "Link", need: int, eng: Engine) -> bool:
    """Top-level commit-check probe: the boolean clock query plus the
    failure backoff and the per-link hit/miss counters that feed
    :meth:`Fabric.ledger_counters` and the ``fabric_ledger="auto"``
    policy (``Link._probe_on``).

    Refusing without evaluating is always timing-sound — the caller just
    parks — so after a refuted full evaluation the link skips the next
    ``_bko`` probes (exponential, capped): a saturated cone refutes every
    train passing through, and walking it each time is the single largest
    proof cost.  A cached eternal value still answers instantly, and any
    successful evaluation resets the backoff."""
    if link._geL_g == eng._led_gen and need <= link._geL_v:
        eng.led_hits += 1
        link._probe_ok += 1
        return True
    s = link._skip
    if s:
        link._skip = s - 1
        link._probe_fail += 1
        return False
    if _clock_eval(link, need, eng.led_depth, eng, eng.events_processed,
                   eng._now_ps, eng._no_hz, eng._led_gen)[1] >= need:
        link._probe_ok += 1
        link._bko = 0
        return True
    b = link._bko
    link._skip = link._bko = (b + b) if 0 < b < _BKO_CAP else (b or 1)
    pf = link._probe_fail + 1
    link._probe_fail = pf
    if link._auto and pf >= _AUTO_MIN_FAILS and link._probe_ok << 1 < pf:
        # proof search on this link almost never pays: stop probing (parks
        # still record reservations, so other links' clocks stay sound)
        link._probe_on = False
    return False


def _clock_eval(link: "Link", need: int, depth: int, eng: Engine, ep: int,
                now: int, no_hz: bool, gen: int) -> Tuple[int, int]:
    """Value-returning channel-clock query (module docstring, "reservation
    ledgers"): lower bounds on the earliest tick at which not-yet-committed
    traffic could arrive at ``link``'s input queue, as a pair
    ``(v_ledger, v_assisted)``.

    ``v_ledger`` is assembled purely from the deterministic future
    schedule — reservations, feeder ``_free_ps`` floors, injection
    sources, minimum transit — and is *eternal*: the monitored channels
    only raise it (``_free_ps`` and the clock are monotone, reservations
    are arrivals the bound already covers), and every unmonitored action
    that could lower it bumps ``Engine._led_gen`` (untagged event pushes,
    semaphore-floor pushes, kernel dispatches, census/wiring changes).
    It is therefore cached on the link tagged with that generation
    (``_geL_g``/``_geL_v``): a quiet link answers thousands of probes
    across many events from one cached integer.

    ``v_assisted`` additionally uses the region lookahead horizon, which
    is contingent on the current event's pending queue: per-event
    validity only (``_ge_e``/``_ge_v``).  Mid-batch (``Engine._no_hz``)
    queries see no horizon contribution, so for them the two grades
    coincide — except injection sources that can only answer per-event,
    whose evidence deliberately stays out of the eternal grade.

    A query *succeeds* iff ``v_assisted >= need``.  On failure the search
    exits at the first refuting term and returns ``(-1, -1)`` — the
    partial values are meaningless and are never cached.  Refutations
    memoize per event (``_lt``); a refuting reservation additionally
    memoizes *across* events until its tick has passed (``_ltr``: the
    parked train it witnesses cannot fire earlier, and refuting more than
    necessary only costs a park, never timing).  Cycles in the feeder
    census refute conservatively via the ``_busy_e`` guard.

    Callers pre-check the generation cache and pass the engine context
    (event epoch, now, mid-batch flag, ledger generation) down the
    recursion, so the hot kernel re-loads nothing.
    """
    if need <= now:
        # any future arrival happens at the tick of some event >= now
        return now, now
    if not no_hz and link._ge_e == ep and need <= link._ge_v:
        return now, link._ge_v
    if link._lt_e == ep and need >= link._lt_v:
        return -1, -1
    if need >= link._ltr_v and now <= link._ltr_u:
        return -1, -1               # cross-event reservation witness
    if link._busy_e == ep:
        return -1, -1               # feeder cycle: refuse, do not memoize
    h = -1
    if not no_hz:
        # region horizon, inlined (Engine.horizon_ps): sound without
        # looking at any neighbor (but blind to an in-progress CU batch's
        # own future issues — see Engine)
        q = eng._queue
        reg = link.region
        if reg and eng._regioned:
            rheaps = eng._rheaps
            r = rheaps[reg]
            g = rheaps[0]
            b = r[0] if r else None
            if g and (b is None or g[0] < b):
                b = g[0]
            if q:
                cap = q[0][0] + link._rguard_ps
                if b is None or cap < b:
                    b = cap
            h = b if b is not None else _FAR
        else:
            h = q[0][0] if q else _FAR
        if need <= h:
            # early accept on the horizon alone: skip term evaluation,
            # memoize the horizon value for this event
            if link._ge_e != ep or h > link._ge_v:
                link._ge_e = ep
                link._ge_v = h
            return now, h
    if depth <= 0:
        if link._lt_e != ep or need < link._lt_v:
            link._lt_e = ep
            link._lt_v = need
        return -1, -1
    eng.led_hist[depth if depth < 16 else 16] += 1
    link._busy_e = ep
    ml, ma = _clock_terms(link, need, depth, eng, ep, now, no_hz, gen)
    link._busy_e = -1
    if ml < 0:
        if link._lt_e != ep or need < link._lt_v:
            link._lt_e = ep
            link._lt_v = need
        return -1, -1
    if h > ma:
        ma = h
    vl = now                        # "nothing uncommitted arrives before
    if link._geL_g == gen and link._geL_v > now:
        vl = link._geL_v            #  now" is itself an eternal statement
    if ml > vl:
        vl = ml
    link._geL_g = gen
    link._geL_v = vl
    if vl > ma:
        ma = vl
    if link._ge_e != ep or ma > link._ge_v:
        link._ge_e = ep
        link._ge_v = ma
    return vl, ma


def _clock_terms(link: "Link", need: int, depth: int, eng: Engine,
                 ep: int, now: int, no_hz: bool, gen: int) -> Tuple[int, int]:
    """Term evaluation for :func:`_clock_eval` (split out so the memo fast
    path above inlines well): the min over every way not-yet-committed
    traffic can reach the link, in both grades.  Returns ``(-1, -1)`` as
    soon as any term refutes ``need``.  Search order is outcome-affecting
    only through the conservative cycle guard, so the census order stays
    fixed for determinism."""
    ml = ma = _FAR
    # known future arrivals: trains scheduled to commit here next
    rh = link._resv
    if rh:
        while rh and rh[0] < now:   # strictly past entries have fired
            _heappop(rh)
        if rh:
            r0 = rh[0]
            if r0 < need:
                # the parked train arriving at r0 cannot fire earlier: it
                # refutes every later need until its event has passed
                link._ltr_v = r0 + 1
                link._ltr_u = r0
                return -1, -1
            ml = ma = r0
    # fresh injections at this route head (no source installed: only the
    # region horizon — already consulted by the caller — can prove it)
    if link._inj_fed:
        src = link._inj_src
        if src is None:
            return -1, -1
        sl, sa = src.inj_pair(need, depth)
        if sa < need:
            return -1, -1
        if sl < ml:
            ml = sl
        if sa < ma:
            ma = sa
    # traffic still upstream must clear a feeder's server first: it cannot
    # arrive here sooner than the feeder frees (or its own clock) plus the
    # feeder's minimum transit.  The static transit table short-circuits
    # the whole cone walk for small margins; otherwise the recursive
    # call's memo fast path is hoisted inline.
    slb = link._static_lb
    if slb and need <= now + slb:
        b = now + slb
        if b > _FAR:
            b = _FAR
        if b < ml:
            ml = b
        if b < ma:
            ma = b
        return ml, ma
    for f in link._feeders:
        if f.fast:
            x = f._xfer_lb
            t = need - x
            base = f._free_ps
            if base < now:
                base = now
            if base >= t:
                b = base + x
                if b < ml:
                    ml = b
                if b < ma:
                    ma = b
                continue
            if f._geL_g == gen and t <= f._geL_v:
                eng.led_hits += 1
                fl = fa = f._geL_v
            elif not no_hz and f._ge_e == ep and t <= f._ge_v:
                fl = now
                fa = f._ge_v
            elif (f._lt_e == ep and t >= f._lt_v) or f._busy_e == ep \
                    or (t >= f._ltr_v and now <= f._ltr_u):
                return -1, -1
            else:
                fl, fa = _clock_eval(f, t, depth - 1, eng, ep, now, no_hz,
                                     gen)
                if fa < t:
                    return -1, -1
            bl = (base if base > fl else fl) + x
            ba = (base if base > fa else fa) + x
            if bl < ml:
                ml = bl
            if ba < ma:
                ma = ba
        else:
            # classic/fair feeder: its queued messages advance on events
            # whose ticks the ledger cannot see; any pending event bounds
            # this event's view, but nothing is eternal through it
            q = eng._queue
            if q:
                q0 = q[0][0]
                if q0 < need:
                    return -1, -1
                if q0 < ma:
                    ma = q0
            if now < ml:
                ml = now
    return ml, ma


def _advance(flight: Flight) -> None:
    flight.hop += 1
    if flight.hop >= len(flight.route):
        flight.on_arrive(flight)
    else:
        flight.route[flight.hop].enqueue(flight)


def _deliver(flight: Flight) -> None:
    flight.eta_ps = flight.route[0].engine._now_ps if flight.route else \
        flight.eta_ps
    flight.on_arrive(flight)


def _enqueue_line(link: "Link", flight: Flight) -> None:
    link.enqueue(flight)


def _propel(train: _Train) -> None:
    """Advance a train along its route (see ``_propel_multi`` for the full
    commit rules).  Single-line trains — the overwhelming majority at
    cache-line granularity — take a scalar fast walk: same decisions, no
    per-hop list traffic, lazy horizon computation."""
    lines = train.lines
    if len(lines) != 1:
        _propel_multi(train)
        return
    route = train.route
    rkey = route.key if type(route) is Route else 0
    nroute = len(route)
    hop = train.hop + 1
    f = lines[0]
    at = train.at_ps[0]
    rlink = route[hop] if hop < nroute else route[-1]
    reg = rlink.region
    eng = rlink.engine
    now = eng._now_ps
    queue = eng._queue
    rheaps = eng._rheaps if eng._regioned else None
    bound = -1                       # lazily computed commit bound
    prev = route[hop - 1]
    while True:
        if hop >= nroute:
            train.hop = nroute
            f.hop = hop
            if f.eager:
                f.eta_ps = at
                f.on_arrive(f)
            elif at <= now:
                f.eta_ps = now
                f.on_arrive(f)
            else:
                # the arrival tick is final: stamp eta now and schedule the
                # endpoint callback directly (no _deliver trampoline)
                train.at_ps[0] = at
                f.eta_ps = at
                last = route[-1]
                dreg = last.region
                if last._sink is not None:
                    _heappush(last._sink, at)
                _heappush(queue, (at, rkey, eng._seq, f.on_arrive, (f,), dreg))
                eng._seq += 1
                if dreg:
                    if rheaps is not None:
                        _heappush(rheaps[dreg], at)
                else:
                    # untagged push: Engine._push's ledger-generation bump,
                    # inlined (this site bypasses _push)
                    eng._led_gen += 1
                    if rheaps is not None:
                        _heappush(rheaps[0], at)
            _train_free(train)
            return
        link = route[hop]
        if at > now and link._sole_feed is not prev:
            if link.region != reg:
                if not link.led:
                    # region boundary: park so the target region's horizon
                    # can see this traffic coming.  (No tail registration:
                    # single lines are only joinable at injection, hop 0 —
                    # a parked 1-line train mid-route can never be merged
                    # into.)
                    train.hop = hop - 1
                    train.at_ps[0] = at
                    lreg = link.region
                    _heappush(queue, (at, rkey, eng._seq, _propel, (train,),
                                      lreg))
                    eng._seq += 1
                    if not lreg:
                        eng._led_gen += 1   # untagged push (see _push)
                    if rheaps is not None:
                        _heappush(rheaps[lreg], at)
                    return
                # ledger: chain across the boundary when the channel clock
                # allows; refresh the horizon for the new region
                reg = link.region
                bound = -1
            if bound < 0:
                if eng._no_hz:
                    bound = now      # mid-batch: horizon proofs are blind
                # inline region horizon (Engine.horizon_ps)
                elif reg and rheaps is not None:
                    r = rheaps[reg]
                    g = rheaps[0]
                    b = r[0] if r else None
                    if g and (b is None or g[0] < b):
                        b = g[0]
                    if queue:
                        cap = queue[0][0] + link._rguard_ps
                        if b is None or cap < b:
                            b = cap
                    bound = b if b is not None else _FAR
                else:
                    bound = queue[0][0] if queue else _FAR
            if at >= bound and at - now > link._win_ps and \
                    not (link.led and link._probe_on
                         and _probe(link, at + 1, eng)):
                train.hop = hop - 1
                train.at_ps[0] = at
                if hop == 1 and prev.coalesce:
                    # parked right at injection: later same-route flights
                    # may still ride along (the hop-0 join contract)
                    train.tailed = True
                    prev._tails[id(route)] = train
                lreg = link.region
                if link.led:
                    _heappush(link._resv, at)
                    if at < link._geL_v:
                        link._geL_v = at    # defensive eternal-cache clamp
                _heappush(queue, (at, rkey, eng._seq, _propel, (train,), lreg))
                eng._seq += 1
                if not lreg:
                    eng._led_gen += 1       # untagged push (see _push)
                if rheaps is not None:
                    _heappush(rheaps[lreg], at)
                return
        if not link.fast:
            train.hop = nroute
            f.hop = hop
            if at <= now:
                link.enqueue(f)
            else:
                eng.schedule_abs_ps(at, _enqueue_line, link, f, region=0,
                                    key=rkey)
            _train_free(train)
            return
        # FIFO service commit, inlined
        size = f.size
        ser = link._ser_ps_cache.get(size)
        if ser is None:
            ser = link._ser_ps(size)
        if at < link._last_arr_ps:
            link.order_violations += 1
        else:
            link._last_arr_ps = at
        free = link._free_ps
        start = free if free > at else at
        fin = start + ser
        link._free_ps = fin
        link.bytes_moved += size
        link._busy_ps += ser
        at = fin + link._lat_ps
        train.hop = hop
        hop += 1
        if link.region != reg:
            # crossed a region boundary through a sole-fed link
            reg = link.region
            bound = -1
        prev = link


def _propel_multi(train: _Train) -> None:
    """Advance a train along its route; at most one heap event per region.

    The train keeps moving within a single event while the next arrival tick
    stays inside the *commit bound* of the region it is traversing:

    * the region lookahead horizon (``Engine.peek_region``) — provably safe:
      only events of this region (or untagged ones) can put traffic on these
      links, none is pending earlier than the horizon, so no competing
      arrival can interleave; and
    * optionally the per-link optimistic window ``now + W`` — exact whenever
      the links involved are uncontended; any flight an ahead-of-time commit
      *did* cut in front of is detected by the per-link arrival-order
      monitor (``order_violations``), so a run reporting zero violations is
      certified bit-identical to the un-coalesced schedule.

    The chain parks (schedules one event, tagged with the target region) at
    region boundaries, at the destination, and wherever the bound runs out;
    lines of a multi-line train the bound cannot cover split into a
    re-scheduled remainder train.  Always invoked as a heap event (or
    synchronously right after an admission at *now*), so a line whose
    arrival tick equals *now* really is arriving and its callback may run
    inline.
    """
    route = train.route
    rkey = route.key if type(route) is Route else 0
    lines, at_ps = train.lines, train.at_ps
    nroute = len(route)
    hop = train.hop + 1
    rlink = route[hop] if hop < nroute else route[-1]
    reg = rlink.region
    eng = rlink.engine
    now = eng._now_ps
    # commit bound, computed on first need: traffic from another region
    # must cross one of this region's entry links first — it can reach an
    # interior link no sooner than the earliest pending event anywhere
    # plus that entry transit
    bound = -1
    sched = eng.schedule_abs_ps
    while True:
        first = at_ps[0]
        if hop >= nroute:
            # destination: time-stamp-driven (eager) callbacks run inline on
            # their committed arrival tick; stateful ones get an event so
            # they observe their own arrival time.  Mark the train consumed
            # (sentinel hop) so stale ``_tails`` entries at links it passed
            # can never accept new joiners.
            train.hop = nroute
            n = len(lines)
            inline0 = first <= now
            last = route[-1]
            sink = last._sink
            dreg = last.region          # deliveries affect the destination
            for i in range(n):          # region's state, whatever region
                g = lines[i]            # the chain started in
                g.hop = hop
                if g.eager:
                    g.eta_ps = at_ps[i]
                    g.on_arrive(g)
                elif i == 0 and inline0:
                    g.eta_ps = now
                    g.on_arrive(g)
                else:
                    if sink is not None:
                        _heappush(sink, at_ps[i])
                    sched(at_ps[i], _deliver, g, region=dreg, key=rkey)
            _train_free(train)
            return
        link = route[hop]
        if first > now and link._sole_feed is not route[hop - 1]:
            # ahead of real time on a link with other (or unknown) feeders:
            # the usual lookahead rules apply
            if link.region != reg:
                if not link.led:
                    # region boundary: park so the target region's horizon
                    # can see this traffic coming (its tag makes it visible)
                    train.hop = hop - 1
                    if link.coalesce:
                        train.tailed = True
                        route[hop - 1]._tails[id(route)] = train
                    sched(first, _propel, train, region=link.region,
                          key=rkey)
                    return
                # ledger: chain across the boundary when the channel clock
                # allows; refresh the horizon for the new region
                reg = link.region
                bound = -1
            if bound < 0:
                if eng._no_hz:
                    bound = now      # mid-batch: horizon proofs are blind
                else:
                    b = eng.horizon_ps(reg, link._rguard_ps)
                    bound = b if b is not None else _FAR
            if first >= bound and first - now > link._win_ps and \
                    not (link.led and link._probe_on
                         and _probe(link, first + 1, eng)):
                # neither provably safe (region horizon / channel clock)
                # nor within the optimistic window: park until arrival
                train.hop = hop - 1
                if link.coalesce:
                    train.tailed = True
                    route[hop - 1]._tails[id(route)] = train
                if link.led:
                    _heappush(link._resv, first)
                    if first < link._geL_v:
                        link._geL_v = first
                sched(first, _propel, train, region=link.region, key=rkey)
                return
        if not link.fast:
            # classic/fair link: per-line arrivals (its round-robin pick
            # depends on queue state at serialization-finish time).  The
            # train is consumed here (sentinel hop, see above).
            train.hop = nroute
            for i in range(len(lines)):
                g = lines[i]
                g.hop = hop
                if at_ps[i] <= now:
                    link.enqueue(g)
                else:
                    sched(max(at_ps[i], now), _enqueue_line, link, g,
                          region=0, key=rkey)
            _train_free(train)
            return
        if link.region != reg:
            # entering this link's region — through a sole-fed crossing or
            # with the head arrival already due: every further ahead-of-
            # time commit (the multi-line split limit in particular) must
            # be bounded by the NEW region's horizon, not the stale one
            reg = link.region
            bound = -1
        if len(lines) == 1:
            # hot path: single line, inlined FIFO service commit
            f = lines[0]
            size = f.size
            ser = link._ser_ps_cache.get(size)
            if ser is None:
                ser = link._ser_ps(size)
            if first < link._last_arr_ps:
                link.order_violations += 1
            else:
                link._last_arr_ps = first
            free = link._free_ps
            start = free if free > first else first
            fin = start + ser
            link._free_ps = fin
            link.bytes_moved += size
            link._busy_ps += ser
            at_ps[0] = fin + link._lat_ps
            train.hop = hop
            hop += 1
            # (region crossings are handled by the refresh at the top of
            # the per-link processing, before any commit)
            continue
        # ---- multi-line train ------------------------------------------
        n = len(lines)
        sole = link._sole_feed is route[hop - 1]
        if not sole:
            if bound < 0:
                if eng._no_hz:
                    bound = now      # mid-batch: horizon proofs are blind
                else:
                    b = eng.horizon_ps(reg, link._rguard_ps)
                    bound = b if b is not None else _FAR
            stop = n
            lim = now + link._win_ps
            if bound > lim:
                lim = bound
            # the horizon alone is not enough for a multi-line train: its
            # OWN first delivery may wake a CU whose reinjected traffic
            # arrives before the later lines' committed ticks (neither the
            # horizon nor the channel clock can see events this walk is
            # about to schedule).  Cap the commit window at the first
            # line's earliest possible delivery — no consequence of it can
            # reach any link sooner.
            own = at_ps[0]
            sz0 = lines[0].size
            for l in route[hop:]:
                own += l._ser_ps(sz0) + l._lat_ps
            led = link.led and link._probe_on
            for i in range(1, n):
                a = at_ps[i]
                if a >= own or (a >= lim and not
                                (led and _probe(link, a + 1, eng))):
                    stop = i
                    break
            if stop < n:
                rest = _train_new(route, hop - 1)
                rest.lines = lines[stop:]
                rest.at_ps = at_ps[stop:]
                del lines[stop:]
                del at_ps[stop:]
                if link.coalesce:
                    rest.tailed = True
                    route[hop - 1]._tails[id(route)] = rest
                if link.led:
                    _heappush(link._resv, rest.at_ps[0])
                    if rest.at_ps[0] < link._geL_v:
                        link._geL_v = rest.at_ps[0]
                sched(rest.at_ps[0], _propel, rest, region=reg, key=rkey)
                n = stop
        if link.coalesce:
            key = id(route)
            tail = link._tails.get(key)
            if (tail is not None and tail.hop == hop
                    and now < tail.at_ps[0]
                    and tail.at_ps[-1] == link._free_ps + link._lat_ps):
                # merge into the pending train already queued on this link;
                # this train is consumed (sentinel hop: stale ``_tails``
                # entries pointing at it must reject future joiners)
                train.hop = nroute
                for i in range(n):
                    lines[i].hop = hop
                    tail.lines.append(lines[i])
                    tail.at_ps.append(link._service(lines[i].size, at_ps[i]))
                _train_free(train)
                return
        for i in range(n):
            lines[i].hop = hop
            at_ps[i] = link._service(lines[i].size, at_ps[i])
        train.hop = hop
        nxt = hop + 1
        if n > 1 and nxt < nroute and route[nxt]._sole_feed is not link \
                and not route[nxt].led:
            # multi-line trains advance one hop per event on contended
            # links: a later line's committed arrival may exceed the first
            # line's delivery time, and that delivery's callback may inject
            # competing traffic.  Sole-fed links inherit FIFO order from
            # this link, so the train may chain straight through them.
            # (With the ledger, the next iteration's commit window — which
            # is capped by the train's own first delivery — makes the same
            # call per line instead of parking wholesale.)
            if link.coalesce:
                train.tailed = True
                link._tails[id(route)] = train
            if route[nxt].led:
                _heappush(route[nxt]._resv, at_ps[0])
                if at_ps[0] < route[nxt]._geL_v:
                    route[nxt]._geL_v = at_ps[0]
            sched(at_ps[0], _propel, train, region=route[nxt].region,
                  key=rkey)
            return
        hop += 1


class Fabric:
    """A named-node topology with cached shortest-path routing.

    ``mode`` selects the link scheduling implementation (see module
    docstring): :data:`MODE_COALESCE` (default), :data:`MODE_EXACT`, or
    :data:`MODE_CLASSIC`.
    """

    # default optimistic window: 0 = off, the sound region-horizon bound
    # alone governs ahead-of-time commits (bit-exact guarantee).  Positive
    # values trade certified exactness for fewer events (see _propel).
    DEFAULT_WINDOW_NS = 0.0

    def __init__(self, engine: Engine, default_policy: str = "fifo",
                 mode: str = MODE_COALESCE,
                 coalesce_window_ns: Optional[float] = None,
                 ledger=True, min_msg_bytes: int = 0,
                 ledger_depth: Optional[int] = None):
        self.engine = engine
        self.default_policy = default_policy
        self.mode = mode
        # ledger accepts the NocConfig.fabric_ledger strings ("on"/"off"/
        # "auto") as well as plain bools; "auto" keeps the ledger sound
        # everywhere but lets each link's probe-outcome counters disable
        # proof search where it never pays (see _probe)
        if isinstance(ledger, str):
            self.ledger_auto = ledger == "auto"
            ledger_on = ledger != "off"
        else:
            self.ledger_auto = False
            ledger_on = bool(ledger)
        self.ledger = ledger_on and mode != MODE_CLASSIC
        engine.led_depth = LEDGER_DEPTH if ledger_depth is None \
            else ledger_depth
        # smallest wire message the workload can put on any link (0 = no
        # promise): tightens the ledger's per-feeder transit lower bound
        self.min_msg_bytes = min_msg_bytes
        self.coalesce_window_ns = (self.DEFAULT_WINDOW_NS
                                   if coalesce_window_ns is None
                                   else coalesce_window_ns)
        self.node_names: List[str] = []
        self.node_ids: Dict[str, int] = {}
        # adjacency: node id -> list of (neighbor id, Link)
        self.adj: List[List[Tuple[int, Link]]] = []
        self._census_dirty = False      # any feeder/head census recorded?
        self._route_cache: Dict[Tuple[int, int], List[Link]] = {}
        self._via_cache: Dict[Tuple[int, ...], List[Link]] = {}
        self._bfs_trees: Dict[int, list] = {}
        self.links: List[Link] = []
        self._next_rkey = 1             # route tie-break keys (see Route)
        # census epochs: links whose feeder census mutated since the last
        # commit_census(); lazy route registration batches its updates here
        self._census_changed: set = set()
        self._tables_built = False      # build_transit_tables has run
        # count of census commits that landed while a changed link had
        # already admitted traffic (its FIFO monitor certifies soundness)
        self.census_retro = 0

    # ------------------------------------------------------------- building
    def add_node(self, name: str) -> int:
        if name in self.node_ids:
            return self.node_ids[name]
        nid = len(self.node_names)
        self.node_names.append(name)
        self.node_ids[name] = nid
        self.adj.append([])
        return nid

    def node(self, name: str) -> int:
        return self.node_ids[name]

    def add_link(self, u: int, v: int, bandwidth_GBps: float, latency_ns: float,
                 policy: Optional[str] = None, name: Optional[str] = None,
                 region: int = 0) -> Link:
        link = Link(self.engine,
                    name or f"{self.node_names[u]}->{self.node_names[v]}",
                    bandwidth_GBps, latency_ns,
                    policy or self.default_policy, mode=self.mode,
                    coalesce_window_ns=self.coalesce_window_ns, region=region,
                    ledger=self.ledger, min_msg_bytes=self.min_msg_bytes,
                    auto=self.ledger_auto)
        self.engine._led_gen += 1       # wiring change: drop eternal caches
        self.adj[u].append((v, link))
        self.links.append(link)
        self._route_cache.clear()
        self._via_cache.clear()
        self._bfs_trees.clear()
        # the feeder/injection census was drawn from routes that no longer
        # exist: a link added after routes were registered must not keep
        # sole-feeder or ledger conclusions from the dropped route space.
        # (Pristine builds — to_cluster wiring hundreds of links before any
        # route is asked for — skip the sweep.)
        if self._census_dirty:
            self.reset_census()
        return link

    def reset_census(self) -> None:
        """Forget every feeder/injection conclusion drawn from registered
        routes (they re-form as routes are re-registered).  Called on
        topology mutation; injection sources and endpoint sinks are wiring
        metadata installed by the owner (e.g. ``Cluster.warm_routes``) and
        must be re-installed by it after re-warming."""
        self._census_dirty = False
        self._census_changed.clear()
        self._tables_built = False
        self.engine._led_gen += 1       # census change: drop eternal caches
        for l in self.links:
            l._sole_feed = None
            l._feeders = []
            l._deps = []
            l._inj_fed = False
            l._inj_src = None
            l._sink = None
            l._static_lb = 0            # table was built from the old census
            l._probe_on = True
            l._bko = 0
            l._skip = 0

    def add_bidi(self, u: int, v: int, bandwidth_GBps: float, latency_ns: float,
                 policy: Optional[str] = None,
                 region: int = 0) -> Tuple[Link, Link]:
        return (self.add_link(u, v, bandwidth_GBps, latency_ns, policy,
                              region=region),
                self.add_link(v, u, bandwidth_GBps, latency_ns, policy,
                              region=region))

    # -------------------------------------------------------------- routing
    def route(self, src: int, dst: int,
              key: Optional[int] = None) -> List[Link]:
        """Shortest path, marking the head injection-fed.

        ``key`` optionally pins the route's tie-break key (see
        :class:`Route`).  Lazy registration uses positional keys that are
        order-isomorphic to the eager first-use order, which is what keeps
        same-tick heap ties — and therefore schedules — bit-identical
        whichever order pairs are registered in.
        """
        path = self._route_seg(src, dst, key)
        if path:
            self._mark_head(path[0])
        return path

    def _route_seg(self, src: int, dst: int,
                   key: Optional[int] = None) -> List[Link]:
        """Shortest path *without* marking the first link injection-fed.

        ``route_via`` stitches these segments together: a segment's first
        link is an interior hop of the concatenated route, fed by the
        previous segment's last link — marking it injection-fed there used
        to break the sole-feeder corridor at every waypoint (e.g. the
        ``io -> switch`` hop of every cross-GPU route), parking chains that
        are provably FIFO-safe.  Only the *public* entry points mark heads.
        """
        ck = (src, dst)
        hit = self._route_cache.get(ck)
        if hit is not None:
            return hit
        path = Route(self._bfs(src, dst))
        if key is None:
            key = self._next_rkey
            self._next_rkey += 1
        path.key = key
        self._route_cache[ck] = path
        self._register_feeders(path)
        return path

    def route_via(self, waypoints: List[int],
                  key: Optional[int] = None) -> List[Link]:
        """Concatenated shortest-path route through ``waypoints``.

        Cached per waypoint tuple: callers on the same via-path share one
        route *object*, which is what lets the coalescing fast path recognize
        same-route flights and merge them into trains.
        """
        ck = tuple(waypoints)
        hit = self._via_cache.get(ck)
        if hit is not None:
            return hit
        out: Route = Route()
        if key is None:
            out.key = self._next_rkey
            self._next_rkey += 1
        else:
            out.key = key
        seg = 1
        for a, b in zip(waypoints, waypoints[1:]):
            if a != b:
                # segment keys never break flight ties (segments are not
                # flight routes) but keep them deterministic regardless of
                # registration order by deriving them from the via key
                out.extend(self._route_seg(
                    a, b, None if key is None else key + seg))
                seg += 1
        self._via_cache[ck] = out
        self._register_feeders(out)
        if out:
            self._mark_head(out[0])
        return out

    def _mark_head(self, link: Link) -> None:
        """Mark a link as the head of a publicly-routed path: messages can
        be injected onto it, so its feeder order is never sole."""
        if not link._inj_fed or link._sole_feed is not False:
            self._census_changed.add(link)
        if link._sole_feed is not False:
            link._sole_feed = False
        link._inj_fed = True
        self._census_dirty = True
        self.engine._led_gen += 1       # census change: drop eternal caches

    def _register_feeders(self, path: List[Link]) -> None:
        """Record each link's upstream feeders along a (cached) route.

        A link fed by exactly one predecessor across every registered route
        inherits that predecessor's FIFO order, letting the fast path chain
        admissions through it without a lookahead check; the full feeder
        list is the reservation ledger's census (channel clocks take the
        min over every registered way traffic can reach a link).
        """
        if not path:
            return
        self._census_dirty = True
        self.engine._led_gen += 1       # census change: drop eternal caches
        prev = path[0]
        changed = self._census_changed
        for link in path[1:]:
            feeders = link._feeders
            if prev not in feeders:
                feeders.append(prev)
                prev._deps.append(link)
                changed.add(link)
                cur = link._sole_feed
                if cur is None and not link._inj_fed:
                    link._sole_feed = prev
                elif cur is not prev:
                    link._sole_feed = False
            prev = link
        return

    def commit_census(self) -> None:
        """Seal a batch of lazy route registrations into a census epoch.

        Every link whose feeder census mutated since the last commit gets
        its probe policy re-armed (prior probe-outcome statistics argued
        about a smaller route space) and — once static transit tables
        exist — its static lower-bound floor refreshed incrementally
        through the downstream feeder cone (see
        :func:`ledger_tables.refresh_static_floors`).  Mid-run commits bump
        the per-event memo epoch so no channel-clock memo from the old
        census survives, and count links that had already admitted traffic
        (``census_retro``): the ahead-commit window is never widened
        retroactively because floors only *decrease* under new feeders, and
        the FIFO monitor (``order_violations``) certifies the result.
        """
        changed = self._census_changed
        if not changed:
            return
        eng = self.engine
        if eng._running:
            now = eng._now_ps
            for l in changed:
                if l._last_arr_ps > 0 or l._free_ps > now:
                    self.census_retro += 1
                    break
            # bump the per-event memo epoch: clock memos predating this
            # census must not answer queries about the widened route space
            eng.events_processed += 1
        for l in changed:
            l._probe_on = True
            l._bko = 0
            l._skip = 0
            l._probe_ok = 0
            l._probe_fail = 0
        if self._tables_built:
            from .ledger_tables import refresh_static_floors
            refresh_static_floors(changed)
        self._census_changed = set()

    def _bfs(self, src: int, dst: int) -> List[Link]:
        """Shortest path via a cached per-source BFS parent tree.

        One full BFS per distinct source amortizes across all destinations
        (the cluster pre-registers every route it can ever use — see
        ``Cluster.warm_routes``); discovery order matches the classic
        per-pair BFS exactly, so paths — and therefore timings — are
        unchanged.
        """
        if src == dst:
            return []
        tree = self._bfs_trees.get(src)
        if tree is None:
            tree = [None] * len(self.node_names)
            frontier = deque([src])
            seen = {src}
            while frontier:
                u = frontier.popleft()
                for v, link in self.adj[u]:
                    if v in seen:
                        continue
                    seen.add(v)
                    tree[v] = (u, link)
                    frontier.append(v)
            self._bfs_trees[src] = tree
        if tree[dst] is None:
            raise ValueError(
                f"no route {self.node_names[src]} -> {self.node_names[dst]}")
        path: List[Link] = []
        cur = dst
        while cur != src:
            cur, l = tree[cur]
            path.append(l)
        path.reverse()
        return path

    # --------------------------------------------------------------- sending
    def send(self, route: List[Link], size: int, cls: int,
             on_arrive: Callable[[Flight], None], payload=None,
             eager: bool = False) -> None:
        """Inject a message onto a precomputed route."""
        if not route:
            # src == dst: deliver at *now*.  Eager (time-stamp-driven)
            # callbacks run inline — they read ``eta_ps`` and only schedule
            # absolute-time effects; stateful ones still get an event so
            # causality is preserved.
            f = Flight(size, cls, route, on_arrive, payload, eager)
            f.hop = 0
            f.eta_ps = self.engine._now_ps
            if eager:
                on_arrive(f)
            else:
                self.engine.schedule(0.0, on_arrive, f)
            return
        flight = Flight(size, cls, route, on_arrive, payload, eager)
        route[0].enqueue(flight)

    def send_at(self, route: List[Link], size: int, cls: int,
                on_arrive: Callable[[Flight], None], payload=None,
                at_ps: Optional[int] = None, eager: bool = False) -> None:
        """Inject a message whose first-link arrival is at a *future* tick.

        Contract: successive ``send_at`` calls targeting the same first link
        must carry non-decreasing ``at_ps`` across events (e.g. responses
        leaving a memory endpoint after a fixed access latency).  This lets
        an endpoint fold its fixed-latency injection into the event that
        requested it, saving one heap event per round trip; the per-link
        arrival-order monitor still detects any contract breach.
        """
        now = self.engine._now_ps
        if at_ps is None or at_ps < now:
            at_ps = now
        if not route:
            f = Flight(size, cls, route, on_arrive, payload, eager)
            f.hop = 0
            # the arrival tick is final either way: stamp it here — the
            # scheduled path used to leave ``eta_ps`` at -1 (the ``_deliver``
            # trampoline only stamps non-empty routes)
            f.eta_ps = at_ps
            if eager:
                on_arrive(f)
            else:
                self.engine.schedule_abs_ps(at_ps, on_arrive, f)
            return
        flight = Flight(size, cls, route, on_arrive, payload, eager)
        self.send_flight_at(flight, at_ps)

    def send_flight_at(self, flight: Flight, at_ps: int,
                       chain: bool = False) -> None:
        """``send_at`` for a caller-prepared flight (zero allocation).

        The flight's ``route`` (non-empty), ``size``, ``cls``, ``eager``,
        ``on_arrive`` and ``hop == 0`` must be set; the cluster's request
        path re-arms one object per round trip through here.

        ``chain=True`` walks the route inline (inside the calling event)
        instead of scheduling the first hop event, letting the reservation
        ledger carry the flight as far as its channel clocks allow — zero
        heap events for a fully-chained leg.  Only valid under the ledger
        discipline: successive injections per first link are monotone in
        ``at_ps`` and share the injector's route tree (so later same-source
        traffic stays FIFO-behind on every shared link), and every other
        injector is fenced by an installed :class:`InjectionSource`.
        """
        eng = self.engine
        now = eng._now_ps
        if at_ps < now:
            at_ps = now
        route = flight.route
        first = route[0]
        if not first.fast:
            if at_ps <= now:
                first.enqueue(flight)
            else:
                eng.schedule_abs_ps(at_ps, _enqueue_line, first, flight,
                                    key=_rkey(route))
            return
        # inline FIFO service commit on the first link
        size = flight.size
        ser = first._ser_ps_cache.get(size)
        if ser is None:
            ser = first._ser_ps(size)
        if at_ps < first._last_arr_ps:
            first.order_violations += 1
        else:
            first._last_arr_ps = at_ps
        free = first._free_ps
        start = free if free > at_ps else at_ps
        fin = start + ser
        first._free_ps = fin
        first.bytes_moved += size
        first._busy_ps += ser
        next_at = fin + first._lat_ps
        if first.coalesce:
            key = id(route)
            tail = first._tails.get(key)
            if (tail is not None and tail.hop == 0
                    and now < tail.at_ps[0]
                    and tail.at_ps[-1] == free + first._lat_ps):
                # a train is pending on this link for the same route, its
                # hop event has not fired, AND its last member was this
                # link's most recent service (the pre-commit ``free``
                # watermark): the members stay service-consecutive, so
                # downstream sole-feed chaining cannot commit past a
                # foreign flight serviced in between.  Ride along.
                tail.lines.append(flight)
                tail.at_ps.append(next_at)
                if len(route) == 1 and not flight.eager \
                        and first._sink is not None:
                    _heappush(first._sink, next_at)
                return
            train = _train_new(route, 0)
            train.lines.append(flight)
            train.at_ps.append(next_at)
            if chain and first.led:
                # walk inline: the ledger decides how far; parks register
                # their own tails/reservations, deliveries their own sinks
                _propel(train)
                return
            train.tailed = True
            first._tails[key] = train
        else:
            train = _train_new(route, 0)
            train.lines.append(flight)
            train.at_ps.append(next_at)
            if chain and first.led:
                _propel(train)
                return
        if len(route) > 1:
            nlink = route[1]
            if nlink.led:
                _heappush(nlink._resv, next_at)
                if next_at < nlink._geL_v:
                    nlink._geL_v = next_at
            reg1 = nlink.region
        else:
            last = route[-1]
            if last._sink is not None and not flight.eager:
                _heappush(last._sink, next_at)
            reg1 = last.region
        _heappush(eng._queue, (next_at, _rkey(route), eng._seq, _propel,
                               (train,), reg1))
        eng._seq += 1
        if not reg1:
            eng._led_gen += 1               # untagged push (see _push)
        if eng._regioned:
            _heappush(eng._rheaps[reg1], next_at)

    def inject_train(self, route: List[Link], flights: List[Flight],
                     ats: List[int], chain: bool = False) -> None:
        """Inject a pre-batched request train (bulk wavefront emission).

        ``flights`` are caller-prepared (route/size/cls/eager/on_arrive
        set); ``ats[i]`` is flight ``i``'s first-link arrival tick —
        non-decreasing and in the future, e.g. the issue ticks of one CU
        streak, which arrive in tick order on the CU's (single-injector)
        first link.  The whole batch commits FIFO service up front and
        rides ONE scheduled hop event through the existing lookahead /
        coalescing machinery, instead of one ``send_at`` round trip per
        cache line; a pending same-route tail train is joined when its hop
        event has not fired yet.  Per-line service commit times are
        identical to per-line injection, so timing is bit-exact.
        """
        first = route[0]
        eng = self.engine
        if not first.fast:
            # classic/fair first link: the per-line machinery is the
            # reference path (service order depends on queue state)
            now = eng._now_ps
            for i, f in enumerate(flights):
                at_ps = ats[i]
                if at_ps <= now:
                    first.enqueue(f)
                else:
                    eng.schedule_abs_ps(at_ps, _enqueue_line, first, f,
                                        key=_rkey(route))
            return
        train = None
        if first.coalesce:
            tail = first._tails.get(id(route))
            if (tail is not None and tail.hop == 0
                    and eng._now_ps < tail.at_ps[0]
                    and tail.at_ps[-1] == first._free_ps + first._lat_ps):
                # joinable only while service-consecutive (see
                # send_flight_at): the tail's last member must be this
                # link's most recent service
                train = tail
        new = train is None
        if new:
            train = _train_new(route, 0)
        lines, ticks = train.lines, train.at_ps
        service = first._service
        for i, f in enumerate(flights):
            lines.append(f)
            ticks.append(service(f.size, ats[i]))
        if new:
            if chain and first.led:
                # walk the whole batch inline (see send_flight_at)
                _propel(train)
                return
            if first.coalesce:
                train.tailed = True
                first._tails[id(route)] = train
            if len(route) > 1:
                nlink = route[1]
                if nlink.led:
                    _heappush(nlink._resv, ticks[0])
                    if ticks[0] < nlink._geL_v:
                        nlink._geL_v = ticks[0]
                reg1 = nlink.region
            else:
                last = route[-1]
                if last._sink is not None:
                    for i in range(len(flights)):
                        if not flights[i].eager:
                            _heappush(last._sink, ticks[i])
                reg1 = last.region
            eng.schedule_abs_ps(ticks[0], _propel, train, region=reg1,
                                key=_rkey(route))

    # ------------------------------------------------------------------ stats
    @property
    def order_violations(self) -> int:
        """Total FIFO-order inversions caused by ahead-of-time commits.

        Zero certifies that this run's link schedules are bit-identical to
        the un-coalesced (``MODE_EXACT``) schedule.
        """
        return sum(l.order_violations for l in self.links)

    def set_injection_source(self, node: int, src: InjectionSource) -> None:
        """Attach an injection-bound provider to every registered route head
        leaving ``node`` (see :class:`InjectionSource`).  Heads without a
        source fall back to the region horizon — sound for any injector that
        only acts from engine events."""
        self.engine._led_gen += 1       # wiring change: drop eternal caches
        for _, link in self.adj[node]:
            link._inj_src = src

    def inbound_map(self) -> Dict[int, List[Link]]:
        """node id -> inbound links, in one adjacency pass."""
        out: Dict[int, List[Link]] = {}
        for nbrs in self.adj:
            for v, link in nbrs:
                out.setdefault(v, []).append(link)
        return out

    def clock_ge_ps(self, link: Link, need_ps: int,
                    depth: Optional[int] = None) -> bool:
        """Channel-clock threshold query (tests/tools): True iff no
        not-yet-committed traffic can reach ``link`` before ``need_ps``."""
        if depth is None:
            depth = self.engine.led_depth
        return _clock_ge(link, need_ps, depth)

    def build_transit_tables(self) -> None:
        """Precompute each link's static feeder-cone transit floor.

        For every link: a lower bound on the delay beyond *now* before any
        not-yet-committed traffic can emerge from its feeder cone, valid at
        every future query — the min over feeders of (feeder transit +
        feeder floor), where a feeder's floor collapses to zero as soon as
        traffic can *enter* at it at an arbitrary tick (injection-fed,
        classic-fed, parkable, or a reservation-push target).  Computed by
        vectorized relaxation over flat link-id-indexed int64 arrays
        (:mod:`.ledger_tables`), sound for cyclic censuses (the relaxation
        fixpoint).  The clock kernel uses it to accept small-margin probes
        without walking the feeder DAG (see ``_clock_terms``); reservations
        and injections at the link itself stay dynamic.  Call after the
        route space is registered (``Cluster.warm_routes`` does).
        """
        from .ledger_tables import build_static_floors
        floors = build_static_floors(self.links)
        for i, l in enumerate(self.links):
            l._static_lb = floors[i]
        self._tables_built = True
        self._census_changed.clear()
        self.engine._led_gen += 1

    def ledger_counters(self) -> Dict[str, object]:
        """Ledger observability counters (exported into BENCH rows).

        ``probes``/``chained_legs``: top-level commit checks issued and
        proven (a proven probe is one park event saved).  ``validity_hits``:
        queries answered by a cached cross-event clock value.
        ``evaluations``/``depth_hist``: full term evaluations, by remaining
        recursion depth.  ``probe_off_links``: links whose proof search the
        auto policy disabled.
        """
        eng = self.engine
        ok = sum(l._probe_ok for l in self.links)
        fail = sum(l._probe_fail for l in self.links)
        evals = sum(eng.led_hist)
        hits = eng.led_hits
        return {
            "probes": ok + fail,
            "chained_legs": ok,
            "probe_hit_rate": ok / (ok + fail) if ok + fail else 0.0,
            "validity_hits": hits,
            "evaluations": evals,
            "memo_hit_rate": hits / (hits + evals) if hits + evals else 0.0,
            "depth_hist": [d for d in eng.led_hist],
            "probe_off_links": sum(1 for l in self.links
                                   if not l._probe_on),
            "census_retro": self.census_retro,
        }

    def set_region_guard(self, region: int, guard_ns: float) -> None:
        """Set a region's entry transit: a lower bound on the time any
        message coming from *outside* the region needs to cross one of its
        entry links (e.g. the inbound scale-up hop).  Sound lookahead for
        the region extends to ``earliest pending event + guard``."""
        guard_ps = int(round(guard_ns * _PS_PER_NS))
        self.engine._led_gen += 1       # wiring change: drop eternal caches
        for link in self.links:
            if link.region == region:
                link._rguard_ps = guard_ps

    @property
    def routes_registered(self) -> int:
        """Distinct routes materialized so far (lazy registration makes
        this scale with pairs actually used, not all pairs)."""
        return len(self._route_cache) + len(self._via_cache)

    def stats(self) -> Dict[str, float]:
        return {
            "links": len(self.links),
            "nodes": len(self.node_names),
            "bytes_moved": sum(l.bytes_moved for l in self.links),
            "order_violations": self.order_violations,
            "routes_registered": self.routes_registered,
        }
