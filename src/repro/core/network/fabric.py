"""Event-driven network fabric (paper §4.5).

One implementation serves both granularities:

* the **NoC-level detailed backend** — nodes are CUs, NoC routers, HBM
  channels and I/O ports; messages are cache-line-sized Wavefront Requests —
  and
* the **coarse Simple backend** — nodes are GPUs/NICs/switches; messages are
  chunk-sized collective transfers.

Links are store-and-forward servers with bandwidth, latency, and a two-class
(control vs. data) arbitration policy; ``fifo`` lets large data messages
block control traffic (the paper's Fig. 11 pathology), ``fair`` round-robins
between the classes.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from ..engine import Engine

CONTROL = 0
DATA = 1


class Flight:
    """A message in transit along a precomputed route of links."""
    __slots__ = ("size", "cls", "route", "hop", "on_arrive", "payload")

    def __init__(self, size: int, cls: int, route: List["Link"],
                 on_arrive: Callable[["Flight"], None], payload=None):
        self.size = size
        self.cls = cls
        self.route = route
        self.hop = 0
        self.on_arrive = on_arrive
        self.payload = payload


class Link:
    """Directed link: a serialization server + propagation latency.

    ``policy``: "fifo" (single queue, arrival order) or "fair" (round-robin
    between the control and data queues — paper §5.2's arbitration fix).
    """
    __slots__ = ("name", "bw", "lat_ns", "policy", "engine", "_q", "_busy",
                 "_rr", "bytes_moved", "busy_ns", "min_ser_ns")

    def __init__(self, engine: Engine, name: str, bandwidth_GBps: float,
                 latency_ns: float, policy: str = "fifo",
                 min_ser_ns: float = 0.0):
        self.name = name
        self.bw = bandwidth_GBps  # GB/s == bytes/ns
        self.lat_ns = latency_ns
        self.policy = policy
        self.engine = engine
        self._q: Tuple[deque, deque] = (deque(), deque())  # control, data
        self._busy = False
        self._rr = 0
        self.bytes_moved = 0
        self.busy_ns = 0.0
        self.min_ser_ns = min_ser_ns

    def enqueue(self, flight: Flight) -> None:
        if self.policy == "fair":
            self._q[flight.cls].append(flight)
        else:
            self._q[0].append(flight)
        if not self._busy:
            self._start_next()

    def _pick(self) -> Optional[Flight]:
        if self.policy == "fair":
            for i in range(2):
                c = (self._rr + i) % 2
                q = self._q[c]
                if q:
                    self._rr = (c + 1) % 2  # other class goes first next time
                    return q.popleft()
            return None
        q = self._q[0]
        return q.popleft() if q else None

    def _start_next(self) -> None:
        flight = self._pick()
        if flight is None:
            self._busy = False
            return
        self._busy = True
        ser = max(flight.size / self.bw if self.bw > 0 else 0.0, self.min_ser_ns)
        self.bytes_moved += flight.size
        self.busy_ns += ser
        self.engine.schedule(ser, self._finish, flight)

    def _finish(self, flight: Flight) -> None:
        # serialization done: link free for the next message; this message
        # propagates for lat_ns then arrives at the next node.
        self._start_next()
        self.engine.schedule(self.lat_ns, _advance, flight)


def _advance(flight: Flight) -> None:
    flight.hop += 1
    if flight.hop >= len(flight.route):
        flight.on_arrive(flight)
    else:
        flight.route[flight.hop].enqueue(flight)


class Fabric:
    """A named-node topology with cached shortest-path routing."""

    def __init__(self, engine: Engine, default_policy: str = "fifo"):
        self.engine = engine
        self.default_policy = default_policy
        self.node_names: List[str] = []
        self.node_ids: Dict[str, int] = {}
        # adjacency: node id -> list of (neighbor id, Link)
        self.adj: List[List[Tuple[int, Link]]] = []
        self._route_cache: Dict[Tuple[int, int], List[Link]] = {}
        self.links: List[Link] = []

    # ------------------------------------------------------------- building
    def add_node(self, name: str) -> int:
        if name in self.node_ids:
            return self.node_ids[name]
        nid = len(self.node_names)
        self.node_names.append(name)
        self.node_ids[name] = nid
        self.adj.append([])
        return nid

    def node(self, name: str) -> int:
        return self.node_ids[name]

    def add_link(self, u: int, v: int, bandwidth_GBps: float, latency_ns: float,
                 policy: Optional[str] = None, name: Optional[str] = None) -> Link:
        link = Link(self.engine,
                    name or f"{self.node_names[u]}->{self.node_names[v]}",
                    bandwidth_GBps, latency_ns,
                    policy or self.default_policy)
        self.adj[u].append((v, link))
        self.links.append(link)
        self._route_cache.clear()
        return link

    def add_bidi(self, u: int, v: int, bandwidth_GBps: float, latency_ns: float,
                 policy: Optional[str] = None) -> Tuple[Link, Link]:
        return (self.add_link(u, v, bandwidth_GBps, latency_ns, policy),
                self.add_link(v, u, bandwidth_GBps, latency_ns, policy))

    # -------------------------------------------------------------- routing
    def route(self, src: int, dst: int) -> List[Link]:
        key = (src, dst)
        hit = self._route_cache.get(key)
        if hit is not None:
            return hit
        path = self._bfs(src, dst)
        self._route_cache[key] = path
        return path

    def route_via(self, waypoints: List[int]) -> List[Link]:
        """Concatenated shortest-path route through ``waypoints``."""
        out: List[Link] = []
        for a, b in zip(waypoints, waypoints[1:]):
            if a != b:
                out.extend(self.route(a, b))
        return out

    def _bfs(self, src: int, dst: int) -> List[Link]:
        if src == dst:
            return []
        prev: Dict[int, Tuple[int, Link]] = {}
        frontier = deque([src])
        seen = {src}
        while frontier:
            u = frontier.popleft()
            for v, link in self.adj[u]:
                if v in seen:
                    continue
                seen.add(v)
                prev[v] = (u, link)
                if v == dst:
                    path: List[Link] = []
                    cur = dst
                    while cur != src:
                        cur, l = prev[cur]
                        path.append(l)
                    path.reverse()
                    return path
                frontier.append(v)
        raise ValueError(f"no route {self.node_names[src]} -> {self.node_names[dst]}")

    # --------------------------------------------------------------- sending
    def send(self, route: List[Link], size: int, cls: int,
             on_arrive: Callable[[Flight], None], payload=None) -> None:
        """Inject a message onto a precomputed route."""
        if not route:
            # src == dst: deliver immediately (still via the event queue so
            # causality is preserved)
            f = Flight(size, cls, route, on_arrive, payload)
            f.hop = 0
            self.engine.schedule(0.0, on_arrive, f)
            return
        flight = Flight(size, cls, route, on_arrive, payload)
        route[0].enqueue(flight)

    # ------------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        return {
            "links": len(self.links),
            "nodes": len(self.node_names),
            "bytes_moved": sum(l.bytes_moved for l in self.links),
        }
