from .fabric import Fabric, Link, CONTROL, DATA
from .simple import SimpleNetwork, alpha_beta_time

__all__ = ["Fabric", "Link", "CONTROL", "DATA", "SimpleNetwork",
           "alpha_beta_time"]
