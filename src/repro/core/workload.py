"""Fine-grained workload representation (paper §4.1.3–4.1.4).

Kernel  = set of workgroups, each mapped to one CU, run in parallel.
Workgroup = sequence of GPU operations executed by ``num_wavefronts``
            lock-step wavefronts.
Wavefront = per-wavefront instruction stream state (PC over the op list).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional

from .instructions import Instruction
from .operations import GpuOp, OpContext

_kernel_ids = itertools.count()


@dataclass
class Workgroup:
    ops: List[GpuOp]
    num_wavefronts: int = 4
    name: str = ""

    def total_ops(self) -> int:
        return len(self.ops)


@dataclass
class Kernel:
    """A GPU kernel: workgroups dispatched in parallel onto CUs."""
    workgroups: List[Workgroup]
    name: str = ""
    gpu: int = 0                         # rank this kernel runs on
    kid: int = field(default_factory=lambda: next(_kernel_ids))
    on_done: Optional[Callable[["Kernel", float], None]] = None

    # filled by the GPU model
    start_ns: float = -1.0
    end_ns: float = -1.0

    def __post_init__(self) -> None:
        if not self.name:
            self.name = f"kernel{self.kid}"

    @property
    def duration_ns(self) -> float:
        return self.end_ns - self.start_ns


class WavefrontState:
    """Execution cursor of one wavefront: iterates the workgroup's op list,
    expanding each op into its instruction stream lazily."""

    __slots__ = ("wf", "num_wf", "wg", "ctx", "op_idx", "_instrs",
                 "outstanding", "waiting", "done", "current_op", "fetched",
                 "sem_seen", "owner")

    def __init__(self, wf: int, wg: Workgroup, ctx: OpContext):
        self.wf = wf
        self.num_wf = wg.num_wavefronts
        self.wg = wg
        self.ctx = ctx
        self.op_idx = 0
        self._instrs: Optional[Iterator[Instruction]] = None
        self.outstanding = 0            # this wavefront's in-flight mem ops
        self.waiting: Optional[str] = None  # None|"waitcnt"|"sem"|"sync"|"mem"
        self.done = False
        self.current_op: Optional[GpuOp] = None
        self.fetched: Optional[Instruction] = None  # decoded but un-issued
        self.sem_seen: int = 0          # semaphore value observed by poll
        self.owner = None               # _WGExec backlink (set by the CU)

    def retired(self) -> bool:
        """Instruction stream exhausted AND all memory traffic landed."""
        return self.done and self.outstanding == 0

    def peek_sync(self) -> Optional[str]:
        """If the next op is a sync op (no instructions), return its kind."""
        if self.fetched is None and self.op_idx < len(self.wg.ops):
            op = self.wg.ops[self.op_idx]
            if self._instrs is None and op.sync_kind is not None:
                return op.sync_kind
        return None

    def advance_sync(self) -> None:
        """Consume a sync op (called when the barrier resolves)."""
        self.op_idx += 1
        self._instrs = None
        self.current_op = None

    def fetch(self) -> Optional[Instruction]:
        """Return the next un-issued instruction without losing it.

        The CU calls ``fetch()`` to decide issuability; once the instruction
        is actually issued it must call ``consume()``.  ``None`` means the
        wavefront is at a sync op (``peek_sync`` tells which) or done.
        """
        if self.fetched is None:
            self.fetched = self._pull()
        return self.fetched

    def consume(self) -> None:
        self.fetched = None

    def _pull(self) -> Optional[Instruction]:
        while self.op_idx < len(self.wg.ops):
            op = self.wg.ops[self.op_idx]
            if op.sync_kind is not None:
                return None                      # CU must resolve the sync
            if self._instrs is None:
                self.current_op = op
                self._instrs = op.instructions(self.wf, self.num_wf, self.ctx)
            nxt = next(self._instrs, None)
            if nxt is not None:
                return nxt
            self.op_idx += 1
            self._instrs = None
            self.current_op = None
        self.done = True
        return None
