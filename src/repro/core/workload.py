"""Fine-grained workload representation (paper §4.1.3–4.1.4).

Kernel  = set of workgroups, each mapped to one CU, run in parallel.
Workgroup = sequence of GPU operations executed by ``num_wavefronts``
            lock-step wavefronts.
Wavefront = per-wavefront instruction stream state (PC over the op list).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from .operations import GpuOp, OpContext

_kernel_ids = itertools.count()


@dataclass
class Workgroup:
    ops: List[GpuOp]
    num_wavefronts: int = 4
    name: str = ""

    def total_ops(self) -> int:
        return len(self.ops)


@dataclass
class Kernel:
    """A GPU kernel: workgroups dispatched in parallel onto CUs."""
    workgroups: List[Workgroup]
    name: str = ""
    gpu: int = 0                         # rank this kernel runs on
    kid: int = field(default_factory=lambda: next(_kernel_ids))
    on_done: Optional[Callable[["Kernel", float], None]] = None

    # filled by the GPU model
    start_ns: float = -1.0
    end_ns: float = -1.0

    def __post_init__(self) -> None:
        if not self.name:
            self.name = f"kernel{self.kid}"

    @property
    def duration_ns(self) -> float:
        return self.end_ns - self.start_ns


class WavefrontState:
    """Execution cursor of one wavefront.

    Iterates the workgroup's op list, compiling each op — once, on first
    touch — into its flat :class:`~repro.core.instructions.InstrStream`
    (kind/addr/size scalar tuples plus streak run-lengths).  The CU's scan
    then reads entries by index: no generator frames, no per-cache-line
    ``Instruction``/``MemRef`` boxing, and the streak metadata the bulk
    emission path needs comes for free.
    """

    __slots__ = ("wf", "num_wf", "wg", "ctx", "op_idx", "entries", "runs",
                 "pc", "outstanding", "waiting", "done", "current_op",
                 "wait_thresh", "owner")

    def __init__(self, wf: int, wg: Workgroup, ctx: OpContext):
        self.wf = wf
        self.num_wf = wg.num_wavefronts
        self.wg = wg
        self.ctx = ctx
        self.op_idx = 0
        self.entries: Optional[list] = None   # current op's compiled stream
        self.runs: Optional[list] = None      # LOAD/STORE streak lengths
        self.pc = 0                           # index into ``entries``
        self.outstanding = 0            # this wavefront's in-flight mem ops
        self.waiting: Optional[str] = None  # None|"waitcnt"|"sem"|"sync"
        self.done = False
        self.current_op: Optional[GpuOp] = None
        self.wait_thresh = 0            # threshold of the blocking Waitcnt
        self.owner = None               # _WGExec backlink (set by the CU)

    def retired(self) -> bool:
        """Instruction stream exhausted AND all memory traffic landed."""
        return self.done and self.outstanding == 0

    def peek_sync(self) -> Optional[str]:
        """If the cursor sits on a sync op (no instructions), its kind."""
        if self.entries is None and self.op_idx < len(self.wg.ops):
            return self.wg.ops[self.op_idx].sync_kind
        return None

    def advance_sync(self) -> None:
        """Consume a sync op (called when the barrier resolves)."""
        self.op_idx += 1
        self.current_op = None

    def next_entry(self) -> Optional[tuple]:
        """The entry at the cursor, advancing across op boundaries.

        Returns ``None`` when the wavefront is parked at a sync op
        (``peek_sync`` tells which) or finished (``done`` is set).  The
        caller consumes an issued entry by incrementing ``pc``.
        """
        while True:
            ents = self.entries
            if ents is not None:
                if self.pc < len(ents):
                    return ents[self.pc]
                self.entries = None
                self.runs = None
                self.current_op = None
                self.op_idx += 1
            ops = self.wg.ops
            if self.op_idx >= len(ops):
                self.done = True
                return None
            op = ops[self.op_idx]
            if op.sync_kind is not None:
                return None                  # CU must resolve the sync
            stream = op.compile(self.wf, self.num_wf, self.ctx)
            self.current_op = op
            self.entries = stream.entries
            self.runs = stream.runs
            self.pc = 0
