"""Canonical content hashing for experiment provenance (DSE sweep cache).

The sweep harness (:mod:`repro.sweep`) caches simulation results on disk
keyed by *what was simulated*: the workload, the infrastructure, and the
tier config.  That only works if the same semantic object always hashes
to the same string — across processes (``PYTHONHASHSEED`` must not leak
in), across sessions, and across the machines a sweep may be sharded
over.  This module is the one place that canonicalization lives:

* :func:`canonical_form` lowers an object to a JSON-able structure with
  deterministic ordering (dict keys sorted, dataclasses tagged with
  their class name, tuples flattened to lists);
* :func:`canonical_json` serializes that form compactly with
  ``sort_keys=True`` so byte output is order-independent;
* :func:`content_hash` is the sha256 hex digest of the canonical JSON.

Objects participate in one of three ways, tried in order:

1. a ``content_hash()`` method (``Program``, ``ExecutionTrace``,
   ``Infrastructure`` and the tier configs define one) — embedded as an
   opaque tagged digest so nested objects stay stable even if their
   internals gain fields;
2. a ``canonical_form()`` method returning a JSON-able structure;
3. plain dataclasses and builtin containers, handled structurally.

Anything else (callables, open handles, arbitrary instances) raises
``TypeError`` — silently hashing ``repr()`` would make cache keys
collide or drift, which is worse than failing loudly.

Runtime fields are the *caller's* responsibility to exclude: each
``content_hash()`` implementation hashes semantic fields only (e.g. an
``ExecutionTrace`` hashes identically before and after a run).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any

__all__ = ["canonical_form", "canonical_json", "content_hash",
           "hash_of", "combine_hashes"]


def canonical_form(obj: Any) -> Any:
    """Lower ``obj`` to a deterministic JSON-able structure."""
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        # repr() is the shortest round-trip form — stable across CPython
        # processes and platforms for equal values (and what json.dumps
        # emits anyway); normalize int-valued floats explicitly so
        # 2.0 == 2 hash apart deliberately (they are different configs)
        return obj
    if isinstance(obj, (list, tuple)):
        return [canonical_form(x) for x in obj]
    if isinstance(obj, (set, frozenset)):
        items = [canonical_form(x) for x in obj]
        return {"__set__": sorted(items, key=lambda x: json.dumps(
            x, sort_keys=True, default=str))}
    if isinstance(obj, dict):
        if all(isinstance(k, str) for k in obj):
            return {k: canonical_form(v) for k, v in obj.items()}
        pairs = sorted(
            ([canonical_form(k), canonical_form(v)] for k, v in obj.items()),
            key=lambda kv: json.dumps(kv[0], sort_keys=True, default=str))
        return {"__pairs__": pairs}
    ch = getattr(obj, "content_hash", None)
    if callable(ch):
        return {"__content_hash__": type(obj).__qualname__, "sha256": ch()}
    cf = getattr(obj, "canonical_form", None)
    if callable(cf):
        return {"__canonical__": type(obj).__qualname__,
                "form": canonical_form(cf())}
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {"__dataclass__": type(obj).__qualname__,
                "fields": {f.name: canonical_form(getattr(obj, f.name))
                           for f in dataclasses.fields(obj)}}
    raise TypeError(
        f"object of type {type(obj).__qualname__!r} is not canonically "
        f"hashable; give it a content_hash() or canonical_form() method, "
        f"or make it a dataclass of hashable fields")


def canonical_json(obj: Any) -> str:
    """Canonical compact JSON of ``obj`` (deterministic byte output)."""
    return json.dumps(canonical_form(obj), sort_keys=True,
                      separators=(",", ":"))


def content_hash(obj: Any) -> str:
    """sha256 hex digest of ``obj``'s canonical JSON."""
    return hashlib.sha256(canonical_json(obj).encode("utf-8")).hexdigest()


def hash_of(obj: Any, none_token: str = "none") -> str:
    """``content_hash`` that maps ``None`` to a fixed token and prefers an
    object's own ``content_hash()`` — the sweep cache's building block."""
    if obj is None:
        return none_token
    ch = getattr(obj, "content_hash", None)
    if callable(ch):
        return ch()
    return content_hash(obj)


def combine_hashes(**parts: str) -> str:
    """One key from named sub-hashes (sorted by part name)."""
    payload = json.dumps(sorted(parts.items()), separators=(",", ":"))
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()
