"""Backend seam shared types (paper §4: one program, many fidelities).

Every fidelity tier consumes the same MSCCL++ :class:`~repro.core.mscclpp.
Program` and the same InfraGraph :class:`~repro.core.infragraph.graph.
Infrastructure`, and produces the same :class:`CollectiveResult` — so
studies can dial fidelity up and down without touching the experiment
code.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Protocol, runtime_checkable

from ..mscclpp import Program


@dataclass
class CollectiveResult:
    """Uniform result record across all fidelity tiers."""
    program: str
    collective: str
    nranks: int
    time_ns: float
    moved_bytes: int               # payload bytes defined by the collective
    events: int
    wallclock_s: float
    requests: int = 0
    per_rank_done_ns: Optional[List[float]] = None
    fidelity: str = "fine"

    @property
    def bus_GBps(self) -> float:
        """Collective bandwidth: buffer size / collective time (paper §5.2)."""
        return self.moved_bytes / self.time_ns if self.time_ns > 0 else 0.0


def payload_bytes(program: Program) -> int:
    """The 'buffer size' the paper divides by: per-rank output payload."""
    return program.buffers.get("output", 0)


@runtime_checkable
class SimBackend(Protocol):
    """A fidelity tier: runs a collective Program end to end.

    Implementations: :class:`~repro.core.backends.fine.FineBackend`
    (Load-Store granularity on a detailed Cluster),
    :class:`~repro.core.backends.coarse.CoarseBackend` (chunk granularity
    on the alpha-beta SimpleNetwork), and
    :class:`~repro.core.backends.analytic.AnalyticBackend` (closed-form
    estimators, no event simulation).
    """

    fidelity: str

    def run(self, program: Program, **kwargs) -> CollectiveResult:
        """Simulate ``program`` and return its timing result."""
        ...
