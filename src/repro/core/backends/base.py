"""Backend seam shared types (paper §4: one workload, many fidelities).

Every fidelity tier consumes the same workload — an MSCCL++
:class:`~repro.core.mscclpp.Program` or a Chakra-style
:class:`~repro.core.chakra.ExecutionTrace` — over the same InfraGraph
:class:`~repro.core.infragraph.graph.Infrastructure`, and produces a
result deriving from one :class:`SimResult` base, so studies can dial
fidelity up and down (and swap single collectives for whole training
steps) without touching the experiment code.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Protocol, runtime_checkable

from ..mscclpp import Program


@dataclass
class SimResult:
    """Fields shared by every simulation result, at every tier.

    Sweep scripts can treat :class:`CollectiveResult` (a single collective
    program) and :class:`~repro.core.chakra.TraceResult` (a multi-kernel
    execution trace) uniformly through this base.
    """
    time_ns: float = 0.0
    events: int = 0
    wallclock_s: float = 0.0
    fidelity: str = "fine"
    per_rank_done_ns: Optional[List[float]] = None


@dataclass
class CollectiveResult(SimResult):
    """Result of one collective Program (uniform across fidelity tiers)."""
    program: str = ""
    collective: str = ""
    nranks: int = 0
    moved_bytes: int = 0           # payload bytes defined by the collective
    requests: int = 0

    @property
    def bus_GBps(self) -> float:
        """Collective bandwidth: buffer size / collective time (paper §5.2)."""
        return self.moved_bytes / self.time_ns if self.time_ns > 0 else 0.0


def payload_bytes(program: Program) -> int:
    """The 'buffer size' the paper divides by: per-rank output payload."""
    return program.buffers.get("output", 0)


@runtime_checkable
class SimBackend(Protocol):
    """A fidelity tier: runs a collective Program end to end.

    Implementations: :class:`~repro.core.backends.fine.FineBackend`
    (Load-Store granularity on a detailed Cluster),
    :class:`~repro.core.backends.coarse.CoarseBackend` (chunk granularity
    on the alpha-beta SimpleNetwork), and
    :class:`~repro.core.backends.analytic.AnalyticBackend` (closed-form
    estimators, no event simulation).  ExecutionTraces run over these same
    backends through :mod:`repro.core.backends.workload`.
    """

    fidelity: str

    def run(self, program: Program, **kwargs) -> CollectiveResult:
        """Simulate ``program`` and return its timing result."""
        ...
