"""The chunk-granularity MSCCL++ program interpreter.

This is the single home of coarse op semantics (put/get = one network
message, signal = control message, copy/reduce = memory-bandwidth cost,
wait/barrier = ordering only) — extracted from the old
``system._CoarseExec`` so the coarse and analytic backends can never
drift apart: both execute programs through this one interpreter, differing
only in the :class:`Transport` they plug in (a contended ``SimpleNetwork``
fabric vs. contention-free alpha-beta delays).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Protocol, Tuple

from ..engine import Engine
from ..mscclpp import Program
from ..network.fabric import CONTROL, DATA


class Transport(Protocol):
    """What the interpreter needs from a network model."""

    engine: Engine

    def send(self, src_gpu: int, dst_gpu: int, size: int,
             on_done: Callable[[], None], cls: int = DATA) -> None:
        ...


class AnalyticTransport:
    """Contention-free alpha-beta message delays (closed-form per message).

    The analytic tier's fallback for programs whose collective has no
    closed-form estimator: every transfer takes ``alpha + size/beta``
    independent of link occupancy, so the event count is proportional to
    the *program* size, not the payload size.
    """

    def __init__(self, alpha_ns: float, beta_GBps: float,
                 engine: Optional[Engine] = None):
        self.engine = engine or Engine()
        self.alpha_ns = alpha_ns
        self.beta_GBps = beta_GBps

    def send(self, src_gpu: int, dst_gpu: int, size: int,
             on_done: Callable[[], None], cls: int = DATA) -> None:
        if src_gpu == dst_gpu:
            self.engine.schedule(0.0, on_done)
            return
        delay = self.alpha_ns + (size / self.beta_GBps
                                 if self.beta_GBps > 0 else 0.0)
        self.engine.schedule(delay, on_done)


class ProgramInterpreter:
    """Chunk-granularity interpreter of an MSCCL++ program.

    Semantics: put/get = one network message of `size`; signal = one small
    control message; copy/reduce = local, modeled with a memory-bandwidth
    cost; wait/barrier = ordering only.  This is deliberately the 2.0-level
    model — no CU contention, no per-cache-line control path.
    """

    HDR = 64  # control message bytes

    def __init__(self, program: Program, net: Transport,
                 local_GBps: float, reduce_GBps: float,
                 rank_delay_ns: Optional[List[float]] = None,
                 deferred: bool = False,
                 on_rank_done: Optional[Callable[[int, float], None]] = None):
        """``deferred=True`` holds every rank's cursors until the owner calls
        :meth:`start_rank` — the workload seam's hook for dispatching one
        collective's per-rank halves as their trace dependencies resolve.
        ``on_rank_done(rank, t_ns)`` fires once per rank on completion.
        """
        self.p = program
        self.net = net
        self.e = net.engine
        self.local_GBps = local_GBps
        self.reduce_GBps = reduce_GBps
        self.on_rank_done = on_rank_done
        self.sems: Dict[Tuple[int, int], int] = {}
        self.pcs: Dict[Tuple[int, int], int] = {}
        self.blocked: Dict[Tuple[int, int], bool] = {}
        self.done_at: Dict[int, float] = {}
        self.live = 0
        for r in range(program.num_ranks):
            wgs = program.gpus[r]
            if not wgs and not deferred:
                # a rank with no workgroups at all (e.g. a p2p transfer's
                # bystander) schedules no cursors and would otherwise never
                # reach _rank_done, leaving done_at[r] missing and the
                # backend's per_rank_done_ns assembly raising.  Complete it
                # immediately — via an event, mirroring start_rank(), so
                # completion observes a consistent `now` (and any launch
                # delay still applies).
                delay = rank_delay_ns[r] if rank_delay_ns else 0.0
                self.e.schedule(delay, self._rank_done, r)
                continue
            for w in range(len(wgs)):
                self.pcs[(r, w)] = 0
                self.blocked[(r, w)] = False
                self.live += 1
                if not deferred:
                    delay = rank_delay_ns[r] if rank_delay_ns else 0.0
                    self.e.schedule(delay, self._advance, r, w)

    def start_rank(self, r: int) -> None:
        """Release rank ``r``'s workgroup cursors at the current time
        (deferred-start mode; see ``__init__``)."""
        wgs = self.p.gpus[r]
        if not wgs:
            # a rank with no program: complete immediately (still via an
            # event so completion observes a consistent `now`)
            self.e.schedule(0, self._rank_done, r)
            return
        for w in range(len(wgs)):
            self.e.schedule(0, self._advance, r, w)

    # each (rank, wg) cursor advances op by op; ops take simulated time
    def _advance(self, r: int, w: int) -> None:
        ops = self.p.gpus[r][w]
        pc = self.pcs[(r, w)]
        if pc >= len(ops):
            self._wg_done(r, w)
            return
        o = ops[pc]
        if o.op in ("put", "get"):
            peer = o.remote_rank
            src, dst = (r, peer) if o.op == "put" else (peer, r)
            self.pcs[(r, w)] = pc + 1
            self.net.send(src, dst, o.size, lambda: self._advance(r, w),
                          cls=DATA)
        elif o.op == "copy":
            self.pcs[(r, w)] = pc + 1
            self.e.schedule(o.size / self.local_GBps, self._advance, r, w)
        elif o.op == "reduce":
            nsrc = max(1, len(o.srcs or []))
            cost = o.size * nsrc / self.reduce_GBps
            # remote sources pay a network round trip too
            remote = [s for s in (o.srcs or []) if len(s) > 2 and s[2] >= 0
                      and s[2] != r]
            self.pcs[(r, w)] = pc + 1
            if remote:
                pend = {"n": len(remote)}

                def got_one():
                    pend["n"] -= 1
                    if pend["n"] == 0:
                        self.e.schedule(cost, self._advance, r, w)
                for s in remote:
                    self.net.send(s[2], r, o.size, got_one, cls=DATA)
            else:
                self.e.schedule(cost, self._advance, r, w)
        elif o.op == "signal":
            self.pcs[(r, w)] = pc + 1
            peer, sem = o.remote_rank, o.sem

            def deliver():
                key = (peer, sem)
                self.sems[key] = self.sems.get(key, 0) + 1
                self._wake_waiters(peer)
            self.net.send(r, peer, self.HDR, deliver, cls=CONTROL)
            self.e.schedule(0, self._advance, r, w)
        elif o.op == "wait":
            if self.sems.get((r, o.sem), 0) >= o.expected:
                self.pcs[(r, w)] = pc + 1
                self.e.schedule(0, self._advance, r, w)
            else:
                self.blocked[(r, w)] = True
        elif o.op == "barrier":
            # coarse: barrier when every wg of the rank is at one
            self.blocked[(r, w)] = True
            if all(self.pcs[(r, w2)] >= len(self.p.gpus[r][w2]) or
                   (self.blocked[(r, w2)] and
                    self.p.gpus[r][w2][self.pcs[(r, w2)]].op == "barrier")
                   for w2 in range(len(self.p.gpus[r]))):
                for w2 in range(len(self.p.gpus[r])):
                    pc2 = self.pcs[(r, w2)]
                    if pc2 < len(self.p.gpus[r][w2]) and \
                            self.p.gpus[r][w2][pc2].op == "barrier":
                        self.pcs[(r, w2)] = pc2 + 1
                        self.blocked[(r, w2)] = False
                        self.e.schedule(0, self._advance, r, w2)
        else:  # nop / flush: free at coarse granularity
            self.pcs[(r, w)] = pc + 1
            self.e.schedule(0, self._advance, r, w)

    def _wake_waiters(self, rank: int) -> None:
        for w in range(len(self.p.gpus[rank])):
            if not self.blocked[(rank, w)]:
                continue
            pc = self.pcs[(rank, w)]
            ops = self.p.gpus[rank][w]
            if pc < len(ops) and ops[pc].op == "wait" and \
                    self.sems.get((rank, ops[pc].sem), 0) >= ops[pc].expected:
                self.blocked[(rank, w)] = False
                self.pcs[(rank, w)] = pc + 1
                self.e.schedule(0, self._advance, rank, w)

    def _wg_done(self, r: int, w: int) -> None:
        self.live -= 1
        if all(self.pcs[(r, w2)] >= len(self.p.gpus[r][w2])
               for w2 in range(len(self.p.gpus[r]))):
            self._rank_done(r)

    def _rank_done(self, r: int) -> None:
        if r not in self.done_at:
            self.done_at[r] = self.e.now
            if self.on_rank_done is not None:
                self.on_rank_done(r, self.e.now)
