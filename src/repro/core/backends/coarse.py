"""Coarse backend: chunk granularity over the alpha-beta SimpleNetwork.

ASTRA-sim 2.0 fidelity (paper §2.1): one event-driven message per
put/get, zero-cost local ops, contended links — but no CU model and no
per-cache-line control path.  Program semantics come from the shared
:class:`~repro.core.backends.interpreter.ProgramInterpreter`.
"""

from __future__ import annotations

from typing import List, Optional

from ..mscclpp import Program
from ..network.simple import SimpleNetwork, SimpleTopology
from .base import CollectiveResult, payload_bytes
from .interpreter import ProgramInterpreter


class CoarseBackend:
    """ASTRA-sim 2.0 fidelity tier."""

    fidelity = "coarse"

    def __init__(self, infra=None, topo: Optional[SimpleTopology] = None,
                 link_GBps: float = 34.36 * 8, link_lat_ns: float = 1000.0,
                 local_GBps: float = 1099.5, reduce_GBps: float = 4398.0):
        self.infra = infra
        self.topo = topo
        self.link_GBps = link_GBps
        self.link_lat_ns = link_lat_ns
        self.local_GBps = local_GBps
        self.reduce_GBps = reduce_GBps

    def make_topology(self, num_ranks: int) -> SimpleTopology:
        if self.topo is not None:
            return self.topo
        if self.infra is not None:
            from ..infragraph.translate import to_simple_topology
            return to_simple_topology(self.infra)
        return SimpleTopology([(num_ranks, self.link_GBps, self.link_lat_ns,
                                "switch")])

    def run(self, program: Program,
            rank_delay_ns: Optional[List[float]] = None,
            until_ns: float = 5e10) -> CollectiveResult:
        """ASTRA-sim 2.0-fidelity simulation of the same program."""
        topo = self.make_topology(program.num_ranks)
        if topo.num_gpus < program.num_ranks:
            raise ValueError(
                f"topology has {topo.num_gpus} endpoints but the program "
                f"needs {program.num_ranks} ranks")
        net = SimpleNetwork(topo)
        ex = ProgramInterpreter(program, net, self.local_GBps,
                                self.reduce_GBps, rank_delay_ns)
        net.run(until_ns)
        if len(ex.done_at) != program.num_ranks:
            missing = [r for r in range(program.num_ranks)
                       if r not in ex.done_at]
            raise RuntimeError(f"coarse sim incomplete: ranks {missing}")
        t = max(ex.done_at.values())
        return CollectiveResult(
            program=program.name + ".coarse", collective=program.collective,
            nranks=program.num_ranks, time_ns=t,
            moved_bytes=payload_bytes(program),
            events=net.engine.events_processed,
            wallclock_s=net.engine.wallclock_seconds(),
            per_rank_done_ns=[ex.done_at[r]
                              for r in range(program.num_ranks)],
            fidelity=self.fidelity)
