"""Analytic backend: closed-form collective estimators (paper §2.1, §4.7).

The lowest-cost fidelity tier, used at pod scale (256+ chips) where event
simulation of every chunk is unnecessary: when the program's collective
has a textbook closed form, time comes straight from the
``collective_time_*`` estimators in :mod:`repro.core.network.simple`
(zero simulation events).  Unrecognized collectives fall back to running
the shared :class:`~repro.core.backends.interpreter.ProgramInterpreter`
over a contention-free alpha-beta transport, so *any* MSCCL++ program
still gets an answer at this tier.
"""

from __future__ import annotations

import time as _wallclock
from typing import List, Optional, Tuple

from ..mscclpp import Program
from ..network.simple import best_collective_time
from .base import CollectiveResult, payload_bytes
from .interpreter import AnalyticTransport, ProgramInterpreter

#: collective kind -> buffer holding the estimator's *global* payload
_GLOBAL_BUFFER = {
    "all_reduce": "output",
    "all_gather": "output",
    "reduce_scatter": "input",
    "all_to_all": "input",
}


class AnalyticBackend:
    """Closed-form fidelity tier (alpha-beta, no contention)."""

    fidelity = "analytic"

    def __init__(self, infra=None, link_GBps: Optional[float] = None,
                 link_lat_ns: Optional[float] = None,
                 local_GBps: float = 1099.5, reduce_GBps: float = 4398.0):
        self.infra = infra
        self.link_GBps = link_GBps
        self.link_lat_ns = link_lat_ns
        self.local_GBps = local_GBps
        self.reduce_GBps = reduce_GBps

    def link_params(self) -> Tuple[float, float]:
        """(bandwidth_GBps, latency_ns) of the scale-up fabric."""
        bw, lat = self.link_GBps, self.link_lat_ns
        if (bw is None or lat is None) and self.infra is not None:
            lats = [lt.latency_ns for lt in self.infra.links.values()]
            bws = [lt.bandwidth_GBps for lt in self.infra.links.values()]
            if bw is None and bws:
                bw = min(bws)
            if lat is None and lats:
                lat = max(lats)
        return (bw if bw is not None else 34.36 * 8,
                lat if lat is not None else 1000.0)

    def run(self, program: Program,
            rank_delay_ns: Optional[List[float]] = None,
            until_ns: float = 5e10) -> CollectiveResult:
        wall0 = _wallclock.perf_counter()
        bw, lat = self.link_params()
        n = program.num_ranks
        buf = _GLOBAL_BUFFER.get(program.collective)
        delays = list(rank_delay_ns) if rank_delay_ns else [0.0] * n
        skew = max(delays)
        # The closed form answers "every rank finishes at t" — only true
        # when every rank launches together.  A *uniform* delay d merely
        # shifts the collective (t + d keeps every percentile honest), but
        # non-uniform skew changes the critical path per rank, so those
        # runs must go through the interpreter or per_rank_done_ns would
        # silently flatten every tail percentile to p50.
        uniform = len(set(delays)) == 1
        if buf is not None and buf in program.buffers and uniform:
            size = program.buffers[buf]
            t, algo = best_collective_time(program.collective, size, n,
                                           bw, lat)
            t += delays[0]
            return CollectiveResult(
                program=f"{program.name}.analytic[{algo}]",
                collective=program.collective, nranks=n, time_ns=t,
                moved_bytes=payload_bytes(program), events=0,
                wallclock_s=_wallclock.perf_counter() - wall0,
                per_rank_done_ns=[t] * n, fidelity=self.fidelity)
        # fallback: interpret the actual program over alpha-beta delays
        net = AnalyticTransport(alpha_ns=lat, beta_GBps=bw)
        ex = ProgramInterpreter(program, net, self.local_GBps,
                                self.reduce_GBps, rank_delay_ns)
        net.engine.run(until_ns + skew)
        if len(ex.done_at) != n:
            missing = [r for r in range(n) if r not in ex.done_at]
            raise RuntimeError(f"analytic sim incomplete: ranks {missing}")
        t = max(ex.done_at.values())
        return CollectiveResult(
            program=f"{program.name}.analytic", collective=program.collective,
            nranks=n, time_ns=t, moved_bytes=payload_bytes(program),
            events=net.engine.events_processed,
            wallclock_s=_wallclock.perf_counter() - wall0,
            per_rank_done_ns=[ex.done_at[r] for r in range(n)],
            fidelity=self.fidelity)
