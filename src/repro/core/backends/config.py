"""Typed per-tier experiment configs (the ``SimConfig`` seam).

One dataclass per fidelity tier holds everything needed to *construct* that
tier's backend — plus the trace-execution knobs the workload seam consumes
(how collective nodes lower, how compute nodes cost).  ``simulate`` takes
one of these via ``config=``:

    simulate(workload, infra, config=FineConfig(noc=NocConfig(...)))
    simulate(workload, infra, fidelity="coarse",
             config=CoarseConfig(link_GBps=400.0))

Unknown keys fail at construction time with Python's normal dataclass
``TypeError`` — no more kwargs silently falling through to ``backend.run``
and exploding there.  The legacy flat-kwargs spelling
(``simulate(prog, infra, noc=...)``) still works through a deprecation
shim: :func:`split_legacy_kwargs` partitions the flat keywords into config
fields and per-run arguments and rejects anything else immediately, naming
the valid keys.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, fields
from typing import Dict, FrozenSet, Optional, Protocol, runtime_checkable

from ..cluster import NocConfig
from ..gpu_model import GpuConfig
from ..network.simple import SimpleTopology


def _config_hash(cfg) -> str:
    """Canonical sha256 of a tier config: fidelity tag + every dataclass
    field (nested ``NocConfig``/``GpuConfig``/``SimpleTopology`` dataclasses
    canonicalize structurally).  The sweep cache's config key: two configs
    hash equal iff they construct identically-behaving backends *and*
    lower traces identically."""
    from ..canonical import content_hash
    return content_hash({"kind": type(cfg).__qualname__,
                         "fidelity": cfg.fidelity,
                         "fields": {f.name: getattr(cfg, f.name)
                                    for f in fields(cfg)}})


@runtime_checkable
class SimConfig(Protocol):
    """What ``simulate`` needs from a tier config: its fidelity name and a
    backend factory.  The three dataclasses below implement it; studies can
    supply their own (e.g. a frozen sweep-point config) as long as
    ``make_backend`` returns an object satisfying
    :class:`~repro.core.backends.base.SimBackend`."""

    fidelity: str

    def make_backend(self, infra=None):
        ...


@dataclass
class FineConfig:
    """Load-Store tier: detailed Cluster construction + trace lowering."""

    # backend construction
    noc: Optional[NocConfig] = None
    gpu_config: Optional[GpuConfig] = None
    topology: str = "switch"
    bulk_emission: Optional[str] = None
    # trace execution (how ExecutionTrace nodes lower onto the Cluster)
    comp_workgroups: int = 8
    coll_workgroups: int = 4
    flops_per_cu_cycle: float = 2048.0
    protocol: str = "put"

    fidelity = "fine"

    def content_hash(self) -> str:
        return _config_hash(self)

    def make_backend(self, infra=None):
        from .fine import FineBackend
        return FineBackend(infra=infra, noc=self.noc,
                           gpu_config=self.gpu_config, topology=self.topology,
                           bulk_emission=self.bulk_emission)


@dataclass
class CoarseConfig:
    """Chunk tier: alpha-beta SimpleNetwork + roofline compute nodes."""

    # backend construction
    topo: Optional[SimpleTopology] = None
    link_GBps: float = 34.36 * 8
    link_lat_ns: float = 1000.0
    local_GBps: float = 1099.5
    reduce_GBps: float = 4398.0
    # trace execution
    coll_workgroups: int = 4
    protocol: str = "put"
    #: roofline compute rate of one rank (flops per simulated ns); the
    #: default matches the fine tier's defaults (8 comp workgroups x 2048
    #: flops per CU-cycle at 1 GHz)
    flops_per_ns: float = 16384.0

    fidelity = "coarse"

    def content_hash(self) -> str:
        return _config_hash(self)

    def make_backend(self, infra=None):
        from .coarse import CoarseBackend
        return CoarseBackend(infra=infra, topo=self.topo,
                             link_GBps=self.link_GBps,
                             link_lat_ns=self.link_lat_ns,
                             local_GBps=self.local_GBps,
                             reduce_GBps=self.reduce_GBps)


@dataclass
class AnalyticConfig:
    """Closed-form tier: alpha-beta estimators, contention-free fallback."""

    link_GBps: Optional[float] = None
    link_lat_ns: Optional[float] = None
    local_GBps: float = 1099.5
    reduce_GBps: float = 4398.0
    # trace execution
    coll_workgroups: int = 4
    protocol: str = "put"
    flops_per_ns: float = 16384.0

    fidelity = "analytic"

    def content_hash(self) -> str:
        return _config_hash(self)

    def make_backend(self, infra=None):
        from .analytic import AnalyticBackend
        return AnalyticBackend(infra=infra, link_GBps=self.link_GBps,
                               link_lat_ns=self.link_lat_ns,
                               local_GBps=self.local_GBps,
                               reduce_GBps=self.reduce_GBps)


#: fidelity name -> config dataclass
CONFIGS: Dict[str, type] = {
    "fine": FineConfig,
    "coarse": CoarseConfig,
    "analytic": AnalyticConfig,
}

#: per-run keyword arguments accepted by ``backend.run`` for a Program
PROGRAM_RUN_KW: Dict[str, FrozenSet[str]] = {
    "fine": frozenset({"cluster", "unroll", "rank_delay_ns", "until_ns"}),
    "coarse": frozenset({"rank_delay_ns", "until_ns"}),
    "analytic": frozenset({"rank_delay_ns", "until_ns"}),
}

#: per-run keyword arguments accepted by the trace path (any tier)
TRACE_RUN_KW: FrozenSet[str] = frozenset({"until_ns"})


def config_field_names(fidelity: str) -> FrozenSet[str]:
    return frozenset(f.name for f in fields(CONFIGS[fidelity]))


def split_legacy_kwargs(fidelity: str, kwargs: dict, run_keys: FrozenSet[str],
                        entry: str = "simulate()") -> tuple:
    """Partition legacy flat ``entry`` kwargs into (config, run kwargs).

    Keys matching the tier's config dataclass build the config (with a
    DeprecationWarning pointing at ``config=``); keys in ``run_keys`` pass
    through to the run; anything else raises immediately with the full
    valid-key list — instead of the old behavior of exploding as an
    unexpected-keyword error deep inside ``backend.run``.
    """
    cls = CONFIGS[fidelity]
    names = config_field_names(fidelity)
    cfg_kw, run_kw, unknown = {}, {}, []
    for k, v in kwargs.items():
        if k in names:
            cfg_kw[k] = v
        elif k in run_keys:
            run_kw[k] = v
        else:
            unknown.append(k)
    if unknown:
        valid = sorted(names | run_keys)
        raise TypeError(
            f"{entry} got unknown keyword(s) {sorted(unknown)} for "
            f"fidelity {fidelity!r}; valid keys: {valid} "
            f"(or pass config={cls.__name__}(...))")
    if cfg_kw:
        warnings.warn(
            f"passing backend-construction kwargs {sorted(cfg_kw)} to "
            f"{entry} is deprecated; use config="
            f"{cls.__name__}({', '.join(k + '=...' for k in sorted(cfg_kw))})",
            DeprecationWarning, stacklevel=3)
    return cls(**cfg_kw), run_kw
