"""Fine-grained backend: Load-Store granularity on a detailed Cluster.

Paper §4.2-§4.4: the MSCCL++ program is lowered into per-rank Load-Store
kernels and executed over the NoC-level fabric (CU contention, cache-line
Wavefront Requests, per-link arbitration).  When constructed from an
InfraGraph :class:`Infrastructure`, the cluster's scale-up wiring comes
from the graph's fabric edges via :func:`repro.core.infragraph.translate.
to_cluster`.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from ..cluster import Cluster, NocConfig
from ..gpu_model import GpuConfig
from ..mscclpp import Program, lower_program
from .base import CollectiveResult, payload_bytes


class FineBackend:
    """ASTRA-sim 3.0 fidelity tier."""

    fidelity = "fine"

    def __init__(self, infra=None, noc: Optional[NocConfig] = None,
                 gpu_config: Optional[GpuConfig] = None,
                 topology: str = "switch",
                 bulk_emission: Optional[str] = None):
        self.infra = infra
        self.noc = noc
        self.gpu_config = gpu_config
        self.topology = topology
        if bulk_emission is not None:
            # convenience override of NocConfig.bulk_emission ("on"|"off");
            # copy so the caller's config object is not mutated
            import dataclasses
            self.noc = dataclasses.replace(noc or NocConfig(),
                                           bulk_emission=bulk_emission)

    def make_cluster(self, num_ranks: int) -> Cluster:
        if self.infra is not None:
            from ..infragraph.translate import to_cluster
            cluster = to_cluster(self.infra, noc=self.noc,
                                 gpu_config=self.gpu_config)
            if len(cluster.gpus) < num_ranks:
                raise ValueError(
                    f"infrastructure has {len(cluster.gpus)} endpoints but "
                    f"the program needs {num_ranks} ranks")
            return cluster
        return Cluster(num_ranks, gpu_config=self.gpu_config, noc=self.noc,
                       topology=self.topology)

    def run(self, program: Program, cluster: Optional[Cluster] = None,
            unroll: Optional[int] = None,
            rank_delay_ns: Optional[List[float]] = None,
            until_ns: float = 5e10) -> CollectiveResult:
        """Run a collective program at Load-Store granularity end to end.

        ``rank_delay_ns`` injects per-rank kernel-launch skew (straggler
        study).
        """
        if cluster is None:
            cluster = self.make_cluster(program.num_ranks)
        kernels = lower_program(program, unroll=unroll)
        done_at: Dict[int, float] = {}

        def on_done(kernel, t, rank=None):
            done_at[kernel.gpu] = t

        for k in kernels:
            k.on_done = on_done
            delay = rank_delay_ns[k.gpu] if rank_delay_ns else 0.0
            if delay > 0:
                cluster.dispatch_at(delay, k)
            else:
                cluster.dispatch(k)
        # every dispatch above either happened or is an engine event the
        # ledger can see: promise that no callback springs new work on an
        # idle CU (lets channel clocks treat idle CUs as quiet)
        cluster.seal()
        cluster.run(until_ns)
        if len(done_at) != program.num_ranks:
            missing = [r for r in range(program.num_ranks)
                       if r not in done_at]
            raise RuntimeError(
                f"collective did not complete: ranks {missing} still running "
                f"at {cluster.engine.now} ns (deadlock or until_ns too small)")
        t = max(done_at.values())
        return CollectiveResult(
            program=program.name, collective=program.collective,
            nranks=program.num_ranks, time_ns=t,
            moved_bytes=payload_bytes(program),
            events=cluster.engine.events_processed,
            wallclock_s=cluster.engine.wallclock_seconds(),
            requests=cluster.request_count,
            per_rank_done_ns=[done_at[r] for r in range(program.num_ranks)],
            fidelity=self.fidelity)
