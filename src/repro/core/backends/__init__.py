"""Pluggable simulation backends: one *workload*, one infrastructure, three
fidelity tiers (paper §2.1, §4).

A workload is either a single MSCCL++ :class:`~repro.core.mscclpp.Program`
or a Chakra-style :class:`~repro.core.chakra.ExecutionTrace` (per-rank DAGs
of compute and communication kernels — the unit real DSE studies sweep).
Both run through one typed entry point, at any tier:

    from repro.core.backends import simulate, FineConfig
    from repro.core.chakra import ExecutionTrace
    from repro.core.infragraph import single_tier_fabric

    et = ExecutionTrace(num_ranks=8)
    fwd = {r: et.comp(r, f"fwd.r{r}", flops=2e8) for r in range(8)}
    et.coll(0, "all_reduce", 1 << 20, "ring",
            deps_by_rank={r: [fwd[r]] for r in range(8)})

    infra = single_tier_fabric(8)
    fine = simulate(et, infra, fidelity="fine")       # Load-Store Cluster
    coarse = simulate(et, infra, fidelity="coarse")   # chunk alpha-beta
    quick = simulate(et, infra, fidelity="analytic")  # contention-free

Results derive from one :class:`SimResult` base (``time_ns``, ``events``,
``wallclock_s``, ``fidelity``, ``per_rank_done_ns``): programs return
:class:`CollectiveResult`, traces return
:class:`~repro.core.chakra.TraceResult` — sweep scripts handle both
uniformly.  Program-interpretation semantics live in exactly one place
(:mod:`.interpreter`), shared by the coarse and analytic tiers; trace
dependency scheduling lives in exactly one place (:mod:`.workload`),
shared by all three.

Backend construction is configured with a typed per-tier dataclass
(:class:`FineConfig` / :class:`CoarseConfig` / :class:`AnalyticConfig`)
passed as ``config=``; per-run arguments (``until_ns``, ``rank_delay_ns``,
``unroll``, ``cluster``) stay keywords.  Unknown keywords raise
immediately with the valid-key list.

Migration note (deprecated flat kwargs)
---------------------------------------
``simulate(prog, infra, noc=..., link_GBps=...)`` — backend-construction
knobs as flat keywords — still works via a deprecation shim that splits
them into the tier's config dataclass (and warns).  New code should write
``simulate(prog, infra, config=FineConfig(noc=...))``.
"""

from __future__ import annotations

from typing import Dict, Optional, Union

from .analytic import AnalyticBackend
from .base import CollectiveResult, SimBackend, SimResult, payload_bytes
from .coarse import CoarseBackend
from .config import (CONFIGS, PROGRAM_RUN_KW, TRACE_RUN_KW, AnalyticConfig,
                     CoarseConfig, FineConfig, SimConfig, split_legacy_kwargs)
from .fine import FineBackend
from .interpreter import AnalyticTransport, ProgramInterpreter
from .workload import DagScheduler, is_trace, run_trace

#: fidelity name -> backend class
FIDELITIES: Dict[str, type] = {
    "fine": FineBackend,
    "coarse": CoarseBackend,
    "analytic": AnalyticBackend,
}


def make_backend(fidelity: str = "fine", infra=None,
                 config: Optional[SimConfig] = None, **kwargs) -> SimBackend:
    """Construct a backend for a fidelity tier from an Infrastructure.

    ``config`` is a typed tier config; flat ``kwargs`` (legacy) are config
    dataclass fields and raise on anything unknown.
    """
    _check_fidelity(fidelity)
    if config is None:
        config, extra = split_legacy_kwargs(fidelity, kwargs, frozenset(),
                                            entry="make_backend()")
    elif kwargs:
        raise TypeError(f"make_backend() got both config= and flat kwargs "
                        f"{sorted(kwargs)}; pass one or the other")
    return config.make_backend(infra)


def _check_fidelity(fidelity: str) -> None:
    if fidelity not in FIDELITIES:
        raise ValueError(f"unknown fidelity {fidelity!r}; "
                         f"choose from {sorted(FIDELITIES)}")


def _run_check(workload, infra, config, check: str) -> None:
    """Pre-simulation static verification (``check="warn"|"error"``)."""
    if check not in ("off", "warn", "error"):
        raise ValueError(f"check={check!r}; choose 'off', 'warn' or 'error'")
    import warnings

    from ..check import CheckWarning, check_workload
    report = check_workload(
        workload, infra,
        workgroups=getattr(config, "coll_workgroups", 4),
        protocol=getattr(config, "protocol", "put"))
    if check == "error":
        report.raise_if_errors()
    if not report.clean:
        warnings.warn(
            f"static check found issues (simulate(..., check='off') to "
            f"silence, check='error' to fail fast):\n{report.format()}",
            CheckWarning, stacklevel=3)


def simulate(workload, infra=None, fidelity: Optional[str] = None,
             config: Optional[SimConfig] = None, check: str = "warn",
             **kwargs) -> SimResult:
    """Simulate ``workload`` over ``infra`` at the chosen fidelity tier.

    ``workload`` is an MSCCL++ :class:`~repro.core.mscclpp.Program` (one
    collective) or an :class:`~repro.core.chakra.ExecutionTrace` (a
    multi-kernel DAG).  ``infra`` is an InfraGraph
    :class:`~repro.core.infragraph.graph.Infrastructure`, or None for a
    default single-switch scale-up fabric sized to the workload.

    The tier comes from ``fidelity=`` ("fine" | "coarse" | "analytic"),
    or from ``config``'s tier when only ``config=`` is given (default:
    fine).  Remaining keywords are per-run arguments — ``until_ns``, plus
    ``rank_delay_ns`` / ``unroll`` / ``cluster`` for programs; anything
    else raises with the valid-key list (legacy backend-construction
    keywords are split into the tier config by a deprecation shim).

    ``check`` runs the static verifier (:mod:`repro.core.check`) before
    any event is simulated: ``"warn"`` (default) emits a
    :class:`~repro.core.check.CheckWarning` describing every finding,
    ``"error"`` raises :class:`~repro.core.check.CheckError` on
    error-severity findings (deadlocks, races, out-of-bounds transfers),
    ``"off"`` skips verification entirely.  Program reports are memoized,
    so sweeps re-simulating the same generated workload pay once.
    """
    if config is not None:
        cfg_fid = getattr(config, "fidelity", None)
        if fidelity is None:
            fidelity = cfg_fid
        elif cfg_fid is not None and cfg_fid != fidelity:
            raise ValueError(
                f"fidelity={fidelity!r} conflicts with "
                f"config.fidelity={cfg_fid!r}")
    if fidelity is None:
        fidelity = "fine"
    _check_fidelity(fidelity)
    trace = is_trace(workload)
    run_keys = TRACE_RUN_KW if trace else PROGRAM_RUN_KW[fidelity]
    if config is None:
        config, run_kw = split_legacy_kwargs(fidelity, kwargs, run_keys)
    else:
        unknown = set(kwargs) - run_keys
        if unknown:
            raise TypeError(
                f"simulate() got unknown keyword(s) {sorted(unknown)} for "
                f"a {'trace' if trace else 'program'} run at fidelity "
                f"{fidelity!r}; valid run keys: {sorted(run_keys)}")
        run_kw = kwargs
    if check != "off":
        _run_check(workload, infra, config, check)
    backend = config.make_backend(infra)
    if trace:
        workload.reset_runtime()
        return run_trace(workload, backend, config, **run_kw)
    return backend.run(workload, **run_kw)


__all__ = [
    "AnalyticBackend", "AnalyticConfig", "AnalyticTransport", "CoarseBackend",
    "CoarseConfig", "CollectiveResult", "DagScheduler", "FIDELITIES",
    "FineBackend", "FineConfig", "ProgramInterpreter", "SimBackend",
    "SimConfig", "SimResult", "is_trace", "make_backend", "payload_bytes",
    "run_trace", "simulate",
]
