"""Pluggable simulation backends: one program, one infrastructure, three
fidelity tiers (paper §4).

    from repro.core.backends import simulate
    from repro.core.infragraph import single_tier_fabric
    from repro.core.collectives import ring_all_reduce

    prog = ring_all_reduce(8, 1 << 20, 2, "put")
    infra = single_tier_fabric(8)
    fine = simulate(prog, infra, fidelity="fine")       # Load-Store Cluster
    coarse = simulate(prog, infra, fidelity="coarse")   # chunk alpha-beta
    quick = simulate(prog, infra, fidelity="analytic")  # closed form

The same MSCCL++ program and the same InfraGraph description drive every
tier; results come back as a uniform :class:`CollectiveResult`, so studies
can trade fidelity for speed without touching experiment code.  The
program-interpretation semantics live in exactly one place
(:mod:`.interpreter`), shared by the coarse and analytic tiers.
"""

from __future__ import annotations

from typing import Dict, Optional, Type

from ..mscclpp import Program
from .analytic import AnalyticBackend
from .base import CollectiveResult, SimBackend, payload_bytes
from .coarse import CoarseBackend
from .fine import FineBackend
from .interpreter import AnalyticTransport, ProgramInterpreter

#: fidelity name -> backend class
FIDELITIES: Dict[str, type] = {
    "fine": FineBackend,
    "coarse": CoarseBackend,
    "analytic": AnalyticBackend,
}

#: constructor keyword names accepted per backend (everything else is
#: forwarded to ``backend.run``)
_CTOR_KW = {
    "fine": ("noc", "gpu_config", "topology"),
    "coarse": ("topo", "link_GBps", "link_lat_ns", "local_GBps",
               "reduce_GBps"),
    "analytic": ("link_GBps", "link_lat_ns", "local_GBps", "reduce_GBps"),
}


def make_backend(fidelity: str = "fine", infra=None, **kwargs) -> SimBackend:
    """Construct a backend for a fidelity tier from an Infrastructure."""
    try:
        cls = FIDELITIES[fidelity]
    except KeyError:
        raise ValueError(f"unknown fidelity {fidelity!r}; "
                         f"choose from {sorted(FIDELITIES)}") from None
    return cls(infra=infra, **kwargs)


def simulate(program: Program, infra=None, fidelity: str = "fine",
             **kwargs) -> CollectiveResult:
    """Simulate ``program`` over ``infra`` at the chosen fidelity tier.

    ``infra`` is an InfraGraph :class:`Infrastructure` (or None for a
    default single-switch scale-up fabric sized to the program).  Keyword
    arguments are split between backend construction (e.g. ``noc=`` for
    fine, ``link_GBps=`` for coarse/analytic) and the run itself (e.g.
    ``rank_delay_ns=``, ``until_ns=``, ``unroll=`` for fine).
    """
    ctor_names = _CTOR_KW[fidelity] if fidelity in _CTOR_KW else ()
    ctor = {k: kwargs.pop(k) for k in list(kwargs) if k in ctor_names}
    backend = make_backend(fidelity, infra, **ctor)
    return backend.run(program, **kwargs)


__all__ = [
    "AnalyticBackend", "AnalyticTransport", "CoarseBackend",
    "CollectiveResult", "FIDELITIES", "FineBackend", "ProgramInterpreter",
    "SimBackend", "make_backend", "payload_bytes", "simulate",
]
