"""The Workload seam: traces AND programs through one ``simulate()``.

The paper's headline flow (§2.1, §4.3) starts from a Chakra execution
trace, not a single collective: per-rank DAGs of compute and communication
kernels.  This module makes that workload first-class at *every* fidelity
tier:

* :class:`DagScheduler` — the tier-agnostic dependency tracker.  One
  implementation dispatches per-rank kernels as their dependencies
  resolve, shared by the fine tier's semaphore-accurate
  :class:`~repro.core.chakra.TraceExecutor` and the cheap tiers below.
* :func:`run_trace` — runs an :class:`~repro.core.chakra.ExecutionTrace`
  on a constructed backend.  The fine tier keeps today's path bit-exactly:
  an *unsealed* detailed Cluster (trace dispatches chain off ``on_done``
  callbacks mid-run, which ``Cluster.seal()`` would forbid) driven by
  ``TraceExecutor``.  Coarse and analytic execute each collective node
  through the shared :class:`~repro.core.backends.interpreter.
  ProgramInterpreter` (deferred per-rank start) over their usual
  transports, and cost compute nodes with a roofline model on per-rank
  timelines — opening multi-collective workloads (training steps, decode
  loops, overlap studies) to the cheap tiers.

Nothing here imports :mod:`repro.core.chakra` at module load — the trace
types are resolved lazily so ``chakra`` itself can build on this module's
scheduler without an import cycle.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from .interpreter import AnalyticTransport, ProgramInterpreter


def is_trace(workload) -> bool:
    """True iff ``workload`` is an ExecutionTrace (vs an MSCCL++ Program)."""
    from ..chakra import ExecutionTrace
    return isinstance(workload, ExecutionTrace)


class DagScheduler:
    """Dependency bookkeeping for one ExecutionTrace, tier-agnostic.

    Owns nothing about *how* a node executes — callers launch the nodes
    this scheduler hands them (``roots`` first, then whatever each
    ``complete`` call unblocks) and stamp start/end times on the nodes.
    Iteration order is the trace's node order, so two executors sharing a
    trace launch ready nodes in the same deterministic sequence.
    """

    def __init__(self, trace):
        trace.validate()
        self.trace = trace
        self.by_id = {n.nid: n for n in trace.nodes}
        self.pending_deps = {n.nid: len(n.deps) for n in trace.nodes}
        self.dependents: Dict[int, List[int]] = {}
        for n in trace.nodes:
            for d in n.deps:
                self.dependents.setdefault(d, []).append(n.nid)
        self.unfinished = len(trace.nodes)

    def roots(self) -> list:
        """Nodes with no outstanding dependencies, in trace order."""
        return [n for n in self.trace.nodes if self.pending_deps[n.nid] == 0]

    def complete(self, nid: int, t: float) -> list:
        """Mark ``nid`` finished at ``t``; return newly-ready nodes."""
        self.by_id[nid].end_ns = t
        self.unfinished -= 1
        ready = []
        for dep_id in self.dependents.get(nid, []):
            self.pending_deps[dep_id] -= 1
            if self.pending_deps[dep_id] == 0:
                ready.append(self.by_id[dep_id])
        return ready

    def incomplete_ids(self, limit: int = 10) -> list:
        return [n.nid for n in self.trace.nodes if n.end_ns < 0][:limit]

    def result(self, engine, fidelity: str):
        """Assemble the TraceResult after the engine drained (shared by the
        fine TraceExecutor and the cheap-tier executor); raises if any node
        never completed."""
        if self.unfinished:
            raise RuntimeError(
                f"trace incomplete at {fidelity} tier, nodes left: "
                f"{self.incomplete_ids()}")
        from ..chakra import TraceResult
        per_rank = [0.0] * self.trace.num_ranks
        for n in self.trace.nodes:
            per_rank[n.rank] = max(per_rank[n.rank], n.end_ns)
        return TraceResult(
            time_ns=max(per_rank), events=engine.events_processed,
            wallclock_s=engine.wallclock_seconds(), fidelity=fidelity,
            per_rank_done_ns=per_rank,
            node_times={n.nid: (n.start_ns, n.end_ns)
                        for n in self.trace.nodes})


class _TierTraceExecutor:
    """ExecutionTrace at chunk/analytic granularity.

    Collective nodes run through one deferred-start
    :class:`ProgramInterpreter` per ``coll_id`` — each rank's half released
    when *that rank's* trace dependencies resolve, so launch skew
    propagates through the interpreter's semaphores just like the fine
    tier.  All interpreters share one engine and one transport, so
    overlapping collectives contend for the same links (coarse) or overlap
    freely (analytic).  Compute nodes cost ``max(flops/rate, bytes/bw)``
    (roofline) on a serialized per-rank compute timeline, overlapping
    network activity — the cheap-tier analogue of comp and coll kernels
    sharing CUs.
    """

    def __init__(self, trace, backend, config):
        self.trace = trace
        self.cfg = config
        self.fidelity = backend.fidelity
        n = trace.num_ranks
        if backend.fidelity == "coarse":
            from ..network.simple import SimpleNetwork
            topo = backend.make_topology(n)
            if topo.num_gpus < n:
                raise ValueError(
                    f"topology has {topo.num_gpus} endpoints but the trace "
                    f"needs {n} ranks")
            self.net = SimpleNetwork(topo)
        else:                          # analytic: contention-free alpha-beta
            bw, lat = backend.link_params()
            self.net = AnalyticTransport(alpha_ns=lat, beta_GBps=bw)
        self.local_GBps = backend.local_GBps
        self.reduce_GBps = backend.reduce_GBps
        self.engine = self.net.engine
        self.dag = DagScheduler(trace)
        self.comp_free_ps = [0] * n   # per-rank compute timeline (integer ps)
        self._interps: Dict[int, ProgramInterpreter] = {}
        self._coll_nid: Dict[Tuple[int, int], int] = {}

    # ---------------------------------------------------------------- running
    def run(self, until_ns: float = 1e12):
        for node in self.dag.roots():
            self._launch(node)
        self.engine.run(until_ns)
        return self.dag.result(self.engine, self.fidelity)

    def _launch(self, node) -> None:
        # arrival release: hold the node past its resolved deps until
        # start_after_ns (request arrival jitter), then dispatch for real
        release_ps = int(round(node.start_after_ns * 1000.0))
        if release_ps > self.engine.now_ps:
            self.engine.schedule_abs_ps(release_ps, self._dispatch, node)
            return
        self._dispatch(node)

    def _dispatch(self, node) -> None:
        if node.kind == "comp":
            self._launch_comp(node)
        else:
            self._launch_coll(node)

    def _complete(self, nid: int) -> None:
        for nxt in self.dag.complete(nid, self.engine.now):
            self._launch(nxt)

    # ---------------------------------------------------------------- compute
    def _launch_comp(self, node) -> None:
        # integer-ps timeline so stamped starts line up exactly with the
        # engine ticks completion events fire on
        r = node.rank
        start_ps = max(self.engine.now_ps, self.comp_free_ps[r])
        node.start_ns = start_ps / 1000.0  # actual roofline start, not launch
        end_ps = start_ps + int(round(self._roofline_ns(node) * 1000))
        self.comp_free_ps[r] = end_ps
        self.engine.schedule_abs_ps(end_ps, self._complete, node.nid)

    def _roofline_ns(self, node) -> float:
        cfg = self.cfg
        t_flop = node.flops / cfg.flops_per_ns if cfg.flops_per_ns > 0 else 0.0
        t_mem = (node.bytes_moved / self.local_GBps
                 if self.local_GBps > 0 else 0.0)
        return max(t_flop, t_mem, 1.0)         # >= one CU cycle, like fine

    # ------------------------------------------------------------ collectives
    def _launch_coll(self, node) -> None:
        cid = node.coll_id
        key = (cid, node.rank)
        if key in self._coll_nid:
            # validate()/check_trace (TR-DUP-COLL) catch this statically;
            # raising here too keeps completion routing from silently
            # mis-wiring if a caller bypassed validation
            raise RuntimeError(
                f"rank {node.rank} appears twice in collective {cid} "
                f"(nodes {self._coll_nid[key]} and {node.nid}); duplicate "
                f"(coll_id, rank) halves corrupt completion routing "
                f"[TR-DUP-COLL]")
        interp = self._interps.get(cid)
        if interp is None:
            from ..chakra import collective_program
            prog = collective_program(node, self.trace.num_ranks,
                                      self.cfg.coll_workgroups,
                                      self.cfg.protocol)
            interp = ProgramInterpreter(
                prog, self.net, self.local_GBps, self.reduce_GBps,
                deferred=True,
                on_rank_done=lambda r, t, cid=cid: self._coll_done(cid, r))
            self._interps[cid] = interp
        self._coll_nid[key] = node.nid
        # stamp at the moment the rank's half is actually released into the
        # interpreter (after any arrival hold), not when the node was first
        # handed to _launch — node_times-derived latencies stay honest
        node.start_ns = self.engine.now
        interp.start_rank(node.rank)

    def _coll_done(self, cid: int, rank: int) -> None:
        self._complete(self._coll_nid[(cid, rank)])


def run_trace(trace, backend, config, until_ns: float = 1e12):
    """Run an ExecutionTrace on a constructed backend (any tier)."""
    if backend.fidelity == "fine":
        from ..chakra import TraceExecutor
        cluster = backend.make_cluster(trace.num_ranks)
        # NOTE: the cluster stays *unsealed* — trace dispatches chain off
        # kernel on_done callbacks mid-run (see Cluster.seal()).
        ex = TraceExecutor(trace, cluster,
                           comp_workgroups=config.comp_workgroups,
                           coll_workgroups=config.coll_workgroups,
                           flops_per_cu_cycle=config.flops_per_cu_cycle,
                           protocol=config.protocol)
        return ex.run(until_ns)
    return _TierTraceExecutor(trace, backend, config).run(until_ns)
