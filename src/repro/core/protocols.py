"""Analytical LL vs Simple protocol model (paper §3.2, Fig. 4).

Most CCLs ship two communication protocols:

* **Simple** — uses 100% of link bandwidth but requires synchronization
  before and after the transfer (buffer-ready / completion handshakes);
* **LL (low latency)** — embeds flags in the data (no synchronization) at
  the cost of 50% effective bandwidth.

With the Hockney alpha-beta model the transfer times are::

    T_simple(S) = sync_hops * alpha + S / beta
    T_LL(S)     =             alpha + S / (beta / 2)

so the crossover size is  S* = (sync_hops - 1) * alpha * beta — directly
proportional to the modeled latency.  The paper's point: misestimating
``alpha`` by 10x moves the protocol-choice boundary by 10x, so fine-grained
latency modeling (ASTRA-sim 3.0's GPU model) is a prerequisite for drawing
the right design conclusions.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

GiB = float(1 << 30)


@dataclass
class ProtocolModel:
    alpha_ns: float                 # one-way link latency
    beta_GBps: float                # link bandwidth (bytes/ns)
    sync_hops: int = 3              # latency units paid by Simple's handshake

    def t_simple_ns(self, size: int) -> float:
        return self.sync_hops * self.alpha_ns + size / self.beta_GBps

    def t_ll_ns(self, size: int) -> float:
        return self.alpha_ns + size / (self.beta_GBps / 2)

    def bw_simple_GBps(self, size: int) -> float:
        return size / self.t_simple_ns(size)

    def bw_ll_GBps(self, size: int) -> float:
        return size / self.t_ll_ns(size)

    def crossover_bytes(self) -> float:
        """Size above which Simple beats LL (exact model solution)."""
        return (self.sync_hops - 1) * self.alpha_ns * self.beta_GBps

    def crossover_pow2_bytes(self, lo: int = 1 << 10, hi: int = 1 << 30) -> int:
        """First power-of-two transfer size where Simple outperforms LL
        (how the paper reads Fig. 4 off a discrete sweep)."""
        s = lo
        while s <= hi:
            if self.t_simple_ns(s) < self.t_ll_ns(s):
                return s
            s *= 2
        return -1

    def sweep(self, sizes: List[int]) -> List[Tuple[int, float, float]]:
        return [(s, self.bw_ll_GBps(s), self.bw_simple_GBps(s))
                for s in sizes]
