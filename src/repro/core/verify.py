"""Functional (data-correctness) executor for MSCCL++ programs.

The timing simulator never touches data; this module does the opposite —
it executes a Program's put/get/copy/reduce semantics on numpy buffers,
honoring signal/wait/barrier ordering, under an arbitrary (seedable)
interleaving of (rank, workgroup) cursors.  Used by tests to prove each
collective generator satisfies its postcondition for any schedule.

Buffers are modeled one int64 *per byte* so arbitrary byte offsets work and
reductions never overflow.
"""

from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

import numpy as np

from .mscclpp import Program


class DeadlockError(RuntimeError):
    """The executor found live cursors but none runnable.

    ``blocked`` lists one dict per stuck cursor — ``rank``, ``wg``, ``pc``,
    the blocking ``op`` (wait/barrier), and for waits the semaphore id,
    the ``expected`` count and how many signals ``have`` arrived.
    ``semaphores`` snapshots every ``(rank, sem) -> count``.  The same
    hang is reported *statically* (no execution) by
    :func:`repro.core.check.check_program`.
    """

    def __init__(self, message: str, blocked: Optional[List[dict]] = None,
                 semaphores: Optional[Dict[Tuple[int, int], int]] = None):
        super().__init__(message)
        self.blocked = blocked or []
        self.semaphores = dict(semaphores or {})


def make_inputs(program: Program, seed: int = 0) -> List[np.ndarray]:
    """Deterministic distinct inputs: input_r[i] = hash-ish(r, i)."""
    rng = np.random.default_rng(seed)
    size = program.buffers["input"]
    return [rng.integers(1, 1000, size=size).astype(np.int64)
            for _ in range(program.num_ranks)]


def execute(program: Program, inputs: Optional[List[np.ndarray]] = None,
            seed: int = 0, max_steps: int = 10_000_000) -> List[np.ndarray]:
    """Run the program to completion; returns each rank's output buffer."""
    program.validate()
    n = program.num_ranks
    if inputs is None:
        inputs = make_inputs(program, seed)
    bufs: List[Dict[str, np.ndarray]] = []
    for r in range(n):
        d = {name: np.zeros(size, dtype=np.int64)
             for name, size in program.buffers.items()}
        d["input"][:] = inputs[r]
        bufs.append(d)
    sems: Dict[Tuple[int, int], int] = {}
    # cursor per (rank, wg)
    cursors: List[Tuple[int, int, int]] = []   # (rank, wg, pc) — pc mutable
    pcs: Dict[Tuple[int, int], int] = {}
    for r in range(n):
        for w in range(len(program.gpus[r])):
            pcs[(r, w)] = 0
    rng = random.Random(seed)

    def ready(r: int, w: int) -> bool:
        pc = pcs[(r, w)]
        ops = program.gpus[r][w]
        if pc >= len(ops):
            return False
        o = ops[pc]
        if o.op == "wait":
            return sems.get((r, o.sem), 0) >= o.expected
        if o.op == "barrier":
            # all workgroups of this rank must be AT a barrier
            return all(
                pcs[(r, w2)] >= len(program.gpus[r][w2]) or
                program.gpus[r][w2][pcs[(r, w2)]].op == "barrier"
                for w2 in range(len(program.gpus[r])))
        return True

    def step(r: int, w: int) -> None:
        pc = pcs[(r, w)]
        o = program.gpus[r][w][pc]
        if o.op == "put":
            src = bufs[r][o.src_buf][o.src_off:o.src_off + o.size]
            bufs[o.remote_rank][o.dst_buf][o.dst_off:o.dst_off + o.size] = src
        elif o.op == "get":
            src = bufs[o.remote_rank][o.src_buf][o.src_off:o.src_off + o.size]
            bufs[r][o.dst_buf][o.dst_off:o.dst_off + o.size] = src
        elif o.op == "copy":
            src = bufs[r][o.src_buf][o.src_off:o.src_off + o.size].copy()
            bufs[r][o.dst_buf][o.dst_off:o.dst_off + o.size] = src
        elif o.op == "reduce":
            acc = np.zeros(o.size, dtype=np.int64)
            for (buf, off, rk) in o.srcs or []:
                owner = rk if rk >= 0 else r
                acc += bufs[owner][buf][off:off + o.size]
            bufs[r][o.dst_buf][o.dst_off:o.dst_off + o.size] = acc
        elif o.op == "signal":
            key = (o.remote_rank, o.sem)
            sems[key] = sems.get(key, 0) + 1
        elif o.op == "barrier":
            # advance every workgroup of this rank past its barrier
            for w2 in range(len(program.gpus[r])):
                pc2 = pcs[(r, w2)]
                if pc2 < len(program.gpus[r][w2]) and \
                        program.gpus[r][w2][pc2].op == "barrier":
                    pcs[(r, w2)] = pc2 + 1
            return
        # wait/nop/flush: pure ordering, nothing to do
        pcs[(r, w)] = pc + 1

    all_cursors = [(r, w) for r in range(n)
                   for w in range(len(program.gpus[r]))]
    steps = 0
    while True:
        live = [(r, w) for (r, w) in all_cursors
                if pcs[(r, w)] < len(program.gpus[r][w])]
        if not live:
            break
        runnable = [(r, w) for (r, w) in live if ready(r, w)]
        if not runnable:
            blocked = []
            for (r, w) in live:
                o = program.gpus[r][w][pcs[(r, w)]]
                entry = {"rank": r, "wg": w, "pc": pcs[(r, w)], "op": o.op}
                if o.op == "wait":
                    entry["sem"] = o.sem
                    entry["expected"] = o.expected
                    entry["have"] = sems.get((r, o.sem), 0)
                blocked.append(entry)
            brief = [(b["rank"], b["wg"], b["pc"], b["op"],
                      b.get("sem", -1), b.get("have", "-"),
                      b.get("expected", "-")) for b in blocked[:8]]
            raise DeadlockError(
                f"no runnable cursor after {steps} step(s); "
                f"{len(blocked)} cursor(s) stuck "
                f"(rank, wg, pc, op, sem, have, expected): {brief}",
                blocked=blocked, semaphores=sems)
        r, w = rng.choice(runnable)
        step(r, w)
        steps += 1
        if steps > max_steps:
            raise RuntimeError("step budget exceeded")
    return [bufs[r]["output"] for r in range(n)]


# ---------------------------------------------------------------------------
# Collective postconditions
# ---------------------------------------------------------------------------

def expected_outputs(program: Program, inputs: List[np.ndarray]
                     ) -> List[np.ndarray]:
    n = program.num_ranks
    kind = program.collective
    if kind == "all_gather":
        cat = np.concatenate(inputs)
        return [cat for _ in range(n)]
    if kind == "reduce_scatter":
        S = program.buffers["output"]
        total = np.sum(np.stack(inputs), axis=0)
        return [total[r * S:(r + 1) * S] for r in range(n)]
    if kind == "all_reduce":
        total = np.sum(np.stack(inputs), axis=0)
        return [total for _ in range(n)]
    if kind == "all_to_all":
        S = program.buffers["input"] // n
        return [np.concatenate([inputs[k][r * S:(r + 1) * S]
                                for k in range(n)]) for r in range(n)]
    raise ValueError(kind)


def check_program(program: Program, seed: int = 0) -> None:
    """Assert the program computes its collective. Raises on mismatch."""
    inputs = make_inputs(program, seed)
    outs = execute(program, inputs, seed=seed)
    want = expected_outputs(program, inputs)
    for r, (got, exp) in enumerate(zip(outs, want)):
        if not np.array_equal(got, exp):
            bad = np.nonzero(got != exp)[0]
            raise AssertionError(
                f"{program.name}: rank {r} wrong at {len(bad)} bytes, "
                f"first at offset {bad[0]}: got {got[bad[0]]}, "
                f"want {exp[bad[0]]}")
