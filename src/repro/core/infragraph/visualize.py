"""InfraGraph visualizer (paper §4.7.2): DOT output + text summaries so
users can check the graph they defined is the one they intended."""

from __future__ import annotations

from collections import Counter

from .graph import Infrastructure


def to_dot(infra: Infrastructure, collapse_devices: bool = True) -> str:
    """Graphviz DOT.  With ``collapse_devices`` each device instance becomes
    one node (readable for big fabrics); otherwise fully qualified."""
    g = infra.expand()
    lines = [f'digraph "{infra.name}" {{', "  rankdir=TB;"]
    if collapse_devices:
        devs = sorted({(a["instance"], a["index"]) for a in g.nodes.values()})
        for inst, idx in devs:
            lines.append(f'  "{inst}.{idx}" [shape=box];')
        seen = set()
        for (src, dst), lt in g.edges.items():
            a = ".".join(src.split(".")[:2])
            b = ".".join(dst.split(".")[:2])
            if a == b or (b, a) in seen or (a, b) in seen:
                continue
            seen.add((a, b))
            lines.append(f'  "{a}" -> "{b}" [dir=both, '
                         f'label="{lt.name}\\n{lt.bandwidth_GBps:g}GB/s"];')
    else:
        for n in g.nodes:
            lines.append(f'  "{n}";')
        done = set()
        for (src, dst), lt in g.edges.items():
            if (dst, src) in done:
                continue
            done.add((src, dst))
            lines.append(f'  "{src}" -> "{dst}" [dir=both, '
                         f'label="{lt.name}"];')
    lines.append("}")
    return "\n".join(lines)


def summary(infra: Infrastructure) -> str:
    """Text summary: node/edge census, connectivity, per-kind counts."""
    g = infra.expand()
    kinds = Counter(a.get("kind", "?") for a in g.nodes.values())
    linkkinds = Counter(lt.name for lt in g.edges.values())
    out = [f"InfraGraph '{infra.name}': {len(g.nodes)} nodes, "
           f"{len(g.edges)} directed edges, "
           f"connected={g.connected()}"]
    out.append("  components: " + ", ".join(
        f"{k}x{v}" for k, v in sorted(kinds.items())))
    out.append("  links: " + ", ".join(
        f"{k}x{v}" for k, v in sorted(linkkinds.items())))
    return "\n".join(out)
