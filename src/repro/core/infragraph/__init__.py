from .graph import (Component, Device, Infrastructure, Instance, LinkType,
                    FQGraph)
from .blueprints import (clos_fat_tree_fabric, generic_gpu_device,
                         hierarchical_fabric, hierarchical_host_device,
                         host_device, single_tier_fabric, switch_device,
                         torus2d_fabric, tpu_v5e_device, tpu_pod_fabric)
from .translate import to_fabric, to_simple_topology, to_cluster
from .visualize import to_dot, summary

__all__ = [
    "Component", "Device", "Infrastructure", "Instance", "LinkType",
    "FQGraph", "clos_fat_tree_fabric", "generic_gpu_device",
    "hierarchical_fabric", "hierarchical_host_device", "host_device",
    "single_tier_fabric", "switch_device", "torus2d_fabric",
    "tpu_v5e_device", "tpu_pod_fabric", "to_fabric", "to_simple_topology",
    "to_cluster", "to_dot", "summary",
]
