"""InfraGraph: a standard, portable representation of AI/HPC network
infrastructure (paper §4.6).

Topology is a directed, attributed graph: vertices are hardware components
(GPUs, NICs, switch ASICs, ports, ...), edges are connections annotated
with link properties.  Definitions are compact — reusable ``Device``
templates instantiated into an ``Infrastructure`` and programmatically
expanded into a **fully qualified graph** whose nodes follow the
hierarchical naming convention of paper §4.7.3::

    <device-instance>.<index>.<component>.<index>

e.g. ``switch.0.port.3`` — and whose edges are
``(src_node, dst_node, link_name)`` triples.
"""

from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class Component:
    """Hardware unit within a device (paper §4.6.1)."""
    name: str                    # e.g. "gpu", "nic", "port", "asic", "cu"
    count: int = 1
    attrs: Tuple[Tuple[str, object], ...] = ()

    def attr(self, key: str, default=None):
        return dict(self.attrs).get(key, default)


@dataclass(frozen=True)
class LinkType:
    """Named connection container with physical properties (§4.6.1)."""
    name: str                    # e.g. "pcie", "xgmi", "ici", "eth800"
    bandwidth_GBps: float
    latency_ns: float
    attrs: Tuple[Tuple[str, object], ...] = ()


@dataclass
class Device:
    """Subgraph template for device hardware (paper §4.6.2).

    ``edges``: internal wiring as ((comp, idx), (comp, idx), link_name),
    added for both directions when the graph is expanded.
    """
    name: str
    components: List[Component] = field(default_factory=list)
    links: Dict[str, LinkType] = field(default_factory=dict)
    edges: List[Tuple[Tuple[str, int], Tuple[str, int], str]] = \
        field(default_factory=list)

    def component(self, name: str) -> Component:
        for c in self.components:
            if c.name == name:
                return c
        raise KeyError(f"{self.name}: no component {name!r}")

    def add_link_type(self, lt: LinkType) -> "Device":
        self.links[lt.name] = lt
        return self

    def wire(self, a: Tuple[str, int], b: Tuple[str, int], link: str) -> None:
        if link not in self.links:
            raise KeyError(f"{self.name}: unknown link type {link!r}")
        self.edges.append((a, b, link))


@dataclass
class Instance:
    """Device instantiation alias (paper §4.6.2)."""
    device: Device
    name: str
    count: int = 1


NodeRef = Tuple[str, int, str, int]      # (instance, idx, component, cidx)


@dataclass
class Infrastructure:
    """Top-level graph container (paper §4.6.2)."""
    name: str
    instances: Dict[str, Instance] = field(default_factory=dict)
    links: Dict[str, LinkType] = field(default_factory=dict)
    # inter-device edges: (src NodeRef, dst NodeRef, link name)
    edges: List[Tuple[NodeRef, NodeRef, str]] = field(default_factory=list)

    def add(self, device: Device, name: str, count: int = 1) -> Instance:
        inst = Instance(device, name, count)
        self.instances[name] = inst
        return inst

    def add_link_type(self, lt: LinkType) -> None:
        self.links[lt.name] = lt

    def connect(self, a: NodeRef, b: NodeRef, link: str) -> None:
        if link not in self.links:
            raise KeyError(f"unknown fabric link type {link!r}")
        self.edges.append((a, b, link))

    def expand(self) -> "FQGraph":
        return FQGraph.from_infrastructure(self)

    # ----------------------------------------------------------------- JSON
    def to_json(self) -> str:
        def lt_json(lt: LinkType) -> dict:
            return {"name": lt.name, "bandwidth_GBps": lt.bandwidth_GBps,
                    "latency_ns": lt.latency_ns, "attrs": dict(lt.attrs)}

        devs = {}
        for inst in self.instances.values():
            d = inst.device
            devs[d.name] = {
                "components": [{"name": c.name, "count": c.count,
                                "attrs": dict(c.attrs)}
                               for c in d.components],
                "links": {k: lt_json(v) for k, v in d.links.items()},
                "edges": d.edges,
            }
        return json.dumps({
            "name": self.name,
            "devices": devs,
            "instances": [{"device": i.device.name, "name": i.name,
                           "count": i.count}
                          for i in self.instances.values()],
            "links": {k: lt_json(v) for k, v in self.links.items()},
            "edges": self.edges,
        }, indent=1)

    def content_hash(self) -> str:
        """Canonical sha256 over the topology's semantic content — the
        sweep cache's infrastructure key.  Computed over the same
        structure :meth:`to_json` emits (devices, instances, link types,
        edges — edge *order* included, since translation walks edges in
        order), so ``from_json(to_json(i))`` hashes equal to ``i``."""
        from ..canonical import content_hash
        return content_hash({"kind": "Infrastructure",
                             **json.loads(self.to_json())})

    @staticmethod
    def from_json(text: str) -> "Infrastructure":
        d = json.loads(text)

        def lt(o: dict) -> LinkType:
            return LinkType(o["name"], o["bandwidth_GBps"], o["latency_ns"],
                            tuple(sorted(o.get("attrs", {}).items())))

        devices: Dict[str, Device] = {}
        for name, spec in d["devices"].items():
            dev = Device(name,
                         [Component(c["name"], c["count"],
                                    tuple(sorted(c.get("attrs", {}).items())))
                          for c in spec["components"]],
                         {k: lt(v) for k, v in spec["links"].items()},
                         [(tuple(a), tuple(b), l)
                          for a, b, l in spec["edges"]])
            devices[name] = dev
        infra = Infrastructure(d["name"])
        for i in d["instances"]:
            infra.add(devices[i["device"]], i["name"], i["count"])
        infra.links = {k: lt(v) for k, v in d["links"].items()}
        infra.edges = [(tuple(a), tuple(b), l) for a, b, l in d["edges"]]
        return infra


def node_name(inst: str, idx: int, comp: str, cidx: int) -> str:
    """Hierarchical identifier (paper §4.7.3)."""
    return f"{inst}.{idx}.{comp}.{cidx}"


@dataclass
class FQGraph:
    """Fully qualified graph: every component instance is a node."""
    name: str
    nodes: Dict[str, Dict] = field(default_factory=dict)
    # directed edges: (src, dst) -> LinkType
    edges: Dict[Tuple[str, str], LinkType] = field(default_factory=dict)
    adj: Dict[str, List[str]] = field(default_factory=dict)

    @staticmethod
    def from_infrastructure(infra: Infrastructure) -> "FQGraph":
        g = FQGraph(infra.name)
        for inst in infra.instances.values():
            for i in range(inst.count):
                for comp in inst.device.components:
                    for c in range(comp.count):
                        g.add_node(node_name(inst.name, i, comp.name, c),
                                   kind=comp.name, device=inst.device.name,
                                   instance=inst.name, index=i, cindex=c,
                                   **dict(comp.attrs))
                for (ca, ia), (cb, ib), lname in inst.device.edges:
                    lt = inst.device.links[lname]
                    a = node_name(inst.name, i, ca, ia)
                    b = node_name(inst.name, i, cb, ib)
                    g.add_edge(a, b, lt)
                    g.add_edge(b, a, lt)
        for (ai, aidx, ac, acx), (bi, bidx, bc, bcx), lname in infra.edges:
            lt = infra.links[lname]
            a = node_name(ai, aidx, ac, acx)
            b = node_name(bi, bidx, bc, bcx)
            if a not in g.nodes or b not in g.nodes:
                missing = a if a not in g.nodes else b
                raise KeyError(f"fabric edge references unknown node "
                               f"{missing!r}")
            g.add_edge(a, b, lt)
            g.add_edge(b, a, lt)
        return g

    def add_node(self, name: str, **attrs) -> None:
        if name in self.nodes:
            raise ValueError(f"duplicate node {name}")
        self.nodes[name] = attrs
        self.adj[name] = []

    def add_edge(self, src: str, dst: str, lt: LinkType) -> None:
        self.edges[(src, dst)] = lt
        self.adj[src].append(dst)

    # ------------------------------------------------------------- analysis
    def nodes_of_kind(self, kind: str) -> List[str]:
        return sorted(n for n, a in self.nodes.items()
                      if a.get("kind") == kind)

    def path(self, src: str, dst: str) -> List[str]:
        """Shortest path (hop count) — communication path discovery."""
        if src == dst:
            return [src]
        prev: Dict[str, str] = {}
        seen = {src}
        q = deque([src])
        while q:
            u = q.popleft()
            for v in self.adj[u]:
                if v in seen:
                    continue
                seen.add(v)
                prev[v] = u
                if v == dst:
                    out = [dst]
                    while out[-1] != src:
                        out.append(prev[out[-1]])
                    return out[::-1]
                q.append(v)
        raise ValueError(f"no path {src} -> {dst}")

    def connected(self) -> bool:
        if not self.nodes:
            return True
        start = next(iter(self.nodes))
        seen = {start}
        q = deque([start])
        while q:
            u = q.popleft()
            for v in self.adj[u]:
                if v not in seen:
                    seen.add(v)
                    q.append(v)
        return len(seen) == len(self.nodes)

    def bisection_GBps(self, group_a: List[str], group_b: List[str]) -> float:
        """Total bandwidth of edges crossing a node partition."""
        a, bset = set(group_a), set(group_b)
        return sum(lt.bandwidth_GBps for (s, d), lt in self.edges.items()
                   if s in a and d in bset)
