"""InfraGraph translators (paper §4.7.1).

One InfraGraph description drives every network backend:

* ``to_fabric``          — detailed event-driven backend (NoC-level Fabric);
* ``to_simple_topology`` — coarse Simple backend: detects topology patterns
  and decomposes the node count into multi-dimensional groups (what the
  paper's Simple translator does);
* ``to_cluster``         — builds a fine-grained GPU Cluster whose scale-up
  wiring comes from the InfraGraph fabric edges instead of the built-ins:
  ring, switch, leaf/spine or torus blueprints all become real scale-up
  topologies between the detailed GPUs' I/O ports, and the graph's link
  properties (bandwidth/latency) override the ``NocConfig`` defaults.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..engine import Engine
from ..network.fabric import Fabric
from ..network.simple import SimpleTopology
from .graph import FQGraph, Infrastructure

#: component kinds that carry collective ranks, in detection order
ENDPOINT_KINDS = ("gpu", "core", "cu")


def to_fabric(infra: Infrastructure, engine: Optional[Engine] = None,
              policy: str = "fifo") -> Tuple[Fabric, FQGraph]:
    """Expand and lower an InfraGraph into the event-driven Fabric."""
    g = infra.expand()
    fab = Fabric(engine or Engine(), default_policy=policy)
    ids = {name: fab.add_node(name) for name in g.nodes}
    for (src, dst), lt in g.edges.items():
        fab.add_link(ids[src], ids[dst], lt.bandwidth_GBps, lt.latency_ns,
                     name=f"{src}->{dst}:{lt.name}")
    return fab, g


def endpoint_nodes(g: FQGraph, kinds: Tuple[str, ...] = ENDPOINT_KINDS
                   ) -> List[str]:
    """Rank-bearing endpoints in deterministic order."""
    out: List[str] = []
    for kind in kinds:
        out.extend(g.nodes_of_kind(kind))
        if out:
            break
    return out


def to_simple_topology(infra: Infrastructure) -> SimpleTopology:
    """Coarse translation: detect the fabric pattern and emit Simple dims.

    Pattern detection (paper: "the Simple translator additionally detects
    topology patterns to decompose large node counts into multi-dimensional
    groups"):
      * one switch tier           -> one "switch" dim over all endpoints
      * leaf/spine (two tiers)    -> (hosts-per-leaf, "switch") x (leaves,
                                      "switch")
      * torus edges               -> per-axis "ring" dims
      * anything else             -> one "ring" dim (direct neighbor wiring)
    """
    g = infra.expand()
    eps = endpoint_nodes(g)
    n = len(eps)
    if n == 0:
        raise ValueError("no endpoints (gpu/core/cu) in infrastructure")

    inst_names = {name.split(".")[0] for name in g.nodes}
    # link properties seen on fabric edges
    lats = [lt.latency_ns for lt in infra.links.values()] or [500.0]
    bws = [lt.bandwidth_GBps for lt in infra.links.values()] or [50.0]
    lat, bw = max(lats), min(bws)

    if "leaf" in inst_names and "spine" in inst_names:
        leaves = len({nm.split(".")[1] for nm in g.nodes
                      if nm.startswith("leaf.")})
        per_leaf = max(1, n // max(leaves, 1))
        return SimpleTopology([(per_leaf, bw, lat, "switch"),
                               (max(leaves, 1), bw, lat, "switch")])
    if "switch" in inst_names or "dcn" in inst_names:
        return SimpleTopology([(n, bw, lat, "switch")])
    # torus: infer per-axis ring sizes from the infrastructure name if
    # present (torus{X}x{Y}), else fall back to a single ring
    name = infra.name
    if name.startswith("torus") and "x" in name:
        try:
            dims = name[len("torus"):].split("x")
            x, y = int(dims[0]), int(dims[1])
            if x * y == n:
                return SimpleTopology([(x, bw, lat, "ring"),
                                       (y, bw, lat, "ring")])
        except ValueError:
            pass
    return SimpleTopology([(n, bw, lat, "ring")])


def _endpoint_units(g: FQGraph) -> List[Tuple[str, int, str, int]]:
    """(instance, index, component, cindex) endpoints, in rank order.

    One rank per endpoint *component*: a multi-GPU host device
    (``host_device(gpus=8)``) contributes eight ranks, one per ``gpu``
    component, not one per device.
    """
    units: List[Tuple[str, int, str, int]] = []
    for name in endpoint_nodes(g):
        inst, idx, comp, cidx = name.split(".")
        units.append((inst, int(idx), comp, int(cidx)))
    return units


def to_cluster(infra: Infrastructure, noc=None, gpu_config=None,
               engine: Optional[Engine] = None):
    """Fine-grained Cluster whose scale-up topology mirrors the InfraGraph.

    Every endpoint *component* becomes a detailed GPU (NoC + CUs + HBM) —
    rank-per-component, so a multi-GPU host device yields one rank per GPU.
    The wiring between their I/O ports follows the InfraGraph edges:

    * a non-endpoint component that is wired (device-internally) to exactly
      one endpoint of its device — e.g. ``host.0.nic.3`` next to
      ``host.0.gpu.3`` — aliases onto that rank's I/O port ``cidx`` (mod
      the NoC's port count); for single-endpoint devices every component
      aliases onto the one rank (the historical behavior);
    * shared components (a PCIe bridge wired to all of a host's GPUs)
      become fabric nodes of their own, with their device-internal edges
      wired — so intra-host GPU-to-GPU traffic crosses the bridge instead
      of the scale-out network;
    * switch devices become fabric nodes with their internal wiring.

    Every added link takes its bandwidth/latency from the graph's LinkType,
    *not* from the ``NocConfig`` scale-up defaults.
    """
    from ..cluster import Cluster

    g = infra.expand()
    units = _endpoint_units(g)
    n = len(units)
    if n == 0:
        raise ValueError("no endpoints (gpu/core/cu) in infrastructure")
    rank_of = {unit: r for r, unit in enumerate(units)}
    # per device instance: its endpoint units
    per_device: Dict[Tuple[str, int], List[Tuple[str, int, str, int]]] = {}
    for u in units:
        per_device.setdefault((u[0], u[1]), []).append(u)
    ep_names = {f"{i}.{x}.{c}.{k}" for (i, x, c, k) in units}

    def _split(name: str) -> Tuple[str, int, str, int]:
        inst, idx, comp, cidx = name.split(".")
        return inst, int(idx), comp, int(cidx)

    def unit_rank(name: str) -> Optional[int]:
        """Rank a component belongs to, or None (switch-side / shared)."""
        inst, idx, comp, cidx = _split(name)
        r = rank_of.get((inst, idx, comp, cidx))
        if r is not None:
            return r
        eps = per_device.get((inst, idx))
        if not eps:
            return None                       # switch-side component
        if len(eps) == 1:
            return rank_of[eps[0]]            # single-endpoint device
        # multi-endpoint device: alias iff wired to exactly one endpoint
        nbrs = {nb for nb in g.adj[name] if nb in ep_names
                and nb.startswith(f"{inst}.{idx}.")}
        if len(nbrs) == 1:
            return rank_of[_split(nbrs.pop())]
        return None                           # shared (bridge/cpu/...)

    cluster = Cluster(n, gpu_config=gpu_config, noc=noc,
                      engine=engine, topology="none")
    fab = cluster.fabric

    def resolve(name: str) -> int:
        """FQ node -> fabric node id (rank components map onto GPU I/O)."""
        rank = unit_rank(name)
        if rank is None:
            return fab.add_node(name)
        gpu = cluster.gpus[rank]
        cidx = int(name.rsplit(".", 1)[1])
        return gpu.io_nodes[cidx % len(gpu.io_nodes)]

    # one scale-up region guard per GPU: the min latency of inbound edges
    inbound_lat: Dict[int, float] = {}
    wired = 0
    for (src, dst), lt in g.edges.items():
        sr, dr = unit_rank(src), unit_rank(dst)
        if sr is not None and sr == dr:
            continue                          # intra-rank wiring: the
                                              # detailed NoC already models it
        u, v = resolve(src), resolve(dst)
        region = 0
        if dr is not None:
            region = cluster.regions[dr]
            lat = inbound_lat.get(dr)
            inbound_lat[dr] = lt.latency_ns if lat is None \
                else min(lat, lt.latency_ns)
        fab.add_link(u, v, lt.bandwidth_GBps, lt.latency_ns, region=region,
                     name=f"{src}->{dst}:{lt.name}")
        if sr is not None or dr is not None:
            wired += 1
    if n > 1 and wired == 0:
        raise ValueError(
            f"infrastructure {infra.name!r} has no fabric edges between "
            f"its {n} endpoint devices; the cluster would be disconnected")
    for rank, lat in inbound_lat.items():
        fab.set_region_guard(cluster.regions[rank], lat)
        cluster.gpus[rank].region_guard_ps = int(round(lat * 1000))
    # wiring is final: make the route/feeder census final too, and wire
    # the per-link reservation ledgers (feeder lists, CU/endpoint injection
    # sources, delivery sinks) over the graph-built scale-up topology — the
    # fast path's FIFO certificate depends on both (see Cluster.warm_routes)
    cluster.warm_routes()
    return cluster
