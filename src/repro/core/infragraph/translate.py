"""InfraGraph translators (paper §4.7.1).

One InfraGraph description drives every network backend:

* ``to_fabric``          — detailed event-driven backend (NoC-level Fabric);
* ``to_simple_topology`` — coarse Simple backend: detects topology patterns
  and decomposes the node count into multi-dimensional groups (what the
  paper's Simple translator does);
* ``to_cluster``         — builds a fine-grained GPU Cluster whose scale-up
  wiring comes from the InfraGraph fabric edges instead of the built-ins.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..engine import Engine
from ..network.fabric import Fabric
from ..network.simple import SimpleTopology
from .graph import FQGraph, Infrastructure


def to_fabric(infra: Infrastructure, engine: Optional[Engine] = None,
              policy: str = "fifo") -> Tuple[Fabric, FQGraph]:
    """Expand and lower an InfraGraph into the event-driven Fabric."""
    g = infra.expand()
    fab = Fabric(engine or Engine(), default_policy=policy)
    ids = {name: fab.add_node(name) for name in g.nodes}
    for (src, dst), lt in g.edges.items():
        fab.add_link(ids[src], ids[dst], lt.bandwidth_GBps, lt.latency_ns,
                     name=f"{src}->{dst}:{lt.name}")
    return fab, g


def endpoint_nodes(g: FQGraph, kinds: Tuple[str, ...] = ("gpu", "core", "cu")
                   ) -> List[str]:
    """Rank-bearing endpoints in deterministic order."""
    out: List[str] = []
    for kind in kinds:
        out.extend(g.nodes_of_kind(kind))
        if out:
            break
    return out


def to_simple_topology(infra: Infrastructure) -> SimpleTopology:
    """Coarse translation: detect the fabric pattern and emit Simple dims.

    Pattern detection (paper: "the Simple translator additionally detects
    topology patterns to decompose large node counts into multi-dimensional
    groups"):
      * one switch tier           -> one "switch" dim over all endpoints
      * leaf/spine (two tiers)    -> (hosts-per-leaf, "switch") x (leaves,
                                      "switch")
      * torus edges               -> per-axis "ring" dims
    """
    g = infra.expand()
    eps = endpoint_nodes(g)
    n = len(eps)
    if n == 0:
        raise ValueError("no endpoints (gpu/core/cu) in infrastructure")

    inst_names = {name.split(".")[0] for name in g.nodes}
    # link properties seen on fabric edges
    lats = [lt.latency_ns for lt in infra.links.values()] or [500.0]
    bws = [lt.bandwidth_GBps for lt in infra.links.values()] or [50.0]
    lat, bw = max(lats), min(bws)

    if "leaf" in inst_names and "spine" in inst_names:
        leaves = len({nm.split(".")[1] for nm in g.nodes
                      if nm.startswith("leaf.")})
        per_leaf = max(1, n // max(leaves, 1))
        return SimpleTopology([(per_leaf, bw, lat, "switch"),
                               (max(leaves, 1), bw, lat, "switch")])
    if "switch" in inst_names or "dcn" in inst_names:
        return SimpleTopology([(n, bw, lat, "switch")])
    # torus: infer per-axis ring sizes from the infrastructure name if
    # present (torus{X}x{Y}), else fall back to a single ring
    name = infra.name
    if name.startswith("torus") and "x" in name:
        try:
            dims = name[len("torus"):].split("x")
            x, y = int(dims[0]), int(dims[1])
            if x * y == n:
                return SimpleTopology([(x, bw, lat, "ring"),
                                       (y, bw, lat, "ring")])
        except ValueError:
            pass
    return SimpleTopology([(n, bw, lat, "ring")])


def to_cluster(infra: Infrastructure, noc=None, gpu_config=None):
    """Fine-grained Cluster whose scale-up topology mirrors the InfraGraph.

    Endpoint devices become detailed GPUs (NoC + CUs + HBM); switch/torus
    wiring between their I/O ports follows the InfraGraph edges.
    """
    from ..cluster import Cluster, NocConfig

    g = infra.expand()
    eps = endpoint_nodes(g)
    n = len(eps)
    cluster = Cluster(n, gpu_config=gpu_config, noc=noc, topology="switch")
    return cluster
