"""Pre-built, composable InfraGraph blueprints (paper §4.6.3).

Device blueprints define the internal hardware of a platform; fabric
blueprints compose device instances into full network topologies,
parameterized (port counts, depth, hosts) and automatically wired.

Includes the paper's generic GPU (§5.1) and — per DESIGN.md §4 — a TPU v5e
device + 2-D-torus pod fabric used by the JAX framework's step-time
predictor.
"""

from __future__ import annotations

import math
from typing import Optional

from .graph import Component, Device, Infrastructure, LinkType


# ---------------------------------------------------------------------------
# Device blueprints
# ---------------------------------------------------------------------------

def generic_gpu_device(mesh_x: int = 8, mesh_y: int = 4,
                       cus_per_router: int = 4,
                       mem_channels: int = 32, io_ports: int = 32,
                       onchip_GBps: float = 1099.5,
                       mem_GBps: float = 137.4,
                       io_GBps: float = 34.36) -> Device:
    """The paper's §5.1 generic GPU: 2-D mesh NoC, CUs, HBM channels and
    I/O ports hanging off boundary routers."""
    d = Device(f"gpu{mesh_x}x{mesh_y}", [
        Component("router", mesh_x * mesh_y),
        Component("cu", mesh_x * mesh_y * cus_per_router),
        Component("hbm", mem_channels, (("GBps", mem_GBps),)),
        Component("io", io_ports, (("GBps", io_GBps),)),
    ])
    d.add_link_type(LinkType("noc", onchip_GBps, 5.0))
    d.add_link_type(LinkType("culink", onchip_GBps, 1.0))
    d.add_link_type(LinkType("hbmlink", mem_GBps, 1.0))
    d.add_link_type(LinkType("iolink", io_GBps, 1.0))

    def rid(x: int, y: int) -> int:
        return x * mesh_y + y

    for x in range(mesh_x):
        for y in range(mesh_y):
            if x + 1 < mesh_x:
                d.wire(("router", rid(x, y)), ("router", rid(x + 1, y)), "noc")
            if y + 1 < mesh_y:
                d.wire(("router", rid(x, y)), ("router", rid(x, y + 1)), "noc")
    for i in range(mesh_x * mesh_y * cus_per_router):
        r = i // cus_per_router
        d.wire(("cu", i), ("router", r), "culink")
    for i in range(mem_channels):
        row = 0 if i < mem_channels // 2 else mesh_y - 1
        col = i % mesh_x
        d.wire(("hbm", i), ("router", rid(col, row)), "hbmlink")
    for i in range(io_ports):
        col = 0 if i < io_ports // 2 else mesh_x - 1
        row = i % mesh_y
        d.wire(("io", i), ("router", rid(col, row)), "iolink")
    return d


def simple_gpu_device(nic_GBps: float = 50.0, nics: int = 1) -> Device:
    """Coarse GPU: one compute vertex + ``nics`` NICs (scale-out studies;
    ring/torus fabrics need one NIC per direction)."""
    d = Device("sgpu" if nics == 1 else f"sgpu{nics}n", [
        Component("gpu", 1),
        Component("nic", nics, (("GBps", nic_GBps),)),
    ])
    d.add_link_type(LinkType("pcie", 64.0, 500.0))
    for i in range(nics):
        d.wire(("gpu", 0), ("nic", i), "pcie")
    return d


def host_device(gpus: int = 8, nic_GBps: float = 50.0) -> Device:
    """Host server: CPU + PCIe bridge + GPUs + NICs (paper §4.6.2 example)."""
    d = Device(f"host{gpus}g", [
        Component("cpu", 1),
        Component("bridge", 1),
        Component("gpu", gpus),
        Component("nic", gpus, (("GBps", nic_GBps),)),
    ])
    d.add_link_type(LinkType("pcie", 64.0, 500.0))
    d.wire(("cpu", 0), ("bridge", 0), "pcie")
    for g in range(gpus):
        d.wire(("gpu", g), ("bridge", 0), "pcie")
        d.wire(("gpu", g), ("nic", g), "pcie")
    return d


def hierarchical_host_device(gpus: int = 4, nic_GBps: float = 50.0,
                             scaleup_GBps: float = 200.0,
                             scaleup_lat_ns: float = 500.0) -> Device:
    """Multi-GPU host for hierarchical fabrics: ``gpus`` rank-bearing GPU
    endpoints joined by a shared scale-up bridge (NVLink-switch-style, a
    fabric node of its own under ``to_cluster``), plus one scale-out NIC
    per GPU.  Intra-host GPU-to-GPU traffic crosses the bridge; inter-host
    traffic leaves through the NICs."""
    d = Device(f"hhost{gpus}g", [
        Component("gpu", gpus),
        Component("bridge", 1),
        Component("nic", gpus, (("GBps", nic_GBps),)),
    ])
    d.add_link_type(LinkType("scaleup", scaleup_GBps, scaleup_lat_ns))
    d.add_link_type(LinkType("pcie", 64.0, 500.0))
    for g in range(gpus):
        d.wire(("gpu", g), ("bridge", 0), "scaleup")
        d.wire(("gpu", g), ("nic", g), "pcie")
    return d


def switch_device(ports: int, port_GBps: float = 50.0,
                  name: Optional[str] = None) -> Device:
    """Switch: one ASIC vertex + ``ports`` port vertices (paper §4.7.3's
    ``(switch.0.asic.0, switch.0.port.0, pcie)`` example)."""
    d = Device(name or f"switch{ports}p", [
        Component("asic", 1),
        Component("port", ports, (("GBps", port_GBps),)),
    ])
    d.add_link_type(LinkType("asiclink", port_GBps * ports, 50.0))
    for p in range(ports):
        d.wire(("port", p), ("asic", 0), "asiclink")
    return d


def tpu_v5e_device() -> Device:
    """TPU v5e chip: TensorCore+MXU, 2 HBM stacks, 4 ICI ports.

    Hardware constants from the brief: 197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s per ICI link.
    """
    d = Device("tpuv5e", [
        Component("core", 1, (("TFLOPs_bf16", 197.0),)),
        Component("hbm", 2, (("GBps", 409.5),)),
        Component("ici", 4, (("GBps", 50.0),)),
    ])
    d.add_link_type(LinkType("hbmbus", 409.5, 10.0))
    d.add_link_type(LinkType("icibus", 50.0, 10.0))
    for h in range(2):
        d.wire(("core", 0), ("hbm", h), "hbmbus")
    for p in range(4):
        d.wire(("core", 0), ("ici", p), "icibus")
    return d


# ---------------------------------------------------------------------------
# Fabric blueprints
# ---------------------------------------------------------------------------

def single_tier_fabric(num_hosts: int = 4, device: Optional[Device] = None,
                       link_GBps: float = 50.0,
                       link_lat_ns: float = 500.0) -> Infrastructure:
    """SingleTierFabric: a flat single-switch-layer topology (§4.6.3)."""
    dev = device or simple_gpu_device(link_GBps)
    infra = Infrastructure(f"single_tier_{num_hosts}")
    infra.add(dev, "host", num_hosts)
    sw = switch_device(num_hosts, link_GBps)
    infra.add(sw, "switch", 1)
    infra.add_link_type(LinkType("eth", link_GBps, link_lat_ns))
    nic = "nic" if any(c.name == "nic" for c in dev.components) else "io"
    for h in range(num_hosts):
        infra.connect(("host", h, nic, 0), ("switch", 0, "port", h), "eth")
    return infra


def ring_fabric(num_hosts: int = 4, device: Optional[Device] = None,
                link_GBps: float = 50.0,
                link_lat_ns: float = 1000.0) -> Infrastructure:
    """Ring scale-up fabric: host ``i``'s second NIC to host ``i+1``'s
    first (directional pair per neighbor), no switch at all.  The
    fine-grained translator maps these edges onto the detailed GPUs' I/O
    ports, so the same blueprint exercises ring wiring at every fidelity
    tier."""
    dev = device or simple_gpu_device(link_GBps, nics=2)
    port = ("nic" if any(c.name == "nic" for c in dev.components)
            else ("ici" if any(c.name == "ici" for c in dev.components)
                  else "io"))
    nports = dev.component(port).count
    if nports < 2:
        raise ValueError("ring fabric needs >= 2 ports per device")
    infra = Infrastructure(f"ring_{num_hosts}")
    infra.add(dev, "host", num_hosts)
    infra.add_link_type(LinkType("ring", link_GBps, link_lat_ns))
    for h in range(num_hosts):
        infra.connect(("host", h, port, 1),
                      ("host", (h + 1) % num_hosts, port, 0), "ring")
    return infra


def clos_fat_tree_fabric(num_hosts: int = 8, switch_ports: int = 4,
                         depth: int = 2, link_GBps: float = 50.0,
                         link_lat_ns: float = 500.0,
                         device: Optional[Device] = None) -> Infrastructure:
    """ClosFatTreeFabric (§4.6.3, Fig. 9): hierarchical leaf/spine topology
    parameterized by switch port count and network depth; switch counts and
    wiring are computed per the standard folded-Clos construction.

    depth == 2: leaf + spine.  Hosts per leaf = ports/2; uplinks = ports/2.
    """
    if depth != 2:
        raise NotImplementedError("this blueprint builds 2-tier folded Clos")
    half = switch_ports // 2
    num_leaves = math.ceil(num_hosts / half)
    num_spines = half
    dev = device or simple_gpu_device(link_GBps)
    infra = Infrastructure(
        f"clos_h{num_hosts}_p{switch_ports}_d{depth}")
    infra.add(dev, "host", num_hosts)
    infra.add(switch_device(switch_ports, link_GBps, "leafsw"), "leaf",
              num_leaves)
    infra.add(switch_device(max(num_leaves, 1), link_GBps, "spinesw"),
              "spine", num_spines)
    infra.add_link_type(LinkType("eth", link_GBps, link_lat_ns))
    nic = "nic" if any(c.name == "nic" for c in dev.components) else "io"
    for h in range(num_hosts):
        leaf = h // half
        port = h % half
        infra.connect(("host", h, nic, 0), ("leaf", leaf, "port", port),
                      "eth")
    for l in range(num_leaves):
        for s in range(num_spines):
            infra.connect(("leaf", l, "port", half + s),
                          ("spine", s, "port", l), "eth")
    return infra


def hierarchical_fabric(hosts: int = 2, gpus_per_host: int = 4,
                        scaleout: str = "leafspine",
                        switch_ports: Optional[int] = None,
                        nic_GBps: float = 50.0,
                        scaleup_GBps: float = 200.0,
                        scaleup_lat_ns: float = 500.0,
                        eth_lat_ns: float = 500.0,
                        device: Optional[Device] = None) -> Infrastructure:
    """Hierarchical multi-host fabric: detailed NoC per GPU, a shared
    scale-up bridge per host, and a scale-out network between the hosts'
    NICs (the thousand-rank blueprint; paper Figs. 14-15 run this shape).

    ``scaleout`` selects the inter-host tier:

    * ``"leafspine"`` (default) — 2-tier folded Clos over all NICs:
      ``switch_ports`` ports per leaf (default ``2 * gpus_per_host`` — one
      leaf per host), half down to NICs, half up to spines;
    * ``"switch"`` — one flat switch with a port per NIC.

    Each tier keeps its own link type (``scaleup`` / ``pcie`` / ``eth``),
    so ``translate.to_cluster`` wires per-tier bandwidth and latency from
    the graph rather than the ``NocConfig`` scale-up defaults.
    """
    dev = device or hierarchical_host_device(
        gpus_per_host, nic_GBps, scaleup_GBps, scaleup_lat_ns)
    infra = Infrastructure(f"hier_{hosts}x{gpus_per_host}_{scaleout}")
    infra.add(dev, "host", hosts)
    if hosts == 1:
        return infra                  # scale-up bridge only: no scale-out
    infra.add_link_type(LinkType("eth", nic_GBps, eth_lat_ns))
    total = hosts * gpus_per_host
    if scaleout == "switch":
        infra.add(switch_device(total, nic_GBps, "scaleoutsw"), "switch", 1)
        for i in range(total):
            h, j = divmod(i, gpus_per_host)
            infra.connect(("host", h, "nic", j), ("switch", 0, "port", i),
                          "eth")
    elif scaleout == "leafspine":
        ports = switch_ports or 2 * gpus_per_host
        half = ports // 2
        if half < 1:
            raise ValueError("leafspine scale-out needs switch_ports >= 2")
        num_leaves = math.ceil(total / half)
        num_spines = half
        infra.add(switch_device(ports, nic_GBps, "leafsw"), "leaf",
                  num_leaves)
        infra.add(switch_device(max(num_leaves, 1), nic_GBps, "spinesw"),
                  "spine", num_spines)
        for i in range(total):
            h, j = divmod(i, gpus_per_host)
            infra.connect(("host", h, "nic", j),
                          ("leaf", i // half, "port", i % half), "eth")
        for l in range(num_leaves):
            for s in range(num_spines):
                infra.connect(("leaf", l, "port", half + s),
                              ("spine", s, "port", l), "eth")
    else:
        raise ValueError(
            f"unknown scaleout {scaleout!r} (use 'leafspine' or 'switch')")
    return infra


def torus2d_fabric(dim_x: int = 4, dim_y: int = 4,
                   device: Optional[Device] = None,
                   link_GBps: float = 50.0,
                   link_lat_ns: float = 100.0) -> Infrastructure:
    """2-D torus of devices (TPU-pod style): each device uses its 4 ICI/IO
    ports as +x, -x, +y, -y."""
    dev = device or tpu_v5e_device()
    port = "ici" if any(c.name == "ici" for c in dev.components) else "io"
    n = dim_x * dim_y
    infra = Infrastructure(f"torus{dim_x}x{dim_y}")
    infra.add(dev, "chip", n)
    infra.add_link_type(LinkType("ici", link_GBps, link_lat_ns))

    def cid(x: int, y: int) -> int:
        return x * dim_y + y

    for x in range(dim_x):
        for y in range(dim_y):
            # +x wrap link: my port 0 to neighbor's port 1
            infra.connect(("chip", cid(x, y), port, 0),
                          ("chip", cid((x + 1) % dim_x, y), port, 1), "ici")
            # +y wrap link: my port 2 to neighbor's port 3
            infra.connect(("chip", cid(x, y), port, 2),
                          ("chip", cid(x, (y + 1) % dim_y), port, 3), "ici")
    return infra


def tpu_pod_fabric(pods: int = 2, dim_x: int = 16, dim_y: int = 16,
                   dcn_GBps: float = 12.5,
                   dcn_lat_ns: float = 10_000.0) -> Infrastructure:
    """Multi-pod TPU fabric: ``pods`` 2-D-torus pods joined by a DCN switch
    layer (the production mesh of the dry-run: (pod, data, model))."""
    dev = tpu_v5e_device()
    n = dim_x * dim_y
    infra = Infrastructure(f"tpu_{pods}x{dim_x}x{dim_y}")
    infra.add(dev, "chip", pods * n)
    infra.add_link_type(LinkType("ici", 50.0, 100.0))
    infra.add_link_type(LinkType("dcn", dcn_GBps, dcn_lat_ns))
    # one DCN switch with a port per chip (simplified border-router layer)
    infra.add(switch_device(pods * n, dcn_GBps, "dcnsw"), "dcn", 1)

    def cid(p: int, x: int, y: int) -> int:
        return p * n + x * dim_y + y

    for p in range(pods):
        for x in range(dim_x):
            for y in range(dim_y):
                infra.connect(("chip", cid(p, x, y), "ici", 0),
                              ("chip", cid(p, (x + 1) % dim_x, y), "ici", 1),
                              "ici")
                infra.connect(("chip", cid(p, x, y), "ici", 2),
                              ("chip", cid(p, x, (y + 1) % dim_y), "ici", 3),
                              "ici")
                # every chip gets a DCN attachment via its core (border NIC)
                infra.connect(("chip", cid(p, x, y), "core", 0),
                              ("dcn", 0, "port", cid(p, x, y)), "dcn")
    return infra
