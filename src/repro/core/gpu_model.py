"""GPU execution model (paper §4.4).

``GpuModel`` abstracts one physical GPU: it owns compute units (CUs), maps
dispatched kernels' workgroups onto free CUs round-robin, and injects
cache-line-sized *Wavefront Requests* into the network fabric.  A CU issues
at most one instruction per cycle, arbitrating between the ready wavefronts
of its resident workgroups (wavefront-level parallelism); in-flight memory
traffic is bounded per-CU (``max_outstanding`` — the paper's register-file
proxy, Fig. 13) and per-wavefront fences are modeled via ``Waitcnt``.

Instruction streams execute in their compiled (flat-tuple) form — see
:class:`repro.core.instructions.InstrStream` — and when a wavefront's next
run of instructions is a contiguous load/store streak with no intervening
fence, the CU can emit the whole streak in one *bulk wavefront emission*
(``NocConfig.bulk_emission``): every line's issue tick is computed up front
and the batch enters the fabric as coalesced request trains instead of one
scheduling round trip per cache line.  Timing is identical to the
per-instruction cadence by construction (same ticks, same per-link FIFO
commits).

Memory-side behavior (HBM channels servicing loads/stores, semaphore
homes) lives here too: endpoint handlers attached to fabric nodes.
"""

from __future__ import annotations

from dataclasses import dataclass
from heapq import heappop as _heappop, heappush as _heappush
from typing import Dict, List, Optional, Tuple

from .engine import Engine
from .instructions import REDUCE, SEM_ACQUIRE, STORE, WAITCNT
from .operations import OpContext
from .network.fabric import (Fabric, Flight, InjectionSource, _clock_eval,
                             _clock_ge)
from .workload import Kernel, WavefrontState, Workgroup

_SEM_SPACE = 1            # int mirror of Space.SEM
_FAR = 1 << 62


@dataclass
class GpuConfig:
    """Architecture knobs (defaults: paper §5.1 generic GPU, scaled down)."""
    num_cus: int = 16
    cache_line: int = 128            # bytes per Wavefront Request
    cycle_ns: float = 1.0            # CU clock (1 GHz)
    max_outstanding: int = 32        # per-CU in-flight Wavefront Requests
    max_wg_per_cu: int = 1
    unroll: int = 1                  # default loop-unrolling factor (Fig. 12)
    reduce_cycles_per_line: int = 1
    header_bytes: int = 32           # request/ack header size
    hbm_latency_ns: float = 80.0     # channel access latency
    wavefronts_per_wg: int = 4

    def op_context(self) -> OpContext:
        return OpContext(cache_line=self.cache_line, unroll=self.unroll,
                         reduce_cycles_per_line=self.reduce_cycles_per_line)


class WRequest(Flight):
    """One Wavefront Request round-trip (paper §4.4.3).

    Carries its memory operand as plain scalars (``gpu``/``space``/``addr``)
    rather than a boxed ``MemRef`` — and IS its own :class:`Flight`: the
    cluster fills in the wire fields (route/size/cls/eager/on_arrive) per
    leg and re-uses the same object for the response, so a round trip costs
    one allocation instead of three.  ``psize`` is the memory-operand byte
    count; ``size`` is the current leg's wire size (payload and/or header).
    """
    __slots__ = ("kind", "gpu", "space", "addr", "psize", "cu", "wf", "value")

    def __init__(self, kind: int, gpu: int, space: int, addr: int, psize: int,
                 cu: "ComputeUnit", wf: Optional[WavefrontState]):
        self.kind = kind
        self.gpu = gpu
        self.space = space
        self.addr = addr
        self.psize = psize
        self.cu = cu
        self.wf = wf
        self.value = 0          # semaphore value carried by poll responses
        self.hop = 0
        self.payload = None
        self.eta_ps = -1


# Free-list for WRequest round trips: at scale the fine tier allocates
# millions of them, all with identical lifetimes (issued, delivered to
# memory, re-armed, delivered back to the CU).  ``complete`` is the final
# consumer — nothing reads a request after its response delivery — so it
# recycles the object there.
_REQ_POOL: List[WRequest] = []
_REQ_POOL_CAP = 4096


def _wreq(kind: int, gpu: int, space: int, addr: int, psize: int,
          cu: "ComputeUnit", wf: Optional[WavefrontState]) -> WRequest:
    pool = _REQ_POOL
    if pool:
        r = pool.pop()
        r.kind = kind
        r.gpu = gpu
        r.space = space
        r.addr = addr
        r.psize = psize
        r.cu = cu
        r.wf = wf
        r.value = 0
        r.hop = 0
        r.payload = None
        r.eta_ps = -1
        return r
    return WRequest(kind, gpu, space, addr, psize, cu, wf)


def _wreq_free(r: WRequest) -> None:
    if len(_REQ_POOL) < _REQ_POOL_CAP:
        r.wf = None
        r.cu = None
        r.route = None
        _REQ_POOL.append(r)


class _WGExec:
    """A workgroup resident on a CU."""
    __slots__ = ("wg", "kernel", "wavefronts", "nop_arrived", "barrier_arrived")

    def __init__(self, wg: Workgroup, kernel: Kernel, ctx: OpContext):
        self.wg = wg
        self.kernel = kernel
        self.wavefronts = [WavefrontState(i, wg, ctx)
                           for i in range(wg.num_wavefronts)]
        for w in self.wavefronts:
            w.owner = self
        self.nop_arrived = 0
        self.barrier_arrived = False

    def done(self) -> bool:
        return all(w.retired() for w in self.wavefronts)


class _KernelExec:
    __slots__ = ("kernel", "remaining_wgs", "pending", "barrier_count",
                 "barrier_total", "barrier_wgs")

    def __init__(self, kernel: Kernel):
        self.kernel = kernel
        self.remaining_wgs = len(kernel.workgroups)
        self.pending: List[Workgroup] = list(kernel.workgroups)
        self.barrier_count = 0
        self.barrier_total = len(kernel.workgroups)
        self.barrier_wgs: List[_WGExec] = []


class ComputeUnit(InjectionSource):
    __slots__ = ("gpu", "idx", "resident", "outstanding", "_rr",
                 "_scheduled", "_busy_until", "node", "_ticking",
                 "_wake_again", "_order", "_cyc_ps", "_bound",
                 "reqtab", "resptab", "_wake_heap", "_tick_at",
                 "_ext_risk", "_remote_sem", "in_links")

    def __init__(self, gpu: "GpuModel", idx: int, node: int):
        self.gpu = gpu
        self.idx = idx
        self.node = node                 # fabric node id of this CU
        self.resident: List[_WGExec] = []
        self.outstanding = 0
        self._rr = 0
        self._scheduled = False
        self._busy_until = 0.0           # REDUCE occupancy
        self._ticking = False            # a batch scan is on the stack
        self._wake_again = False         # state changed mid-scan: rescan
        self._order: Optional[List[Tuple["_WGExec", WavefrontState]]] = None
        self._cyc_ps = int(round(gpu.config.cycle_ns * 1000))
        self._bound: Optional[int] = None   # current batch's commit bound
        # per-target-GPU multipath route tables, built by
        # Cluster.warm_routes: reqtab[gid] = (period, routes, dst_nodes),
        # resptab[gid] = (period, routes); indexed by cache-line residue
        self.reqtab: Optional[list] = None
        self.resptab: Optional[list] = None
        # ---- reservation-ledger injection source -----------------------
        # wake heap: every tick at which this CU could next act — its
        # scheduled issue slot plus each response delivery the fabric has
        # committed toward it (the fabric pushes those as the CU node's
        # sink, see Cluster.warm_routes)
        self._wake_heap: List[int] = []
        self._tick_at = -1               # tick of the scheduled _tick event
        self._ext_risk = False           # barrier-parked: siblings may wake
        self._remote_sem = 0             # wavefronts waiting on a sem homed
                                         # on another GPU (its bumps floor
                                         # THAT GPU's ledger, not ours)
        self.in_links: list = []         # links delivering at this CU node

    # ----------------------------------------------------------------- wake
    def wake(self) -> None:
        if self._scheduled:
            return
        if self._ticking:
            # an issue scan is on the stack (e.g. a sync just resolved
            # inside it): tell it to rescan instead of recursing
            self._wake_again = True
            return
        eng = self.gpu.engine
        delay = self._busy_until - eng.now
        if delay <= 0.0:
            # nothing to wait for: issue now, saving a zero-delay heap event
            # (this runs inside the waking event, e.g. a response delivery)
            self._tick()
            return
        self._schedule_tick(eng._now_ps + int(round(delay * 1000)))

    def wake_deferred(self) -> None:
        """Schedule a tick instead of issuing inline (used by kernel
        dispatch so that ``Cluster.dispatch`` never executes model code
        synchronously — e.g. a cooperative-launch violation surfaces from
        ``run()``, not from the dispatch call)."""
        if self._scheduled:
            return
        if self._ticking:
            self._wake_again = True
            return
        eng = self.gpu.engine
        delay = max(0.0, self._busy_until - eng.now)
        self._schedule_tick(eng._now_ps + int(round(delay * 1000)))

    def _schedule_tick(self, at_ps: int) -> None:
        """Schedule ``_tick`` at an absolute tick, recording it in the wake
        heap so the ledger's injection bound sees the upcoming issue slot."""
        self._scheduled = True
        self._tick_at = at_ps
        _heappush(self._wake_heap, at_ps)
        self.gpu.engine.schedule_abs_ps(at_ps, self._tick,
                                        region=self.gpu.region)

    # ------------------------------------------------- ledger (fabric hook)
    def inj_pair(self, need: int, depth: int) -> Tuple[int, int]:
        """Earliest tick a *new* message can leave this CU, in both clock
        grades (see :class:`repro.core.network.fabric.InjectionSource`);
        ``(-1, -1)`` when ``need`` cannot be proven.

        The CU can only inject from an issue scan, and every way a scan can
        start before ``need`` is visible here: its scheduled tick and the
        response deliveries committed toward it are in the wake heap;
        semaphore releases that could re-poll are in the GPU's sem floor;
        dispatches ride untagged events (the engine's untagged floor);
        responses not yet committed must still cross this CU's inbound
        links (their channel clocks).  Barrier-parked CUs and CUs that
        could receive fresh workgroups can be woken by arbitrary sibling
        events, and a CU mid-scan is issuing right now — those answer
        ``now`` (refuted for any future ``need``, and never worth caching).
        Cross-event soundness of the heap/floor terms rides the engine's
        ledger generation: semaphore-floor pushes, kernel dispatches and
        untagged events all bump it, and sink pushes are committed
        deliveries the inbound-link clocks already bounded.
        """
        gpu = self.gpu
        eng = gpu.engine
        now = eng._now_ps
        if self._ticking or self._ext_risk or self._remote_sem:
            return now, now
        if len(self.resident) < gpu.config.max_wg_per_cu and \
                (gpu._has_pending or not gpu.cluster.sealed):
            return now, now
        v = _FAR
        h = self._wake_heap
        while h and h[0] < now:
            _heappop(h)
        if h and h[0] < v:
            v = h[0]
        sf = gpu._sem_floor
        while sf and sf[0] < now:
            _heappop(sf)
        if sf and sf[0] < v:
            v = sf[0]
        u = eng.untagged_floor_ps()
        if u < v:
            v = u
        if v < need:
            return -1, -1
        vl = va = v
        gen = eng._led_gen
        ep = eng.events_processed
        no_hz = eng._no_hz
        d1 = depth - 1
        for l in self.in_links:
            if l._geL_g == gen and need <= l._geL_v:
                eng.led_hits += 1
                fl = fa = l._geL_v
            else:
                fl, fa = _clock_eval(l, need, d1, eng, ep, now, no_hz, gen)
                if fa < need:
                    return -1, -1
            if fl < vl:
                vl = fl
            if fa < va:
                va = fa
        return vl, va

    def inj_ge(self, need: int, depth: int) -> bool:
        return self.inj_pair(need, depth)[1] >= need

    # ----------------------------------------------------------------- tick
    def _tick(self) -> None:
        """Issue instructions, batching consecutive cycles into one event.

        The classic cadence is one heap event per issued instruction (one
        per cycle).  Since nothing can change this CU's issue decisions
        before (a) the earliest pending event of its region and (b) the
        soonest possible completion of a request issued in this very batch
        (one memory access latency away, thanks to the response fold), the
        cadence can run ahead on *virtual* time, injecting each Wavefront
        Request at its exact future issue tick via the fabric's monotone
        ``send_at`` — identical times, one heap event per stall instead of
        per instruction.  Syncs, barriers and retirements always process on
        a real event (the batch re-schedules itself for them).

        The commit bound is computed once, at batch start, *before* the
        batch pushes its own events: the only state changes those pushes can
        cause are request completions, which the ``completion_guard`` term
        already covers — so the pre-push horizon is sound, and the batch is
        not cut short by its own in-flight traffic.

        With the reservation ledger enabled, a batch that runs out of
        region horizon keeps going while it can *prove* no earlier wake:
        nothing in the CU's wake heap, semaphore floor, untagged events, or
        uncommitted inbound traffic (channel clocks of its inbound links)
        lands before the next issue slot.  Sync-parked wavefronts and CUs
        that could receive fresh workgroups disable the extension — those
        are woken by sibling events only the horizon can see.
        """
        self._scheduled = False
        ta = self._tick_at
        if ta >= 0:
            # retire this event's own entry from the wake heap
            self._tick_at = -1
            h = self._wake_heap
            while h and h[0] < ta:
                _heappop(h)
            if h and h[0] == ta:
                _heappop(h)
        if not self.resident:
            return
        gpu = self.gpu
        eng = gpu.engine
        cycle_ns = gpu.config.cycle_ns
        cyc_ps = self._cyc_ps
        now_ps = eng.now_ps
        t_ps = now_ps
        cap = now_ps + gpu.completion_guard_ps
        self._bound = eng.horizon_ps(gpu.region, gpu.region_guard_ps,
                                     cap_ps=cap)
        bound = self._bound
        extend = gpu.fabric.ledger and not self._ext_risk and not (
            len(self.resident) < gpu.config.max_wg_per_cu
            and (gpu._has_pending or not gpu.cluster.sealed))
        if extend:
            for wgx in self.resident:
                for wf in wgx.wavefronts:
                    if wf.waiting == "sync":
                        extend = False      # sibling events may release it
                        break
                else:
                    continue
                break
        self._ticking = True
        # the batch issues at future virtual ticks that no pending heap
        # event reflects: response chains folded into this batch's request
        # walks must rely on ledger evidence alone (Engine._batch).  A
        # *nested* batch (a barrier release inline-waking a sibling CU from
        # the arriving CU's scan) is a second concurrent issuer the horizon
        # is equally blind to — its request chains drop horizon proofs too
        # (the outer CU's injection source refuses via ``_ticking``).
        batch_prev = eng._batch
        nohz_prev = eng._no_hz
        eng._batch = True
        if batch_prev:
            eng._no_hz = True
        try:
            while True:
                self._wake_again = False
                res = self._scan(t_ps)
                if res == 0:                  # idle
                    if self._wake_again:
                        continue
                    return
                if res < 0:                   # sync/retire needs real event
                    self._schedule_tick(t_ps)
                    return
                # next issue slot, same arithmetic as the event cadence
                if res == 1:
                    delay = self._busy_until - t_ps / 1000.0
                    if delay < cycle_ns:
                        delay = cycle_ns
                    nt = t_ps + int(round(delay * 1000))
                else:                         # bulk streak of ``res`` lines
                    nt = t_ps + res * cyc_ps
                if nt >= bound:
                    if extend and nt < cap and self._issue_floor_ge(nt + 1):
                        bound = nt + 1        # proven: no wake before nt+1
                        self._bound = bound
                    else:
                        self._schedule_tick(nt)
                        return
                t_ps = nt
        finally:
            self._ticking = False
            eng._batch = batch_prev
            eng._no_hz = nohz_prev

    def _issue_floor_ge(self, need: int) -> bool:
        """True iff provably nothing can change this CU's issue decisions
        before tick ``need`` (the ledger extension of the batch bound)."""
        if self._ext_risk or self._remote_sem:
            # set mid-batch (e.g. a barrier arrival in a real-time scan
            # while another resident workgroup keeps issuing): arbitrary
            # sibling events — or a remote GPU's semaphore bump — may
            # change the picture, and only the horizon sees those
            return False
        gpu = self.gpu
        eng = gpu.engine
        now = eng._now_ps
        h = self._wake_heap
        while h and h[0] < now:
            _heappop(h)
        if h and h[0] < need:
            return False
        sf = gpu._sem_floor
        while sf and sf[0] < now:
            _heappop(sf)
        if sf and sf[0] < need:
            return False
        if eng.untagged_floor_ps() < need:
            return False
        depth = eng.led_depth
        for l in self.in_links:
            if not _clock_ge(l, need, depth):
                return False
        return True

    def _scan(self, t_ps: int) -> int:
        """One cadence step at (virtual) tick ``t_ps``.

        Returns the number of issue slots consumed (1, or the streak length
        for a bulk emission), 0 if nothing is issuable, -1 if a sync/retire
        was encountered ahead of real time (the caller must re-enter on a
        real event at ``t_ps``).
        """
        real = t_ps <= self.gpu.engine.now_ps
        order = self._order
        if order is None:
            order = [(wgx, wf) for wgx in self.resident
                     for wf in wgx.wavefronts]
            self._order = order
        k = len(order)
        start = self._rr % k if k else 0
        gpu = self.gpu
        maxo = gpu.config.max_outstanding
        for i in range(k):
            wgx, wf = order[(start + i) % k]
            if wf.done or wf.waiting is not None:
                if wf.done and wf.outstanding == 0:
                    # a virtual-time scan may have exhausted this wavefront
                    # and then aborted to a real event before retiring:
                    # retirement must be retried here
                    if not real:
                        return -1
                    self._maybe_retire(wgx)
                continue
            e = wf.next_entry()
            if e is None:
                if wf.done:
                    if not real:
                        return -1
                    self._maybe_retire(wgx)
                    continue
                # the cursor advanced onto a sync op — possibly just now,
                # after exhausting an op's stream (the seed's lost-barrier
                # deadlock: this arrival used to be dropped)
                if not real:
                    return -1
                self._handle_sync(wgx, wf, wf.peek_sync())
                continue
            kind = e[0]
            if kind <= STORE:                 # LOAD / STORE: the data path
                if self.outstanding >= maxo:
                    continue                  # register file full: next wf
                n = 1
                ready = None
                if gpu.bulk:
                    run = wf.runs[wf.pc]
                    if run > 1:
                        n, ready = self._streak_rr(order, start + i, k, wf,
                                                   run, t_ps, maxo)
                if n > 1:
                    if len(ready) > 1:
                        gpu.cluster.send_request_bulk_rr(self, ready, n, t_ps)
                        # resume the rotation after the last issuing wf
                        self._rr = (ready[(n - 1) % len(ready)][0] + 1) % k
                    else:
                        gpu.cluster.send_request_bulk(self, wf, n, t_ps)
                        self._rr = (start + i + 1) % k
                else:
                    wf.outstanding += 1
                    self.outstanding += 1
                    gpu.cluster.send_request(
                        _wreq(kind, e[1], e[2], e[3], e[4], self, wf),
                        t_ps)
                    wf.pc += 1
                    self._rr = (start + i + 1) % k
                return n
            if self._issue_ctrl(wf, e, kind, t_ps):
                wf.pc += 1
                self._rr = (start + i + 1) % k
                return 1
        return 0

    # ---------------------------------------------------------------- issue
    def _streak_rr(self, order, istart: int, k: int, wf: WavefrontState,
                   run: int, t_ps: int, maxo: int):
        """How many issue slots may be emitted in one batch, and by whom.

        Bulk emission must reproduce the per-cycle cadence exactly.  The
        per-cycle scan rotates through the ready wavefronts in cyclic scan
        order, one load/store line per cycle; that rotation is stable —
        and therefore batchable — as long as every non-ready wavefront
        stays blocked (they can only unblock via an event, which the
        commit bound excludes) and every ready wavefront sits in an
        uninterrupted load/store run.  So the batch is the ready set's
        round-robin stripe, cut at the shortest run boundary (where the
        ready set would change), capped by register-file headroom and by
        the batch commit bound on the issue ticks.

        Returns ``(n, ready)`` with ``ready`` the ``(scan position,
        wavefront)`` list in rotation order starting at ``wf``, or
        ``(1, None)`` when only a single per-instruction issue is safe
        (e.g. a sibling is parked on a sync boundary this batch must not
        cross).
        """
        ready = [(istart % k, wf)]
        kmin = run
        for j in range(1, k):
            p = (istart + j) % k
            w2 = order[p][1]
            if w2.done or w2.waiting is not None:
                continue
            e2 = w2.next_entry()
            if e2 is None or e2[0] > STORE:
                # sync/retire/control boundary mid-rotation: the ready set
                # would mutate, so fall back to per-instruction issue
                return 1, None
            r2 = w2.runs[w2.pc]
            ready.append((p, w2))
            if r2 < kmin:
                kmin = r2
        n = len(ready) * kmin
        cap = maxo - self.outstanding
        if cap < n:
            n = cap
        if n <= 1:
            return 1, None
        bound = self._bound
        if bound is not None:
            # issue ticks t, t+cyc, ... must stay strictly below the bound
            fit = (bound - 1 - t_ps) // self._cyc_ps + 1
            if fit < n:
                n = fit
        return (n, ready) if n > 1 else (1, None)

    def _issue_ctrl(self, wf: WavefrontState, e: tuple, kind: int,
                    t_ps: int) -> bool:
        """Issue a non-load/store entry.  Returns True if it consumed the
        issue slot for this cycle."""
        if kind == WAITCNT:
            if wf.outstanding <= e[5]:
                return True              # fence satisfied: costs one cycle
            wf.waiting = "waitcnt"
            wf.wait_thresh = e[5]        # re-check on completion
            return False
        if kind == REDUCE:
            self._busy_until = t_ps / 1000.0 + e[5] * self.gpu.config.cycle_ns
            return True
        # semaphore instruction (control-path memory op)
        if self.outstanding >= self.gpu.config.max_outstanding:
            return False                 # register file full: try another wf
        hdr = self.gpu.config.header_bytes
        if kind == SEM_ACQUIRE:
            # poll: issue a control-class load of the semaphore line; the
            # wavefront blocks until the poll observes value >= expected.
            wf.waiting = "sem"
            if e[1] != self.gpu.gid:
                self._remote_sem += 1
            req = _wreq(kind, e[1], e[2], e[3], hdr, self, wf)
            req.value = e[5]             # expected count rides along
            self._inject(req, t_ps)
            return True
        # SEM_RELEASE
        req = _wreq(kind, e[1], e[2], e[3], hdr, self, wf)
        wf.outstanding += 1
        self._inject(req, t_ps)
        return True

    def _inject(self, req: WRequest, at_ps: Optional[int] = None) -> None:
        self.outstanding += 1
        if at_ps is None:
            at_ps = self.gpu.engine.now_ps
        self.gpu.cluster.send_request(req, at_ps)

    # ------------------------------------------------------------ completion
    def complete(self, req: WRequest) -> None:
        self.outstanding -= 1
        wf = req.wf
        kind = req.kind
        if kind == SEM_ACQUIRE:
            gid = req.gpu
            addr = req.addr
            expected = req.value if req.value else 1
            _wreq_free(req)              # final consumer: recycle
            sem_home = self.gpu.cluster.gpus[gid]
            if sem_home.sem_value(addr) >= expected:
                wf.waiting = None
                if gid != self.gpu.gid:
                    self._remote_sem -= 1
                self.wake()
            else:
                # subscribe: when a release bumps this semaphore, re-poll.
                sem_home.sem_subscribe(addr, self, wf, expected)
            return
        _wreq_free(req)                  # final consumer: recycle
        wf.outstanding -= 1
        if wf.waiting == "waitcnt" and wf.outstanding <= wf.wait_thresh:
            wf.waiting = None
            wf.pc += 1                   # consume the satisfied fence
        if wf.done and wf.outstanding == 0 and wf.owner is not None:
            self._maybe_retire(wf.owner)
        self.wake()

    def repoll(self, wf: WavefrontState, gpu: int, addr: int,
               expected: int) -> None:
        """Re-issue a semaphore poll after a release event."""
        req = _wreq(SEM_ACQUIRE, gpu, _SEM_SPACE, addr,
                    self.gpu.config.header_bytes, self, wf)
        req.value = expected
        self._inject(req)

    # ----------------------------------------------------------------- syncs
    def _handle_sync(self, wgx: _WGExec, wf: WavefrontState, sync: str) -> None:
        wf.waiting = "sync"
        if sync == "nop":
            wgx.nop_arrived += 1
            if wgx.nop_arrived == len(wgx.wavefronts):
                wgx.nop_arrived = 0
                for w in wgx.wavefronts:
                    w.waiting = None
                    w.advance_sync()
                self.wake()
        else:  # barrier: whole-kernel sync
            if all(w.waiting == "sync" or w.done for w in wgx.wavefronts) \
                    and not wgx.barrier_arrived:
                wgx.barrier_arrived = True
                # parked at a kernel barrier: an arbitrary sibling CU's
                # event releases it, so the ledger must not prove this CU
                # quiet beyond the region horizon
                self._ext_risk = True
                self.gpu.kernel_barrier_arrive(wgx)

    def barrier_release(self, wgx: _WGExec) -> None:
        wgx.barrier_arrived = False
        self._ext_risk = any(w.barrier_arrived for w in self.resident)
        for w in wgx.wavefronts:
            if not w.done:
                w.waiting = None
                w.advance_sync()
        self.wake()

    # ---------------------------------------------------------------- retire
    def _maybe_retire(self, wgx: _WGExec) -> None:
        if not wgx.done() or wgx not in self.resident:
            return
        self.resident.remove(wgx)
        self._order = None
        self.gpu.wg_retired(self, wgx)


class GpuModel:
    """One GPU: CUs + HBM channels + I/O ports on a fabric."""

    def __init__(self, gid: int, config: GpuConfig, engine: Engine,
                 fabric: Fabric, cluster: "Cluster",
                 cu_nodes: List[int], hbm_nodes: List[int],
                 io_nodes: List[int], region: int = 0,
                 region_guard_ps: int = 0, bulk: bool = True):
        self.gid = gid
        self.region = region
        self.region_guard_ps = region_guard_ps
        # soonest a request issued now can complete: it must at least reach
        # its memory endpoint and pay the access latency (response folding
        # guarantees nothing returns faster)
        self.completion_guard_ps = int(round(config.hbm_latency_ns * 1000))
        self.config = config
        self.engine = engine
        self.fabric = fabric
        self.cluster = cluster
        self.bulk = bulk
        self.cus = [ComputeUnit(self, i, cu_nodes[i]) for i in range(config.num_cus)]
        self.hbm_nodes = hbm_nodes
        self.io_nodes = io_nodes
        self._next_cu = 0
        self._kernels: Dict[int, _KernelExec] = {}
        self._sems: Dict[int, int] = {}
        self._sem_waiters: Dict[int, List[Tuple[ComputeUnit, WavefrontState, int]]] = {}
        self._wg_to_kernel: Dict[int, _KernelExec] = {}
        # ledger floors: ticks of scheduled semaphore bumps on this GPU, and
        # whether any kernel still has undispatched workgroups (a sibling
        # retirement could then hand work to an idle CU at its own tick)
        self._sem_floor: List[int] = []
        self._has_pending = False

    # --------------------------------------------------------------- dispatch
    def dispatch(self, kernel: Kernel) -> None:
        # direct dispatch between runs schedules tagged tick events no
        # cached cross-event clock value could have seen: new generation
        self.engine._led_gen += 1
        kx = _KernelExec(kernel)
        kernel.start_ns = self.engine.now
        self._kernels[kernel.kid] = kx
        self._fill(kx)

    def _fill(self, kx: _KernelExec) -> None:
        """Map pending workgroups onto free CUs round-robin (paper §4.4.1)."""
        n = len(self.cus)
        attempts = 0
        while kx.pending and attempts < n:
            cu = self.cus[self._next_cu % n]
            self._next_cu += 1
            attempts += 1
            if len(cu.resident) < self.config.max_wg_per_cu:
                wg = kx.pending.pop(0)
                wgx = _WGExec(wg, kx.kernel, self.config.op_context())
                self._wg_to_kernel[id(wgx)] = kx
                cu.resident.append(wgx)
                cu._order = None
                cu.wake_deferred()
                attempts = 0
        self._has_pending = any(k.pending for k in self._kernels.values())

    def wg_retired(self, cu: ComputeUnit, wgx: _WGExec) -> None:
        kx = self._wg_to_kernel.pop(id(wgx))
        kx.remaining_wgs -= 1
        if kx.remaining_wgs == 0:
            kx.kernel.end_ns = self.engine.now
            del self._kernels[kx.kernel.kid]
            if kx.kernel.on_done:
                kx.kernel.on_done(kx.kernel, self.engine.now)
        # refill: this kernel first, then any other with pending work
        for other in list(self._kernels.values()):
            if other.pending:
                self._fill(other)

    # -------------------------------------------------------------- barriers
    def kernel_barrier_arrive(self, wgx: _WGExec) -> None:
        kx = self._wg_to_kernel[id(wgx)]
        kx.barrier_count += 1
        kx.barrier_wgs.append(wgx)
        if kx.pending:
            raise RuntimeError(
                f"kernel {kx.kernel.name}: BarrierOp with undispatched "
                f"workgroups (needs cooperative-launch residency)")
        if kx.barrier_count == kx.barrier_total:
            kx.barrier_count = 0
            wgs, kx.barrier_wgs = kx.barrier_wgs, []
            for w in wgs:
                for cu in self.cus:
                    if w in cu.resident:
                        cu.barrier_release(w)
                        break

    # ------------------------------------------------------------ semaphores
    def sem_value(self, addr: int) -> int:
        return self._sems.get(addr, 0)

    def sem_bump(self, addr: int) -> None:
        self._sems[addr] = self._sems.get(addr, 0) + 1
        waiters = self._sem_waiters.pop(addr, None)
        if waiters:
            for cu, wf, expected in waiters:
                cu.repoll(wf, self.gid, addr, expected)

    def sem_subscribe(self, addr: int, cu: ComputeUnit, wf: WavefrontState,
                      expected: int) -> None:
        self._sem_waiters.setdefault(addr, []).append((cu, wf, expected))

    def reset_sems(self) -> None:
        self._sems.clear()
        self._sem_waiters.clear()

    # ------------------------------------------------------- memory endpoints
    def hbm_node_for(self, addr: int, space: int) -> int:
        ch = (addr // self.config.cache_line) % len(self.hbm_nodes)
        return self.hbm_nodes[ch]

    def io_node_for(self, key: int) -> int:
        return self.io_nodes[key % len(self.io_nodes)]
