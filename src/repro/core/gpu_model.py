"""GPU execution model (paper §4.4).

``GpuModel`` abstracts one physical GPU: it owns compute units (CUs), maps
dispatched kernels' workgroups onto free CUs round-robin, and injects
cache-line-sized *Wavefront Requests* into the network fabric.  A CU issues
at most one instruction per cycle, arbitrating between the ready wavefronts
of its resident workgroups (wavefront-level parallelism); in-flight memory
traffic is bounded per-CU (``max_outstanding`` — the paper's register-file
proxy, Fig. 13) and per-wavefront fences are modeled via ``Waitcnt``.

Memory-side behavior (HBM channels servicing loads/stores, semaphore
homes) lives here too: endpoint handlers attached to fabric nodes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .engine import Engine
from .instructions import IKind, Instruction, MemRef, Space
from .operations import OpContext
from .network.fabric import CONTROL, DATA, Fabric, Flight, Link
from .workload import Kernel, WavefrontState, Workgroup


@dataclass
class GpuConfig:
    """Architecture knobs (defaults: paper §5.1 generic GPU, scaled down)."""
    num_cus: int = 16
    cache_line: int = 128            # bytes per Wavefront Request
    cycle_ns: float = 1.0            # CU clock (1 GHz)
    max_outstanding: int = 32        # per-CU in-flight Wavefront Requests
    max_wg_per_cu: int = 1
    unroll: int = 1                  # default loop-unrolling factor (Fig. 12)
    reduce_cycles_per_line: int = 1
    header_bytes: int = 32           # request/ack header size
    hbm_latency_ns: float = 80.0     # channel access latency
    wavefronts_per_wg: int = 4

    def op_context(self) -> OpContext:
        return OpContext(cache_line=self.cache_line, unroll=self.unroll,
                         reduce_cycles_per_line=self.reduce_cycles_per_line)


class WRequest:
    """One Wavefront Request round-trip (paper §4.4.3)."""
    __slots__ = ("kind", "mem", "size", "cu", "wf", "value", "issued_ns")

    def __init__(self, kind: IKind, mem: MemRef, size: int, cu: "ComputeUnit",
                 wf: Optional[WavefrontState]):
        self.kind = kind
        self.mem = mem
        self.size = size
        self.cu = cu
        self.wf = wf
        self.value = 0          # semaphore value carried by poll responses
        self.issued_ns = 0.0


class _WGExec:
    """A workgroup resident on a CU."""
    __slots__ = ("wg", "kernel", "wavefronts", "nop_arrived", "barrier_arrived")

    def __init__(self, wg: Workgroup, kernel: Kernel, ctx: OpContext):
        self.wg = wg
        self.kernel = kernel
        self.wavefronts = [WavefrontState(i, wg, ctx)
                           for i in range(wg.num_wavefronts)]
        for w in self.wavefronts:
            w.owner = self
        self.nop_arrived = 0
        self.barrier_arrived = False

    def done(self) -> bool:
        return all(w.retired() for w in self.wavefronts)


class _KernelExec:
    __slots__ = ("kernel", "remaining_wgs", "pending", "barrier_count",
                 "barrier_total", "barrier_wgs")

    def __init__(self, kernel: Kernel):
        self.kernel = kernel
        self.remaining_wgs = len(kernel.workgroups)
        self.pending: List[Workgroup] = list(kernel.workgroups)
        self.barrier_count = 0
        self.barrier_total = len(kernel.workgroups)
        self.barrier_wgs: List[_WGExec] = []


class ComputeUnit:
    __slots__ = ("gpu", "idx", "resident", "outstanding", "_rr",
                 "_scheduled", "_busy_until", "node", "waiters_waitcnt",
                 "_ticking", "_wake_again", "_order")

    def __init__(self, gpu: "GpuModel", idx: int, node: int):
        self.gpu = gpu
        self.idx = idx
        self.node = node                 # fabric node id of this CU
        self.resident: List[_WGExec] = []
        self.outstanding = 0
        self._rr = 0
        self._scheduled = False
        self._busy_until = 0.0           # REDUCE occupancy
        self._ticking = False            # a batch scan is on the stack
        self._wake_again = False         # state changed mid-scan: rescan
        self._order: Optional[List[Tuple["_WGExec", WavefrontState]]] = None

    # ----------------------------------------------------------------- wake
    def wake(self) -> None:
        if self._scheduled:
            return
        if self._ticking:
            # an issue scan is on the stack (e.g. a sync just resolved
            # inside it): tell it to rescan instead of recursing
            self._wake_again = True
            return
        now = self.gpu.engine.now
        delay = self._busy_until - now
        if delay <= 0.0:
            # nothing to wait for: issue now, saving a zero-delay heap event
            # (this runs inside the waking event, e.g. a response delivery)
            self._tick()
            return
        self._scheduled = True
        self.gpu.engine.schedule(delay, self._tick, region=self.gpu.region)

    def wake_deferred(self) -> None:
        """Schedule a tick instead of issuing inline (used by kernel
        dispatch so that ``Cluster.dispatch`` never executes model code
        synchronously — e.g. a cooperative-launch violation surfaces from
        ``run()``, not from the dispatch call)."""
        if self._scheduled:
            return
        if self._ticking:
            self._wake_again = True
            return
        self._scheduled = True
        delay = max(0.0, self._busy_until - self.gpu.engine.now)
        self.gpu.engine.schedule(delay, self._tick, region=self.gpu.region)

    # ----------------------------------------------------------------- tick
    def _tick(self) -> None:
        """Issue instructions, batching consecutive cycles into one event.

        The classic cadence is one heap event per issued instruction (one
        per cycle).  Since nothing can change this CU's issue decisions
        before (a) the earliest pending event of its region and (b) the
        soonest possible completion of a request issued in this very batch
        (one memory access latency away, thanks to the response fold), the
        cadence can run ahead on *virtual* time, injecting each Wavefront
        Request at its exact future issue tick via the fabric's monotone
        ``send_at`` — identical times, one heap event per stall instead of
        per instruction.  Syncs, barriers and retirements always process on
        a real event (the batch re-schedules itself for them).
        """
        self._scheduled = False
        if not self.resident:
            return
        gpu = self.gpu
        eng = gpu.engine
        cycle_ns = gpu.config.cycle_ns
        now_ps = eng.now_ps
        t_ps = now_ps
        bound = None
        self._ticking = True
        try:
            while True:
                self._wake_again = False
                res = self._scan(t_ps)
                if res == 0:                  # idle
                    if self._wake_again:
                        continue
                    return
                if res == 2:                  # sync/retire needs real event
                    self._scheduled = True
                    eng.schedule_abs_ps(t_ps, self._tick, region=gpu.region)
                    return
                # next issue slot, same arithmetic as the event cadence
                delay = self._busy_until - t_ps / 1000.0
                if delay < cycle_ns:
                    delay = cycle_ns
                nt = t_ps + int(round(delay * 1000))
                if bound is None:
                    bound = self._issue_bound(eng, now_ps)
                if nt >= bound:
                    self._scheduled = True
                    eng.schedule_abs_ps(nt, self._tick, region=gpu.region)
                    return
                t_ps = nt
        finally:
            self._ticking = False

    def _issue_bound(self, eng, now_ps: int) -> int:
        """Latest tick (exclusive) this batch may issue at without missing
        a state change: the region lookahead horizon, capped by the soonest
        completion a request issued in this batch could produce."""
        gpu = self.gpu
        bound = eng.peek_region(gpu.region)
        if gpu.region:
            gmin = eng.peek_ps()
            if gmin is not None:
                cap = gmin + gpu.region_guard_ps
                if bound is None or cap < bound:
                    bound = cap
        own = now_ps + gpu.completion_guard_ps
        if bound is None or own < bound:
            bound = own
        return bound

    def _scan(self, t_ps: int) -> int:
        """One cadence step at (virtual) tick ``t_ps``.

        Returns 1 if an instruction was issued, 0 if nothing is issuable,
        2 if a sync/retire was encountered ahead of real time (the caller
        must re-enter on a real event at ``t_ps``).
        """
        real = t_ps <= self.gpu.engine.now_ps
        order = self._order
        if order is None:
            order = [(wgx, wf) for wgx in self.resident
                     for wf in wgx.wavefronts]
            self._order = order
        k = len(order)
        start = self._rr % k if k else 0
        for i in range(k):
            wgx, wf = order[(start + i) % k]
            if wf.done or wf.waiting is not None:
                if wf.done and wf.outstanding == 0:
                    # a virtual-time scan may have exhausted this wavefront
                    # (fetch sets ``done``) and then aborted to a real event
                    # before retiring: retirement must be retried here
                    if not real:
                        return 2
                    self._maybe_retire(wgx)
                continue
            sync = wf.peek_sync()
            if sync is not None:
                if not real:
                    return 2
                self._handle_sync(wgx, wf, sync)
                continue
            ins = wf.fetch()
            if ins is None:
                # wavefront finished all ops
                if wf.done:
                    if not real:
                        return 2
                    self._maybe_retire(wgx)
                continue
            if self._issue(wgx, wf, ins, t_ps):
                wf.consume()
                self._rr = (start + i + 1) % k
                return 1
        return 0

    # ---------------------------------------------------------------- issue
    def _issue(self, wgx: _WGExec, wf: WavefrontState, ins: Instruction,
               t_ps: int) -> bool:
        """Try to issue one instruction at tick ``t_ps``.  Returns True if
        it consumed the issue slot for this cycle."""
        kind = ins.kind
        if kind == IKind.WAITCNT:
            if wf.outstanding <= ins.threshold:
                return True              # fence satisfied: costs one cycle
            wf.waiting = "waitcnt"
            wf.fetched = ins             # re-check on completion
            return False
        if kind == IKind.REDUCE:
            self._busy_until = t_ps / 1000.0 + ins.cycles * self.gpu.config.cycle_ns
            return True
        # memory instruction
        if self.outstanding >= self.gpu.config.max_outstanding:
            return False                 # register file full: try another wf
        if kind == IKind.SEM_ACQUIRE:
            # poll: issue a control-class load of the semaphore line; the
            # wavefront blocks until the poll observes value >= expected.
            wf.waiting = "sem"
            req = WRequest(kind, ins.mem, self.gpu.config.header_bytes, self, wf)
            req.value = ins.threshold    # expected count rides along
            self._inject(req, t_ps)
            return True
        if kind == IKind.SEM_RELEASE:
            req = WRequest(kind, ins.mem, self.gpu.config.header_bytes, self, wf)
            wf.outstanding += 1
            self._inject(req, t_ps)
            return True
        # LOAD / STORE
        req = WRequest(kind, ins.mem, ins.size, self, wf)
        wf.outstanding += 1
        self._inject(req, t_ps)
        return True

    def _inject(self, req: WRequest, at_ps: Optional[int] = None) -> None:
        self.outstanding += 1
        if at_ps is None:
            at_ps = self.gpu.engine.now_ps
        req.issued_ns = at_ps / 1000.0
        self.gpu.cluster.send_request(req, at_ps)

    # ------------------------------------------------------------ completion
    def complete(self, req: WRequest) -> None:
        self.outstanding -= 1
        wf = req.wf
        if req.kind == IKind.SEM_ACQUIRE:
            sem_home = self.gpu.cluster.gpus[req.mem.gpu]
            expected = req.value if req.value else 1
            cur = sem_home.sem_value(req.mem.addr)
            if cur >= expected:
                wf.waiting = None
                self.wake()
            else:
                # subscribe: when a release bumps this semaphore, re-poll.
                sem_home.sem_subscribe(req.mem.addr, self, wf, expected)
            return
        wf.outstanding -= 1
        if wf.waiting == "waitcnt" and wf.fetched is not None \
                and wf.outstanding <= wf.fetched.threshold:
            wf.waiting = None
            wf.consume()
        if wf.retired() and wf.owner is not None:
            self._maybe_retire(wf.owner)
        self.wake()

    def repoll(self, wf: WavefrontState, mem: MemRef, expected: int) -> None:
        """Re-issue a semaphore poll after a release event."""
        req = WRequest(IKind.SEM_ACQUIRE, mem, self.gpu.config.header_bytes,
                       self, wf)
        req.value = expected
        self._inject(req)

    # ----------------------------------------------------------------- syncs
    def _handle_sync(self, wgx: _WGExec, wf: WavefrontState, sync: str) -> None:
        wf.waiting = "sync"
        if sync == "nop":
            wgx.nop_arrived += 1
            if wgx.nop_arrived == len(wgx.wavefronts):
                wgx.nop_arrived = 0
                for w in wgx.wavefronts:
                    w.waiting = None
                    w.advance_sync()
                self.wake()
        else:  # barrier: whole-kernel sync
            if all(w.waiting == "sync" or w.done for w in wgx.wavefronts) \
                    and not wgx.barrier_arrived:
                wgx.barrier_arrived = True
                self.gpu.kernel_barrier_arrive(wgx)

    def barrier_release(self, wgx: _WGExec) -> None:
        wgx.barrier_arrived = False
        for w in wgx.wavefronts:
            if not w.done:
                w.waiting = None
                w.advance_sync()
        self.wake()

    # ---------------------------------------------------------------- retire
    def _maybe_retire(self, wgx: _WGExec) -> None:
        if not wgx.done() or wgx not in self.resident:
            return
        self.resident.remove(wgx)
        self._order = None
        self.gpu.wg_retired(self, wgx)


class GpuModel:
    """One GPU: CUs + HBM channels + I/O ports on a fabric."""

    def __init__(self, gid: int, config: GpuConfig, engine: Engine,
                 fabric: Fabric, cluster: "Cluster",
                 cu_nodes: List[int], hbm_nodes: List[int],
                 io_nodes: List[int], region: int = 0,
                 region_guard_ps: int = 0):
        self.gid = gid
        self.region = region
        self.region_guard_ps = region_guard_ps
        # soonest a request issued now can complete: it must at least reach
        # its memory endpoint and pay the access latency (response folding
        # guarantees nothing returns faster)
        self.completion_guard_ps = int(round(config.hbm_latency_ns * 1000))
        self.config = config
        self.engine = engine
        self.fabric = fabric
        self.cluster = cluster
        self.cus = [ComputeUnit(self, i, cu_nodes[i]) for i in range(config.num_cus)]
        self.hbm_nodes = hbm_nodes
        self.io_nodes = io_nodes
        self._next_cu = 0
        self._kernels: Dict[int, _KernelExec] = {}
        self._sems: Dict[int, int] = {}
        self._sem_waiters: Dict[int, List[Tuple[ComputeUnit, WavefrontState, int]]] = {}
        self._wg_to_kernel: Dict[int, _KernelExec] = {}

    # --------------------------------------------------------------- dispatch
    def dispatch(self, kernel: Kernel) -> None:
        kx = _KernelExec(kernel)
        kernel.start_ns = self.engine.now
        self._kernels[kernel.kid] = kx
        self._fill(kx)

    def _fill(self, kx: _KernelExec) -> None:
        """Map pending workgroups onto free CUs round-robin (paper §4.4.1)."""
        n = len(self.cus)
        attempts = 0
        while kx.pending and attempts < n:
            cu = self.cus[self._next_cu % n]
            self._next_cu += 1
            attempts += 1
            if len(cu.resident) < self.config.max_wg_per_cu:
                wg = kx.pending.pop(0)
                wgx = _WGExec(wg, kx.kernel, self.config.op_context())
                self._wg_to_kernel[id(wgx)] = kx
                cu.resident.append(wgx)
                cu._order = None
                cu.wake_deferred()
                attempts = 0

    def wg_retired(self, cu: ComputeUnit, wgx: _WGExec) -> None:
        kx = self._wg_to_kernel.pop(id(wgx))
        kx.remaining_wgs -= 1
        if kx.remaining_wgs == 0:
            kx.kernel.end_ns = self.engine.now
            del self._kernels[kx.kernel.kid]
            if kx.kernel.on_done:
                kx.kernel.on_done(kx.kernel, self.engine.now)
        # refill: this kernel first, then any other with pending work
        for other in list(self._kernels.values()):
            if other.pending:
                self._fill(other)

    # -------------------------------------------------------------- barriers
    def kernel_barrier_arrive(self, wgx: _WGExec) -> None:
        kx = self._wg_to_kernel[id(wgx)]
        kx.barrier_count += 1
        kx.barrier_wgs.append(wgx)
        if kx.pending:
            raise RuntimeError(
                f"kernel {kx.kernel.name}: BarrierOp with undispatched "
                f"workgroups (needs cooperative-launch residency)")
        if kx.barrier_count == kx.barrier_total:
            kx.barrier_count = 0
            wgs, kx.barrier_wgs = kx.barrier_wgs, []
            for w in wgs:
                for cu in self.cus:
                    if w in cu.resident:
                        cu.barrier_release(w)
                        break

    # ------------------------------------------------------------ semaphores
    def sem_value(self, addr: int) -> int:
        return self._sems.get(addr, 0)

    def sem_bump(self, addr: int) -> None:
        self._sems[addr] = self._sems.get(addr, 0) + 1
        waiters = self._sem_waiters.pop(addr, None)
        if waiters:
            for cu, wf, expected in waiters:
                cu.repoll(wf, MemRef(self.gid, Space.SEM, addr), expected)

    def sem_subscribe(self, addr: int, cu: ComputeUnit, wf: WavefrontState,
                      expected: int) -> None:
        self._sem_waiters.setdefault(addr, []).append((cu, wf, expected))

    def reset_sems(self) -> None:
        self._sems.clear()
        self._sem_waiters.clear()

    # ------------------------------------------------------- memory endpoints
    def hbm_node_for(self, addr: int, space: Space) -> int:
        ch = (addr // self.config.cache_line) % len(self.hbm_nodes)
        return self.hbm_nodes[ch]

    def io_node_for(self, key: int) -> int:
        return self.io_nodes[key % len(self.io_nodes)]
