"""ASTRA-sim 3.0 reproduction core: fine-grained distributed-ML simulation.

Layers (paper Fig. 1):
  workload  — instructions / operations / workload (Load-Store granularity)
  system    — collectives, mscclpp, chakra, system (kernel decomposition)
  network   — network.fabric (NoC-level) + network.simple (alpha-beta)
  hardware  — gpu_model + cluster (CUs, HBM channels, I/O ports)
  infra     — infragraph (standardized infrastructure representation)
"""

from .engine import Engine
from .instructions import IKind, Instruction, MemRef, Space
from .operations import (BarrierOp, GpuOp, LoadOp, MemcpyOp, NopOp, OpContext,
                         ReduceOp, SemaphoreAcquireOp, SemaphoreReleaseOp,
                         StoreOp)
from .workload import Kernel, Workgroup
from .gpu_model import GpuConfig, GpuModel
from .cluster import Cluster, NocConfig

__all__ = [
    "Engine", "IKind", "Instruction", "MemRef", "Space",
    "GpuOp", "LoadOp", "StoreOp", "MemcpyOp", "ReduceOp", "NopOp",
    "BarrierOp", "SemaphoreAcquireOp", "SemaphoreReleaseOp", "OpContext",
    "Kernel", "Workgroup", "GpuConfig", "GpuModel", "Cluster", "NocConfig",
]
