"""Discrete-event simulation engine.

The heart of the ASTRA-sim 3.0 reproduction: a deterministic, heapq-based
event queue.  Every model component (compute units, NoC links, semaphores,
network interfaces) schedules callbacks here.  Time is kept in integer
*picoseconds* internally to make event ordering exactly deterministic and
immune to float round-off; the public API speaks float nanoseconds.

Lookahead regions
-----------------
Events may carry a *region* tag (0 = untagged/global).  ``peek_region(r)``
returns the earliest pending tick among region-``r`` and untagged events.
The fabric fast path uses this as a per-region lookahead horizon: a GPU's
NoC only receives traffic from its own region's events (plus global ones),
so service can be committed ahead of the global clock without waiting on
unrelated regions — the discrete-event analogue of Chandy-Misra lookahead.
"""

from __future__ import annotations

import gc as _gc
import heapq
import time as _wallclock
from typing import Any, Callable, List, Optional, Tuple

# one nanosecond in internal ticks (picoseconds)
_PS_PER_NS = 1000


class Engine:
    """Deterministic discrete-event engine.

    Events with equal timestamps fire in *tie-key* order, then scheduling
    order (FIFO), which keeps simulations reproducible run-to-run regardless
    of hash seeds.  The key (default 0) exists for the fabric: link-service
    events carry their route's registration-order key, so same-tick service
    ties resolve identically in every scheduling mode (classic/exact/
    coalesce × ledger) instead of by each mode's incidental insertion order.
    """

    __slots__ = ("_queue", "_now_ps", "_seq", "events_processed", "_running",
                 "_wall_start", "_rheaps", "_regioned",
                 "_batch", "_no_hz", "_led_gen",
                 "led_depth", "led_hits", "led_hist")

    def __init__(self) -> None:
        # (tick, key, seq, fn, args, region)
        self._queue: List[Tuple[int, int, int, Callable[..., None], tuple,
                                int]] = []
        self._now_ps: int = 0
        self._seq: int = 0
        self.events_processed: int = 0
        self._running = False
        self._wall_start: Optional[float] = None
        # per-region pending-tick heaps; [0] tracks untagged events.
        # Maintained only once a region exists — engines that never call
        # new_region() (coarse/analytic tiers) skip the mirror bookkeeping.
        self._rheaps: List[List[int]] = [[]]
        self._regioned = False
        # ---- reservation-ledger state (owned per engine so two clusters
        # simulated in one process can never cross-pollute memos) ----------
        # _batch: a CU issue batch is on the stack (ComputeUnit._tick); its
        # future virtual issues leave no pending heap event, so region-
        # horizon proofs are blind to them.  _no_hz: every ahead-of-time
        # commit must be justified by ledger evidence alone (response
        # chains folded into a batch; see fabric module docstring).
        self._batch = False
        self._no_hz = False
        # ledger cache generation: cross-event channel-clock values are
        # valid while this stays unchanged.  Bumped by the rare actions
        # that can lower an already-proven ledger bound from outside the
        # monitored channels: untagged (region-0) event pushes, semaphore-
        # floor pushes, kernel dispatches, and census/wiring changes.
        self._led_gen = 0
        # channel-clock recursion depth budget (Fabric overrides from
        # NocConfig.ledger_depth) and probe observability counters
        self.led_depth = 4
        self.led_hits = 0               # cross-event validity-window hits
        self.led_hist = [0] * 17        # ledger evaluations by depth

    # ------------------------------------------------------------------ time
    @property
    def now(self) -> float:
        """Current simulation time in nanoseconds."""
        return self._now_ps / _PS_PER_NS

    @property
    def now_ps(self) -> int:
        return self._now_ps

    # ------------------------------------------------------------- scheduling
    def new_region(self) -> int:
        """Allocate a lookahead region id (see module docstring)."""
        self._led_gen += 1
        if not self._regioned:
            self._regioned = True
            # backfill the untagged mirror with already-pending events
            self._rheaps[0] = [e[0] for e in self._queue]
            heapq.heapify(self._rheaps[0])
        self._rheaps.append([])
        return len(self._rheaps) - 1

    def _push(self, at_ps: int, fn: Callable[..., None], args: tuple,
              region: int, key: int = 0) -> None:
        heapq.heappush(self._queue, (at_ps, key, self._seq, fn, args, region))
        self._seq += 1
        if region:
            if self._regioned:
                heapq.heappush(self._rheaps[region], at_ps)
        else:
            # untagged events are the ledger's escape hatch (see
            # untagged_floor_ps): a new one may undercut any proven bound
            self._led_gen += 1
            if self._regioned:
                heapq.heappush(self._rheaps[0], at_ps)

    def schedule(self, delay_ns: float, fn: Callable[..., None], *args: Any,
                 region: int = 0, key: int = 0) -> None:
        """Schedule ``fn(*args)`` ``delay_ns`` nanoseconds from now."""
        if delay_ns < 0:
            raise ValueError(f"negative delay: {delay_ns}")
        self._push(self._now_ps + int(round(delay_ns * _PS_PER_NS)), fn, args,
                   region, key)

    def schedule_ps(self, delay_ps: int, fn: Callable[..., None], *args: Any,
                    region: int = 0, key: int = 0) -> None:
        self._push(self._now_ps + delay_ps, fn, args, region, key)

    def schedule_abs_ps(self, at_ps: int, fn: Callable[..., None], *args: Any,
                        region: int = 0, key: int = 0) -> None:
        """Schedule at an absolute tick (used by the fabric fast path, which
        precomputes service completion times in integer picoseconds).

        The ``_push`` body is inlined: this is the hottest scheduling call
        in fine-grained runs (one per park/delivery).
        """
        if at_ps < self._now_ps:
            raise ValueError(f"cannot schedule in the past: {at_ps} < {self._now_ps}")
        heapq.heappush(self._queue, (at_ps, key, self._seq, fn, args, region))
        self._seq += 1
        if region:
            if self._regioned:
                heapq.heappush(self._rheaps[region], at_ps)
        else:
            self._led_gen += 1
            if self._regioned:
                heapq.heappush(self._rheaps[0], at_ps)

    def peek_ps(self) -> Optional[int]:
        """Timestamp of the earliest pending event, or None if idle.

        The coalescing fast path uses this as its *lookahead horizon*: no new
        flight can be injected or arrive anywhere before this tick, so link
        service committed strictly before it can never violate FIFO order.
        """
        q = self._queue
        return q[0][0] if q else None

    def untagged_floor_ps(self) -> int:
        """Earliest pending *untagged* (region-0) tick, or a far sentinel.

        The fabric's reservation ledger floors every injection bound on
        this: untagged events are the escape hatch for activity the ledger
        cannot otherwise see (pre-scheduled kernel dispatches, straggler
        skew, generic user callbacks), so anything they might trigger stays
        inside every proof.
        """
        if self._regioned:
            g = self._rheaps[0]
            return g[0] if g else (1 << 62)
        q = self._queue
        return q[0][0] if q else (1 << 62)

    def peek_region(self, region: int) -> Optional[int]:
        """Earliest pending tick that could affect region ``region``.

        Region 0 (untagged) can be reached by any event, so its horizon is
        the global queue minimum; a tagged region is only reachable from its
        own events plus untagged ones.
        """
        if not region:
            q = self._queue
            return q[0][0] if q else None
        g = self._rheaps[0]
        r = self._rheaps[region]
        if r:
            if g:
                return r[0] if r[0] < g[0] else g[0]
            return r[0]
        return g[0] if g else None

    def horizon_ps(self, region: int, guard_ps: int,
                   cap_ps: Optional[int] = None) -> Optional[int]:
        """Commit bound for ahead-of-time work touching region ``region``.

        The sound lookahead horizon shared by the fabric's train chaining
        and the CU's batched (bulk) issue: the earliest pending tick that
        can reach the region — its own events, capped by the global minimum
        plus the region's entry transit ``guard_ps`` for foreign traffic —
        optionally clamped to ``cap_ps`` (e.g. the soonest completion a
        batch's own requests could produce).  ``peek_region`` is inlined:
        this runs once per fast-path hop event.
        """
        q = self._queue
        if not region:
            bound = q[0][0] if q else None
        else:
            g = self._rheaps[0]
            r = self._rheaps[region]
            if r:
                bound = r[0]
                if g and g[0] < bound:
                    bound = g[0]
            else:
                bound = g[0] if g else None
            if q:
                cap = q[0][0] + guard_ps
                if bound is None or cap < bound:
                    bound = cap
        if cap_ps is not None and (bound is None or cap_ps < bound):
            bound = cap_ps
        return bound

    def at(self, time_ns: float, fn: Callable[..., None], *args: Any) -> None:
        """Schedule ``fn(*args)`` at absolute time ``time_ns``."""
        at_ps = int(round(time_ns * _PS_PER_NS))
        if at_ps < self._now_ps:
            raise ValueError(f"cannot schedule in the past: {time_ns} < {self.now}")
        self._push(at_ps, fn, args, 0)

    # -------------------------------------------------------------- execution
    def run(self, until_ns: Optional[float] = None, max_events: Optional[int] = None) -> float:
        """Drain the event queue.  Returns final simulation time (ns).

        The cyclic GC is paused for the duration: the event loop allocates
        millions of short-lived tuples/flights and generational scans cost
        20%+ of wall time, while true cycles only form in long-lived model
        objects that a single collection at the end reclaims.
        """
        until_ps = None if until_ns is None else int(round(until_ns * _PS_PER_NS))
        self._running = True
        self._wall_start = _wallclock.perf_counter()
        q = self._queue
        rheaps = self._rheaps if self._regioned else None
        pop = heapq.heappop
        n = 0
        gc_was_enabled = _gc.isenabled()
        if gc_was_enabled:
            _gc.disable()
        try:
            if rheaps is None:
                while q and self._running:
                    at_ps = q[0][0]
                    if until_ps is not None and at_ps > until_ps:
                        break
                    _, _, _, fn, args, _ = pop(q)
                    self._now_ps = at_ps
                    # live per-event count: the fabric's channel-clock memo
                    # uses it as its epoch (one memo generation per event)
                    self.events_processed += 1
                    fn(*args)
                    n += 1
                    if max_events is not None and n >= max_events:
                        break
                    if self._regioned:      # a region appeared mid-run
                        rheaps = self._rheaps
                        break
            push = heapq.heappush
            while rheaps is not None and q and self._running \
                    and (max_events is None or n < max_events):
                item = pop(q)           # pop-first: saves a peek per event
                at_ps = item[0]
                if until_ps is not None and at_ps > until_ps:
                    push(q, item)       # past the horizon: put it back
                    break
                pop(rheaps[item[5]])
                self._now_ps = at_ps
                self.events_processed += 1
                item[3](*item[4])
                n += 1
        finally:
            if gc_was_enabled:
                _gc.enable()
        self._running = False
        if until_ps is not None and q and q[0][0] > until_ps:
            # stopped at the horizon with work pending: clock sits at the
            # horizon (callers can resume); a drained queue keeps the time
            # of the last event.
            self._now_ps = max(self._now_ps, until_ps)
        return self.now

    def stop(self) -> None:
        self._running = False

    @property
    def pending(self) -> int:
        return len(self._queue)

    def wallclock_seconds(self) -> float:
        if self._wall_start is None:
            return 0.0
        return _wallclock.perf_counter() - self._wall_start

    def throughput_ns_per_s(self) -> float:
        """Simulated nanoseconds per wall-clock second (paper Fig. 15 metric)."""
        wall = self.wallclock_seconds()
        return self.now / wall if wall > 0 else float("inf")
