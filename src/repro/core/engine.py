"""Discrete-event simulation engine.

The heart of the ASTRA-sim 3.0 reproduction: a deterministic, heapq-based
event queue.  Every model component (compute units, NoC links, semaphores,
network interfaces) schedules callbacks here.  Time is kept in integer
*picoseconds* internally to make event ordering exactly deterministic and
immune to float round-off; the public API speaks float nanoseconds.
"""

from __future__ import annotations

import heapq
import time as _wallclock
from typing import Any, Callable, List, Optional, Tuple

# one nanosecond in internal ticks (picoseconds)
_PS_PER_NS = 1000


class Engine:
    """Deterministic discrete-event engine.

    Events with equal timestamps fire in scheduling order (FIFO), which keeps
    simulations reproducible run-to-run regardless of hash seeds.
    """

    __slots__ = ("_queue", "_now_ps", "_seq", "events_processed", "_running",
                 "_wall_start")

    def __init__(self) -> None:
        self._queue: List[Tuple[int, int, Callable[..., None], tuple]] = []
        self._now_ps: int = 0
        self._seq: int = 0
        self.events_processed: int = 0
        self._running = False
        self._wall_start: Optional[float] = None

    # ------------------------------------------------------------------ time
    @property
    def now(self) -> float:
        """Current simulation time in nanoseconds."""
        return self._now_ps / _PS_PER_NS

    @property
    def now_ps(self) -> int:
        return self._now_ps

    # ------------------------------------------------------------- scheduling
    def schedule(self, delay_ns: float, fn: Callable[..., None], *args: Any) -> None:
        """Schedule ``fn(*args)`` ``delay_ns`` nanoseconds from now."""
        if delay_ns < 0:
            raise ValueError(f"negative delay: {delay_ns}")
        at_ps = self._now_ps + int(round(delay_ns * _PS_PER_NS))
        heapq.heappush(self._queue, (at_ps, self._seq, fn, args))
        self._seq += 1

    def schedule_ps(self, delay_ps: int, fn: Callable[..., None], *args: Any) -> None:
        heapq.heappush(self._queue, (self._now_ps + delay_ps, self._seq, fn, args))
        self._seq += 1

    def at(self, time_ns: float, fn: Callable[..., None], *args: Any) -> None:
        """Schedule ``fn(*args)`` at absolute time ``time_ns``."""
        at_ps = int(round(time_ns * _PS_PER_NS))
        if at_ps < self._now_ps:
            raise ValueError(f"cannot schedule in the past: {time_ns} < {self.now}")
        heapq.heappush(self._queue, (at_ps, self._seq, fn, args))
        self._seq += 1

    # -------------------------------------------------------------- execution
    def run(self, until_ns: Optional[float] = None, max_events: Optional[int] = None) -> float:
        """Drain the event queue.  Returns final simulation time (ns)."""
        until_ps = None if until_ns is None else int(round(until_ns * _PS_PER_NS))
        self._running = True
        self._wall_start = _wallclock.perf_counter()
        q = self._queue
        n = 0
        while q and self._running:
            at_ps, _, fn, args = q[0]
            if until_ps is not None and at_ps > until_ps:
                break
            heapq.heappop(q)
            self._now_ps = at_ps
            fn(*args)
            n += 1
            if max_events is not None and n >= max_events:
                break
        self.events_processed += n
        self._running = False
        if until_ps is not None and q and q[0][0] > until_ps:
            # stopped at the horizon with work pending: clock sits at the
            # horizon (callers can resume); a drained queue keeps the time
            # of the last event.
            self._now_ps = max(self._now_ps, until_ps)
        return self.now

    def stop(self) -> None:
        self._running = False

    @property
    def pending(self) -> int:
        return len(self._queue)

    def wallclock_seconds(self) -> float:
        if self._wall_start is None:
            return 0.0
        return _wallclock.perf_counter() - self._wall_start

    def throughput_ns_per_s(self) -> float:
        """Simulated nanoseconds per wall-clock second (paper Fig. 15 metric)."""
        wall = self.wallclock_seconds()
        return self.now / wall if wall > 0 else float("inf")
