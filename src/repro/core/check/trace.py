"""Static lint of Chakra-style ExecutionTraces.

Structural findings (duplicate ids, dangling dependencies, dependency
cycles, bad ranks, malformed collective groups) come back as diagnostics
instead of exceptions, so sweep pipelines can triage thousands of
generated traces.  Optionally each distinct collective signature is
*deep-checked*: the MSCCL++ program a backend would lower it to is
generated and run through :func:`~repro.core.check.program.check_program`
(results cached per signature, so sweeps pay once per algorithm shape).
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Tuple

from .program import check_program
from .report import CheckReport, Location

#: deep-check result cache: signature -> list of (severity, rule, message)
_DEEP_CACHE: Dict[Tuple, List] = {}


def check_trace(trace, deep: bool = True, workgroups: int = 4,
                protocol: str = "put") -> CheckReport:
    rep = CheckReport(source=f"trace ({trace.num_ranks} ranks, "
                             f"{len(trace.nodes)} nodes)")
    nodes = trace.nodes
    if trace.num_ranks < 1:
        rep.add("error", "TR-RANK", Location(),
                f"trace needs num_ranks >= 1, got {trace.num_ranks}")
        return rep
    by_id = {}
    for n in nodes:
        if n.nid in by_id:
            rep.add("error", "TR-DUP", Location.node(n.nid),
                    f"duplicate node id {n.nid}")
        by_id[n.nid] = n
    colls: Dict[int, Dict[int, object]] = defaultdict(dict)
    for n in nodes:
        loc = Location.node(n.nid)
        if n.kind not in ("comp", "coll"):
            rep.add("error", "TR-KIND", loc, f"bad kind {n.kind!r}")
        if not (0 <= n.rank < trace.num_ranks):
            rep.add("error", "TR-RANK", loc,
                    f"rank {n.rank} outside 0..{trace.num_ranks - 1}")
        for d in n.deps:
            if d not in by_id:
                rep.add("error", "TR-DANGLING", loc,
                        f"depends on missing node {d}")
        if n.kind == "comp" and (n.flops < 0 or n.bytes_moved < 0):
            rep.add("error", "TR-COMP", loc,
                    f"negative cost (flops={n.flops}, "
                    f"bytes_moved={n.bytes_moved})")
        if n.start_after_ns < 0:
            rep.add("error", "TR-START", loc,
                    f"negative start_after_ns {n.start_after_ns}")
        if n.kind == "coll":
            if n.coll_id < 0 or not n.coll_kind:
                rep.add("error", "TR-COLL", loc,
                        "collective node needs coll_id >= 0 and a coll_kind")
            else:
                prev = colls[n.coll_id].get(n.rank)
                if prev is not None:
                    rep.add("error", "TR-DUP-COLL", loc,
                            f"rank {n.rank} appears twice in collective "
                            f"{n.coll_id} (also node {prev.nid}); duplicate "
                            f"(coll_id, rank) halves corrupt completion "
                            f"routing in every executor")
                colls[n.coll_id][n.rank] = n
            if n.coll_bytes < 0:
                rep.add("error", "TR-COLL", loc,
                        f"negative coll_bytes {n.coll_bytes}")
            if n.coll_kind == "p2p":
                for role, r in (("src", n.src_rank), ("dst", n.dst_rank)):
                    if not (0 <= r < trace.num_ranks):
                        rep.add("error", "TR-P2P", loc,
                                f"p2p {role}_rank {r} outside "
                                f"0..{trace.num_ranks - 1}")
                if n.src_rank == n.dst_rank:
                    rep.add("error", "TR-P2P", loc,
                            f"p2p src_rank == dst_rank ({n.src_rank})")
                if n.rank not in (n.src_rank, n.dst_rank):
                    rep.add("error", "TR-P2P", loc,
                            f"p2p half on rank {n.rank} but the transfer "
                            f"is {n.src_rank} -> {n.dst_rank}")

    _check_cycles(trace, by_id, rep)

    # collective groups must cover every participating rank with consistent
    # parameters; full collectives span every rank, p2p exactly {src, dst}
    for cid, group in sorted(colls.items()):
        any_node = next(iter(group.values()))
        if any_node.coll_kind == "p2p":
            want = {any_node.src_rank, any_node.dst_rank}
        else:
            want = set(range(trace.num_ranks))
        missing = sorted(want - set(group))
        extra = sorted(set(group) - want)
        if missing:
            rep.add("error", "TR-COLL", Location.node(any_node.nid),
                    f"collective {cid} missing rank halves for {missing}; "
                    f"every executor would deadlock waiting for them",
                    witness={"coll_id": cid, "missing_ranks": missing})
        if extra:
            rep.add("error", "TR-COLL", Location.node(any_node.nid),
                    f"collective {cid} has stray rank halves on {extra}",
                    witness={"coll_id": cid, "extra_ranks": extra})
        sig = {(n.coll_kind, n.coll_bytes, n.algorithm,
                n.src_rank, n.dst_rank) for n in group.values()}
        if len(sig) != 1:
            rep.add("error", "TR-COLL", Location.node(any_node.nid),
                    f"collective {cid} inconsistent across ranks: "
                    f"{sorted(sig)}")

    if deep and rep.ok:
        _deep_check(trace, colls, rep, workgroups, protocol)
    return rep


def _check_cycles(trace, by_id, rep: CheckReport) -> None:
    """Dependency cycles: DagScheduler would simply never finish on one —
    this reports the cycle statically, with its member ids as witness."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {nid: WHITE for nid in by_id}
    for root in by_id:
        if color[root] != WHITE:
            continue
        stack = [(root, iter(by_id[root].deps))]
        color[root] = GRAY
        path = [root]
        while stack:
            nid, it = stack[-1]
            advanced = False
            for d in it:
                if d not in by_id:
                    continue
                if color[d] == GRAY:
                    cyc = path[path.index(d):] + [d]
                    rep.add("error", "TR-CYCLE", Location.node(d),
                            "dependency cycle: "
                            + " -> ".join(str(x) for x in cyc),
                            witness={"cycle": cyc[:-1]})
                    continue
                if color[d] == WHITE:
                    color[d] = GRAY
                    stack.append((d, iter(by_id[d].deps)))
                    path.append(d)
                    advanced = True
                    break
            if not advanced:
                color[nid] = BLACK
                stack.pop()
                path.pop()


def _deep_check(trace, colls, rep: CheckReport, workgroups: int,
                protocol: str) -> None:
    from ..chakra import collective_program
    for cid, group in sorted(colls.items()):
        node = next(iter(group.values()))
        sig = (node.coll_kind, node.algorithm, trace.num_ranks,
               node.coll_bytes, workgroups, protocol,
               node.src_rank, node.dst_rank)
        cached = _DEEP_CACHE.get(sig)
        if cached is None:
            cached = []
            try:
                prog = collective_program(node, trace.num_ranks, workgroups,
                                          protocol)
            except Exception as exc:
                cached.append(("error", "TR-COLL",
                               f"collective {node.coll_kind}/"
                               f"{node.algorithm} cannot be generated for "
                               f"{trace.num_ranks} ranks: {exc}"))
            else:
                sub = check_program(prog)
                for d in sub.diagnostics:
                    cached.append((d.severity, d.rule,
                                   f"[{prog.name} @ {d.loc}] {d.message}"))
            if len(_DEEP_CACHE) > 512:
                _DEEP_CACHE.clear()
            _DEEP_CACHE[sig] = cached
        for severity, rule, message in cached:
            rep.add(severity, rule, Location.node(node.nid),
                    f"collective {cid}: {message}")
