"""Static lint of InfraGraph infrastructures.

Catches the sweep-killers before any event is simulated: unreachable
node pairs (a collective would hang routing through them), zero or
negative link bandwidth (infinite serialization time), negative or
absurd latencies, and endpoint capacity below the workload's rank count.
"""

from __future__ import annotations

from collections import deque
from typing import Optional

from .report import CheckReport, Location

#: sanity ceilings: beyond these a value is almost certainly a unit bug
MAX_SANE_BANDWIDTH_GBPS = 100_000.0     # 100 TB/s per link
MAX_SANE_LATENCY_NS = 1e9               # 1 s per hop


def check_infrastructure(infra, num_ranks: Optional[int] = None
                         ) -> CheckReport:
    rep = CheckReport(source=f"infrastructure {infra.name!r}")
    try:
        g = infra.expand()
    except Exception as exc:
        rep.add("error", "IG-EXPAND", Location(),
                f"infrastructure does not expand: {exc}")
        return rep

    # --- link property sanity (each distinct (edge, LinkType) pairing)
    seen_links = set()
    for (src, dst), lt in g.edges.items():
        key = (lt.name, lt.bandwidth_GBps, lt.latency_ns)
        if lt.bandwidth_GBps <= 0:
            rep.add("error", "IG-LINK-BW", Location.graph(f"{src}->{dst}"),
                    f"link {lt.name!r} has non-positive bandwidth "
                    f"{lt.bandwidth_GBps} GB/s")
        elif lt.bandwidth_GBps > MAX_SANE_BANDWIDTH_GBPS and \
                key not in seen_links:
            rep.add("warning", "IG-LINK-BW", Location.graph(f"{src}->{dst}"),
                    f"link {lt.name!r} bandwidth {lt.bandwidth_GBps} GB/s "
                    f"exceeds {MAX_SANE_BANDWIDTH_GBPS} (unit bug?)")
        if lt.latency_ns < 0:
            rep.add("error", "IG-LINK-LAT", Location.graph(f"{src}->{dst}"),
                    f"link {lt.name!r} has negative latency "
                    f"{lt.latency_ns} ns")
        elif lt.latency_ns > MAX_SANE_LATENCY_NS and key not in seen_links:
            rep.add("warning", "IG-LINK-LAT", Location.graph(f"{src}->{dst}"),
                    f"link {lt.name!r} latency {lt.latency_ns} ns exceeds "
                    f"{MAX_SANE_LATENCY_NS} (unit bug?)")
        seen_links.add(key)

    # --- all-pairs reachability (directed BFS forward + backward from one
    # root: equivalent to strong connectivity on this edge set)
    if g.nodes:
        root = next(iter(g.nodes))
        fwd = _reach(g.adj, root)
        radj = {n: [] for n in g.nodes}
        for (src, dst) in g.edges:
            radj[dst].append(src)
        bwd = _reach(radj, root)
        unreachable = sorted(set(g.nodes) - (fwd & bwd))
        if unreachable:
            rep.add("error", "IG-UNREACHABLE",
                    Location.graph(unreachable[0]),
                    f"{len(unreachable)} node(s) not reachable from/to "
                    f"{root!r} (first: {unreachable[:5]}); traffic routed "
                    f"through them would hang",
                    witness={"root": root,
                             "unreachable": unreachable[:50]})

    # --- endpoint capacity vs the workload
    from ..infragraph.translate import endpoint_nodes
    eps = endpoint_nodes(g)
    if not eps:
        rep.add("warning", "IG-NO-ENDPOINT", Location(),
                "no rank-bearing endpoints (gpu/core/cu) in infrastructure")
    elif num_ranks is not None and len(eps) < num_ranks:
        rep.add("error", "IG-CAPACITY", Location.graph(eps[0]),
                f"infrastructure has {len(eps)} endpoint(s) but the "
                f"workload needs {num_ranks} ranks",
                witness={"endpoints": len(eps), "num_ranks": num_ranks})
    return rep


def _reach(adj, root):
    seen = {root}
    q = deque([root])
    while q:
        u = q.popleft()
        for v in adj.get(u, ()):
            if v not in seen:
                seen.add(v)
                q.append(v)
    return seen
