"""Prove-before-simulate: static workload verification (deadlock, data
race, bounds, coverage) over MSCCL++ Programs, Chakra-style
ExecutionTraces, and InfraGraph Infrastructures.

The paper's DSE use case sweeps thousands of *generated* workload points;
a subtly wrong custom collective must fail fast with a diagnostic, not
hang the fine tier.  This package runs before any event is simulated:

    from repro.core.check import check_workload
    report = check_workload(program_or_trace, infra)
    if not report.ok:
        print(report.format())

Surfaces:

* ``simulate(workload, infra, check="warn"|"error"|"off")`` — wired into
  the experiment entry point (default ``"warn"``);
* ``python -m repro.check prog.json trace.json`` — sweep-pipeline CLI
  (``--collectives`` verifies every built-in generator);
* the pass functions below, individually importable.

Guarantees and over-approximations: the deadlock pass is sound and
complete for the MSCCL++ op vocabulary (static op lists, counting
semaphores, rank-local barriers — see :mod:`.program`); the race pass is
sound (never misses a race) but may over-report on synchronization the
must-happens-before matcher cannot prove, e.g. ordering established
through timing alone.  Every built-in generator in
:mod:`repro.core.collectives` verifies clean.
"""

from __future__ import annotations

from typing import Dict, Optional

from .infra import check_infrastructure
from .program import check_program
from .report import CheckError, CheckReport, CheckWarning, Diagnostic, Location
from .trace import check_trace

#: memoized program reports keyed by structural JSON (sweeps re-check the
#: same generated program many times; the checker is pure)
_PROGRAM_CACHE: Dict[str, CheckReport] = {}


def check_program_cached(program) -> CheckReport:
    key = program.to_json()
    rep = _PROGRAM_CACHE.get(key)
    if rep is None:
        if len(_PROGRAM_CACHE) > 256:
            _PROGRAM_CACHE.clear()
        rep = _PROGRAM_CACHE.setdefault(key, check_program(program))
    return rep


def check_workload(workload, infra=None, deep: bool = True,
                   workgroups: int = 4, protocol: str = "put",
                   num_ranks: Optional[int] = None) -> CheckReport:
    """One-call verification of a workload (+ optional infrastructure).

    ``workload`` is an MSCCL++ Program or an ExecutionTrace (or None to
    lint only the infrastructure).  Returns the merged
    :class:`CheckReport`; never raises on findings — call
    ``report.raise_if_errors()`` or use ``simulate(..., check="error")``
    for fail-fast behavior.
    """
    from ..backends.workload import is_trace
    rep = CheckReport()
    if workload is not None:
        if is_trace(workload):
            rep = check_trace(workload, deep=deep, workgroups=workgroups,
                              protocol=protocol)
        else:
            rep = check_program_cached(workload)
        num_ranks = getattr(workload, "num_ranks", num_ranks)
    if infra is not None:
        sub = check_infrastructure(infra, num_ranks=num_ranks)
        if workload is None:
            rep = sub
        else:
            # never mutate the (possibly cached) workload report
            merged = CheckReport(source=rep.source)
            merged.diagnostics = list(rep.diagnostics) + sub.diagnostics
            rep = merged
    return rep


__all__ = [
    "CheckError", "CheckReport", "CheckWarning", "Diagnostic", "Location",
    "check_infrastructure", "check_program", "check_program_cached",
    "check_trace", "check_workload",
]
