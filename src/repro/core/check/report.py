"""Structured diagnostics shared by every static-analysis pass.

A :class:`Diagnostic` pins one finding to a location — ``(rank, wg,
op_index)`` inside an MSCCL++ Program, a trace node id, or a fully
qualified InfraGraph node name — and carries the *witness* that proves
it: the wait-for cycle, the pair of overlapping byte ranges, the
uncovered output intervals.  A :class:`CheckReport` aggregates the
diagnostics of one workload/infrastructure and renders them for humans
(``format``) or pipelines (``to_json``).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, List, Tuple

#: severity levels, in increasing order
SEVERITIES = ("warning", "error")


@dataclass(frozen=True)
class Location:
    """Where a diagnostic points.

    Exactly one of the three shapes is populated:

    * program op:  ``(rank, wg, op_index)``
    * trace node:  ``node_id``
    * graph node:  ``graph_node`` (fully qualified name)
    """
    rank: int = -1
    wg: int = -1
    op_index: int = -1
    node_id: int = -1
    graph_node: str = ""

    @staticmethod
    def op(rank: int, wg: int, op_index: int) -> "Location":
        return Location(rank=rank, wg=wg, op_index=op_index)

    @staticmethod
    def node(node_id: int) -> "Location":
        return Location(node_id=node_id)

    @staticmethod
    def graph(name: str) -> "Location":
        return Location(graph_node=name)

    @property
    def cursor(self) -> Tuple[int, int, int]:
        """The ``(rank, wg, op_index)`` triple (program locations)."""
        return (self.rank, self.wg, self.op_index)

    def __str__(self) -> str:
        if self.graph_node:
            return self.graph_node
        if self.node_id >= 0:
            return f"node {self.node_id}"
        if self.op_index >= 0:
            return f"(rank {self.rank}, wg {self.wg}, op {self.op_index})"
        if self.rank >= 0:
            return f"(rank {self.rank})"
        return "<workload>"

    def to_json(self) -> dict:
        d = {}
        if self.graph_node:
            d["graph_node"] = self.graph_node
        elif self.node_id >= 0:
            d["node_id"] = self.node_id
        else:
            if self.rank >= 0:
                d["rank"] = self.rank
            if self.wg >= 0:
                d["wg"] = self.wg
            if self.op_index >= 0:
                d["op_index"] = self.op_index
        return d


@dataclass(frozen=True)
class Diagnostic:
    """One finding of the static checker."""
    severity: str                 # "error" | "warning"
    rule: str                     # e.g. "DL-CYCLE", "RACE-WW", "BUF-OOB"
    loc: Location
    message: str
    #: machine-readable proof: cycle as cursor list, overlapping ranges, ...
    witness: Any = None

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}; "
                             f"choose from {SEVERITIES}")

    def __str__(self) -> str:
        return f"{self.severity}[{self.rule}] {self.loc}: {self.message}"

    def to_json(self) -> dict:
        d = {"severity": self.severity, "rule": self.rule,
             "loc": self.loc.to_json(), "message": self.message}
        if self.witness is not None:
            d["witness"] = _jsonable(self.witness)
        return d


def _jsonable(obj):
    """Best-effort conversion of witness structures to JSON-safe values."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, Location):
        return obj.to_json()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return str(obj)


@dataclass
class CheckReport:
    """All diagnostics for one workload (plus optional infrastructure)."""
    source: str = ""                       # e.g. program/trace/graph name
    diagnostics: List[Diagnostic] = field(default_factory=list)

    def add(self, severity: str, rule: str, loc: Location, message: str,
            witness: Any = None) -> None:
        self.diagnostics.append(Diagnostic(severity, rule, loc, message,
                                           witness))

    def extend(self, other: "CheckReport") -> None:
        self.diagnostics.extend(other.diagnostics)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        """True iff no *errors* (warnings are advisory)."""
        return not self.errors

    @property
    def clean(self) -> bool:
        """True iff no diagnostics at all."""
        return not self.diagnostics

    def by_rule(self, rule: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.rule == rule]

    def format(self, limit: int = 50) -> str:
        head = (f"check {self.source or '<workload>'}: "
                f"{len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s)")
        lines = [head]
        for d in self.diagnostics[:limit]:
            lines.append(f"  {d}")
        if len(self.diagnostics) > limit:
            lines.append(f"  ... and {len(self.diagnostics) - limit} more")
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps({
            "source": self.source,
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "diagnostics": [d.to_json() for d in self.diagnostics],
        }, indent=1)

    def raise_if_errors(self) -> None:
        if self.errors:
            raise CheckError(self)


class CheckError(RuntimeError):
    """Raised by ``simulate(..., check="error")`` when the static checker
    finds at least one error-severity diagnostic."""

    def __init__(self, report: CheckReport):
        self.report = report
        super().__init__(report.format())


class CheckWarning(UserWarning):
    """Emitted by ``simulate(..., check="warn")`` (the default) when the
    static checker reports any diagnostic."""
