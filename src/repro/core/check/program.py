"""Static analysis of MSCCL++ Programs: deadlock, data race, bounds,
output coverage.

The analyses exploit a structural property of MSCCL++ programs: op lists
are static (no data-dependent branching), semaphores are monotone
counters, and barriers are rank-local joint transitions.  Such a system
is *confluent* — executing any enabled op never disables another — so a
single greedy "saturation" run of an abstract interpreter (no data, no
timing) reaches the unique maximal quiescent state:

* if every cursor finishes, the program is deadlock-free under **every**
  interleaving;
* if cursors remain blocked, the program deadlocks under every
  interleaving, and the blocked set is the witness.

This makes the deadlock pass sound *and* complete, at O(total ops).

On deadlock-free programs a **must-happens-before** DAG is built:
program order, barrier rounds (recorded during saturation), and
signal→wait edges derived by semaphore *counting* — a signal must
precede a wait iff the wait's ``expected`` cannot be reached without it,
computed per totally-ordered per-workgroup signal chain and iterated to
a fixpoint as the order grows.  Must-happens-before under-approximates
guaranteed ordering, so the race pass (byte-interval overlap of accesses
not ordered by the DAG) over-approximates real races — it can cry wolf
on exotic synchronization idioms, but never misses a race expressible in
this op vocabulary, and reports zero findings on every built-in
generator in :mod:`repro.core.collectives`.
"""

from __future__ import annotations

from collections import defaultdict, deque
from typing import Dict, List, Set, Tuple

from ..mscclpp import Program, VALID_OPS
from .report import CheckReport, Location

#: data-movement ops (everything else is control/synchronization)
DATA_OPS = ("put", "get", "copy", "reduce")

#: collectives whose output buffer must be fully written
COVERED_COLLECTIVES = ("all_gather", "reduce_scatter", "all_reduce",
                       "all_to_all")

#: op-count ceiling for the quadratic-ish passes (happens-before closure
#: and race detection); larger programs still get the linear passes
HB_OP_LIMIT = 20_000


# ---------------------------------------------------------------------------
# flattened view
# ---------------------------------------------------------------------------

class _Prog:
    """Index of a Program: flat node ids per (rank, wg, op_index)."""

    def __init__(self, program: Program):
        self.p = program
        self.node_of: Dict[Tuple[int, int, int], int] = {}
        self.cursor_of: List[Tuple[int, int, int]] = []
        for r, wgs in enumerate(program.gpus):
            for w, ops in enumerate(wgs):
                for i in range(len(ops)):
                    self.node_of[(r, w, i)] = len(self.cursor_of)
                    self.cursor_of.append((r, w, i))
        self.n_ops = len(self.cursor_of)
        # static semaphore signal totals: (target rank, sem) -> count
        self.sig_total: Dict[Tuple[int, int], int] = defaultdict(int)
        for r, wgs in enumerate(program.gpus):
            for ops in wgs:
                for o in ops:
                    if o.op == "signal" and \
                            0 <= o.remote_rank < program.num_ranks:
                        self.sig_total[(o.remote_rank, o.sem)] += 1

    def op(self, r: int, w: int, i: int):
        return self.p.gpus[r][w][i]

    def loc(self, node: int) -> Location:
        return Location.op(*self.cursor_of[node])


# ---------------------------------------------------------------------------
# pass 1: structural / bounds
# ---------------------------------------------------------------------------

def _check_bounds(px: _Prog, rep: CheckReport) -> None:
    p = px.p
    for r, wgs in enumerate(p.gpus):
        for w, ops in enumerate(wgs):
            for i, o in enumerate(ops):
                loc = Location.op(r, w, i)
                if o.op not in VALID_OPS:
                    rep.add("error", "OP-UNKNOWN", loc,
                            f"unknown op {o.op!r}")
                    continue
                if o.op in ("put", "get", "signal") and not (
                        0 <= o.remote_rank < p.num_ranks):
                    rep.add("error", "OP-RANK", loc,
                            f"{o.op} targets rank {o.remote_rank}, outside "
                            f"0..{p.num_ranks - 1}")
                if o.op in ("signal", "wait") and o.sem < 0:
                    rep.add("error", "OP-SEM", loc,
                            f"{o.op} uses negative semaphore id {o.sem}")
                if o.op == "wait" and o.expected < 1:
                    rep.add("warning", "OP-SEM", loc,
                            f"wait with expected={o.expected} is trivially "
                            f"satisfied (no ordering)")
                if o.op not in DATA_OPS:
                    continue
                if o.size < 0:
                    rep.add("error", "BUF-SIZE", loc,
                            f"{o.op} with negative size {o.size}")
                elif o.size == 0:
                    rep.add("warning", "BUF-SIZE", loc,
                            f"{o.op} with size 0 moves no data")
                if o.op == "reduce":
                    if not o.srcs:
                        rep.add("warning", "BUF-SIZE", loc,
                                "reduce with no sources writes zeros")
                    for (buf, off, rk) in o.srcs or []:
                        if rk >= p.num_ranks or rk < -1:
                            rep.add("error", "OP-RANK", loc,
                                    f"reduce src references rank {rk}, "
                                    f"outside 0..{p.num_ranks - 1}")
                        else:
                            _check_range(p, rep, loc, o.op, buf, off, o.size)
                else:
                    if o.src_buf:
                        _check_range(p, rep, loc, o.op, o.src_buf, o.src_off,
                                     o.size)
                    elif o.op in ("put", "get", "copy"):
                        rep.add("error", "BUF-UNKNOWN", loc,
                                f"{o.op} without a source buffer")
                if o.op in DATA_OPS:
                    if o.dst_buf:
                        _check_range(p, rep, loc, o.op, o.dst_buf, o.dst_off,
                                     o.size)
                    else:
                        rep.add("error", "BUF-UNKNOWN", loc,
                                f"{o.op} without a destination buffer")


def _check_range(p: Program, rep: CheckReport, loc: Location, op: str,
                 buf: str, off: int, size: int) -> None:
    declared = p.buffers.get(buf)
    if declared is None:
        rep.add("error", "BUF-UNKNOWN", loc,
                f"{op} references undeclared buffer {buf!r} "
                f"(declared: {sorted(p.buffers)})")
        return
    if off < 0 or (size > 0 and off + size > declared):
        rep.add("error", "BUF-OOB", loc,
                f"{op} touches {buf}[{off}:{off + max(size, 0)}] but "
                f"{buf!r} is {declared} bytes",
                witness={"buffer": buf, "range": [off, off + max(size, 0)],
                         "declared": declared})


# ---------------------------------------------------------------------------
# pass 2: saturation (deadlock) — see module docstring for why this is
# sound and complete
# ---------------------------------------------------------------------------

class _Saturation:
    def __init__(self, px: _Prog):
        self.px = px
        p = px.p
        self.pcs: Dict[Tuple[int, int], int] = {
            (r, w): 0 for r in range(p.num_ranks)
            for w in range(len(p.gpus[r]))}
        self.sems: Dict[Tuple[int, int], int] = defaultdict(int)
        self.order: List[int] = []            # node ids in execution order
        self.parked: Set[Tuple[int, int]] = set()   # cursors at a barrier
        self.waiters: Dict[Tuple[int, int],
                           List[Tuple[int, int]]] = defaultdict(list)
        # per rank: list of rounds; each round maps wg -> barrier op_index,
        # plus the set of wgs already finished when the round fired
        self.rounds: Dict[int, List[Tuple[Dict[int, int], Set[int]]]] = \
            defaultdict(list)
        self.virtual_rounds: List[Tuple[int, int]] = []  # (rank, round idx)

    def run(self) -> None:
        work = deque(self.pcs)
        queued = set(work)
        while work:
            cur = work.popleft()
            queued.discard(cur)
            self._advance(cur, work, queued)

    def _advance(self, cur: Tuple[int, int], work, queued) -> None:
        px, p = self.px, self.px.p
        r, w = cur
        ops = p.gpus[r][w]
        while True:
            pc = self.pcs[cur]
            if pc >= len(ops):
                self._try_barrier(r, work, queued)    # siblings may unblock
                return
            o = ops[pc]
            if o.op == "wait":
                if o.sem < 0:                  # diagnosed; treat as satisfied
                    self.order.append(px.node_of[(r, w, pc)])
                    self.pcs[cur] = pc + 1
                    continue
                if self.sems[(r, o.sem)] >= o.expected:
                    self.order.append(px.node_of[(r, w, pc)])
                    self.pcs[cur] = pc + 1
                    continue
                self.waiters[(r, o.sem)].append(cur)
                return
            if o.op == "barrier":
                self.parked.add(cur)
                self._try_barrier(r, work, queued)
                return
            if o.op == "signal":
                self.order.append(px.node_of[(r, w, pc)])
                self.pcs[cur] = pc + 1
                if 0 <= o.remote_rank < p.num_ranks:
                    key = (o.remote_rank, o.sem)
                    self.sems[key] += 1
                    have = self.sems[key]
                    still = []
                    for c2 in self.waiters[key]:
                        r2, w2 = c2
                        o2 = p.gpus[r2][w2][self.pcs[c2]]
                        if o2.expected <= have:
                            if c2 not in queued:
                                work.append(c2)
                                queued.add(c2)
                        else:
                            still.append(c2)
                    self.waiters[key] = still
                continue
            # data ops / nop / flush: pure progress
            self.order.append(px.node_of[(r, w, pc)])
            self.pcs[cur] = pc + 1

    def _try_barrier(self, r: int, work, queued) -> None:
        px, p = self.px, self.px.p
        nwg = len(p.gpus[r])
        participants: Dict[int, int] = {}
        done: Set[int] = set()
        for w2 in range(nwg):
            pc2 = self.pcs[(r, w2)]
            if pc2 >= len(p.gpus[r][w2]):
                done.add(w2)
            elif (r, w2) in self.parked:
                participants[w2] = pc2
            else:
                return                          # some sibling still running
        if not participants:
            return
        for w2, pc2 in participants.items():
            self.order.append(px.node_of[(r, w2, pc2)])
            self.pcs[(r, w2)] = pc2 + 1
            self.parked.discard((r, w2))
        self.rounds[r].append((dict(participants), done))
        self.virtual_rounds.append((r, len(self.rounds[r]) - 1))
        for w2 in participants:
            if (r, w2) not in queued:
                work.append((r, w2))
                queued.add((r, w2))

    def blocked(self) -> List[Tuple[int, int]]:
        p = self.px.p
        return sorted(c for c, pc in self.pcs.items()
                      if pc < len(p.gpus[c[0]][c[1]]))


def _barrier_arity(px: _Prog, rep: CheckReport,
                   deadlocked: bool) -> Set[int]:
    """Flag ranks whose workgroups disagree on barrier count.  Returns the
    offending ranks (their stuck-at-barrier cursors are then explained by
    this diagnostic rather than a separate cycle report)."""
    p = px.p
    bad: Set[int] = set()
    for r, wgs in enumerate(p.gpus):
        if len(wgs) < 2:
            continue
        counts = [sum(1 for o in ops if o.op == "barrier") for ops in wgs]
        if len(set(counts)) > 1:
            bad.add(r)
            w = counts.index(max(counts))
            idx = [i for i, o in enumerate(wgs[w]) if o.op == "barrier"]
            sev = "error" if deadlocked else "warning"
            rep.add(sev, "DL-BARRIER-ARITY",
                    Location.op(r, w, idx[min(counts)] if
                                min(counts) < len(idx) else idx[-1]),
                    f"rank {r} workgroups disagree on barrier count "
                    f"{counts}; a barrier only releases when every "
                    f"workgroup reaches one (or ends)",
                    witness={"rank": r, "barrier_counts": counts})
    return bad


def _check_deadlock(px: _Prog, sat: _Saturation, rep: CheckReport) -> bool:
    """Classify blocked cursors.  Returns True iff the program deadlocks."""
    p = px.p
    blocked = sat.blocked()
    arity_bad = _barrier_arity(px, rep, deadlocked=bool(blocked))
    if not blocked:
        return False

    explained: Set[Tuple[int, int]] = set()
    # --- under-signaled waits: expected not coverable by program-wide total
    for (r, w) in blocked:
        pc = sat.pcs[(r, w)]
        o = p.gpus[r][w][pc]
        if o.op != "wait":
            continue
        total = px.sig_total.get((r, o.sem), 0)
        if total < o.expected:
            have = sat.sems.get((r, o.sem), 0)
            rep.add("error", "DL-UNDERSIGNAL", Location.op(r, w, pc),
                    f"wait on sem {o.sem} needs {o.expected} signal(s) but "
                    f"the whole program only issues {total} to rank {r} "
                    f"(delivered before the hang: {have})",
                    witness={"sem": o.sem, "rank": r,
                             "expected": o.expected, "signals_total": total,
                             "signals_delivered": have})
            explained.add((r, w))
    # barrier cursors on arity-mismatched ranks are already explained
    for (r, w) in blocked:
        pc = sat.pcs[(r, w)]
        if p.gpus[r][w][pc].op == "barrier" and r in arity_bad:
            explained.add((r, w))

    # --- wait-for graph over the remaining blocked cursors
    remaining = [c for c in blocked if c not in explained]
    idx = {c: i for i, c in enumerate(remaining)}
    succ: List[List[int]] = [[] for _ in remaining]
    for c in remaining:
        r, w = c
        pc = sat.pcs[c]
        o = p.gpus[r][w][pc]
        if o.op == "wait":
            # any blocked cursor whose unexecuted suffix holds a matching
            # signal could still satisfy this wait
            for c2 in blocked:
                if c2 == c or c2 not in idx:
                    continue
                r2, w2 = c2
                suffix = p.gpus[r2][w2][sat.pcs[c2]:]
                if any(s.op == "signal" and s.remote_rank == r and
                       s.sem == o.sem for s in suffix):
                    succ[idx[c]].append(idx[c2])
            # a signal later in this cursor's own suffix can never run
            suffix = p.gpus[r][w][pc + 1:]
            if any(s.op == "signal" and s.remote_rank == r and
                   s.sem == o.sem for s in suffix):
                succ[idx[c]].append(idx[c])
        elif o.op == "barrier":
            for w2 in range(len(p.gpus[r])):
                c2 = (r, w2)
                if c2 != c and c2 in idx and c2 not in sat.parked:
                    succ[idx[c]].append(idx[c2])

    sccs = _tarjan(succ)
    in_cycle: Set[int] = set()
    for comp in sccs:
        cyclic = len(comp) > 1 or comp[0] in succ[comp[0]]
        if not cyclic:
            continue
        in_cycle.update(comp)
        cyc = []
        for ci in comp:
            r, w = remaining[ci]
            pc = sat.pcs[(r, w)]
            o = p.gpus[r][w][pc]
            cyc.append({"rank": r, "wg": w, "op_index": pc, "op": o.op,
                        "sem": o.sem if o.op == "wait" else None,
                        "expected": o.expected if o.op == "wait" else None})
        r, w = remaining[comp[0]]
        rep.add("error", "DL-CYCLE",
                Location.op(r, w, sat.pcs[(r, w)]),
                f"circular wait among {len(comp)} cursor(s): "
                + " -> ".join(f"(r{e['rank']},wg{e['wg']},op{e['op_index']}:"
                              f"{e['op']})" for e in cyc),
                witness={"cycle": cyc})

    leftovers = [c for c in remaining if idx[c] not in in_cycle]
    if leftovers and not explained and not in_cycle:
        # blocked but neither under-signaled nor cyclic (e.g. waiting on a
        # cursor blocked for another reason): report the stuck set
        wit = []
        for (r, w) in blocked:
            pc = sat.pcs[(r, w)]
            o = p.gpus[r][w][pc]
            wit.append({"rank": r, "wg": w, "op_index": pc, "op": o.op,
                        "sem": o.sem if o.op in ("wait", "signal") else None})
        r, w = leftovers[0]
        rep.add("error", "DL-STUCK", Location.op(r, w, sat.pcs[(r, w)]),
                f"{len(blocked)} cursor(s) blocked with no runnable op",
                witness={"blocked": wit})
    return True


def _tarjan(succ: List[List[int]]) -> List[List[int]]:
    """Strongly connected components (iterative Tarjan)."""
    n = len(succ)
    index = [-1] * n
    low = [0] * n
    on_stack = [False] * n
    stack: List[int] = []
    out: List[List[int]] = []
    counter = [0]
    for root in range(n):
        if index[root] != -1:
            continue
        work = [(root, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                index[v] = low[v] = counter[0]
                counter[0] += 1
                stack.append(v)
                on_stack[v] = True
            recurse = False
            for i in range(pi, len(succ[v])):
                u = succ[v][i]
                if index[u] == -1:
                    work[-1] = (v, i + 1)
                    work.append((u, 0))
                    recurse = True
                    break
                if on_stack[u]:
                    low[v] = min(low[v], index[u])
            if recurse:
                continue
            work.pop()
            if low[v] == index[v]:
                comp = []
                while True:
                    u = stack.pop()
                    on_stack[u] = False
                    comp.append(u)
                    if u == v:
                        break
                out.append(comp)
            if work:
                pv = work[-1][0]
                low[pv] = min(low[pv], low[v])
    return out


# ---------------------------------------------------------------------------
# pass 3: must-happens-before DAG + transitive closure
# ---------------------------------------------------------------------------

class _HB:
    """Must-happens-before over op nodes + virtual barrier-round nodes.

    ``anc[i]`` is a bitset (int) of topological positions that are proven
    to precede node ``i`` in every execution.
    """

    def __init__(self, px: _Prog, sat: _Saturation):
        self.px = px
        p = px.p
        n_virtual = len(sat.virtual_rounds)
        self.n = px.n_ops + n_virtual
        self.preds: List[List[int]] = [[] for _ in range(self.n)]
        # program order
        for r, wgs in enumerate(p.gpus):
            for w, ops in enumerate(wgs):
                for i in range(1, len(ops)):
                    self.preds[px.node_of[(r, w, i)]].append(
                        px.node_of[(r, w, i - 1)])
        # barrier rounds: every participant's barrier op (and the last op
        # of each already-finished workgroup) precedes the virtual round
        # node, which precedes each participant's next op
        vbase = px.n_ops
        vid = {}
        for k, (r, ridx) in enumerate(sat.virtual_rounds):
            vid[(r, ridx)] = vbase + k
        for (r, ridx), v in vid.items():
            participants, done = sat.rounds[r][ridx]
            for w, bar_i in participants.items():
                self.preds[v].append(px.node_of[(r, w, bar_i)])
                if bar_i + 1 < len(p.gpus[r][w]):
                    self.preds[px.node_of[(r, w, bar_i + 1)]].append(v)
            for w in done:
                ops = p.gpus[r][w]
                if ops:
                    self.preds[v].append(px.node_of[(r, w, len(ops) - 1)])
        # topological order over preds (the graph is a DAG whenever the
        # saturation run completed — every edge is consistent with that
        # execution's order)
        self.order = self._kahn()
        self.pos = [0] * self.n
        for i, node in enumerate(self.order):
            self.pos[node] = i
        self.anc: List[int] = [0] * self.n

    def _kahn(self) -> List[int]:
        indeg = [0] * self.n
        succ: List[List[int]] = [[] for _ in range(self.n)]
        for v, ps in enumerate(self.preds):
            for u in ps:
                succ[u].append(v)
                indeg[v] += 1
        q = deque(i for i in range(self.n) if indeg[i] == 0)
        out = []
        while q:
            u = q.popleft()
            out.append(u)
            for v in succ[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    q.append(v)
        if len(out) != self.n:                           # pragma: no cover
            raise RuntimeError("happens-before graph has a cycle")
        return out

    # ---------------------------------------------------------------- closure
    def close(self) -> None:
        anc = self.anc = [0] * self.n
        pos = self.pos
        for node in self.order:
            a = 0
            for u in self.preds[node]:
                a |= anc[u] | (1 << pos[u])
            anc[node] = a

    def before(self, a: int, b: int) -> bool:
        """True iff node ``a`` must happen before node ``b``."""
        return (self.anc[b] >> self.pos[a]) & 1 == 1

    # ------------------------------------------------- signal->wait matching
    def add_must_signal_edges(self) -> None:
        """Fixpoint: a signal must precede a wait iff the wait's expected
        count is unreachable without it (per-workgroup signal chains)."""
        px, p = self.px, self.px.p
        sigs_by_key: Dict[Tuple[int, int], List[int]] = defaultdict(list)
        waits: List[Tuple[int, Tuple[int, int], int]] = []
        for r, wgs in enumerate(p.gpus):
            for w, ops in enumerate(wgs):
                for i, o in enumerate(ops):
                    node = px.node_of[(r, w, i)]
                    if o.op == "signal" and \
                            0 <= o.remote_rank < p.num_ranks:
                        sigs_by_key[(o.remote_rank, o.sem)].append(node)
                    elif o.op == "wait" and o.sem >= 0:
                        waits.append((node, (r, o.sem), o.expected))
        have: Set[Tuple[int, int]] = set()
        for _ in range(64):                     # converges in 2-3 in practice
            self.close()
            changed = False
            for wt, key, expected in waits:
                sigs = sigs_by_key.get(key, ())
                ordered = [s for s in sigs if self.before(s, wt)]
                if len(ordered) >= expected:
                    continue
                j = expected - len(ordered)
                cand = [s for s in sigs
                        if not self.before(s, wt) and not self.before(wt, s)]
                chains: Dict[Tuple[int, int], List[int]] = defaultdict(list)
                for s in cand:
                    r2, w2, _ = px.cursor_of[s]
                    chains[(r2, w2)].append(s)
                total = len(cand)
                for chain in chains.values():
                    chain.sort(key=lambda s: px.cursor_of[s][2])
                    need = j - (total - len(chain))
                    for s in chain[:max(0, need)]:
                        if (s, wt) not in have:
                            have.add((s, wt))
                            self.preds[wt].append(s)
                            changed = True
            if not changed:
                break
        # edges changed the graph; refresh order + closure once more
        self.order = self._kahn()
        for i, node in enumerate(self.order):
            self.pos[node] = i
        self.close()


# ---------------------------------------------------------------------------
# pass 4: data races
# ---------------------------------------------------------------------------

def _accesses(px: _Prog):
    """Yield (node, is_write, owner_rank, buf, lo, hi) for every in-bounds
    access of every data op."""
    p = px.p
    out = []
    for r, wgs in enumerate(p.gpus):
        for w, ops in enumerate(wgs):
            for i, o in enumerate(ops):
                if o.op not in DATA_OPS or o.size <= 0:
                    continue
                node = px.node_of[(r, w, i)]

                def acc(is_write, rank, buf, off):
                    declared = p.buffers.get(buf)
                    if declared is None or off < 0 or off + o.size > declared:
                        return                  # already diagnosed by bounds
                    if not (0 <= rank < p.num_ranks):
                        return
                    out.append((node, is_write, rank, buf, off, off + o.size))

                if o.op == "put":
                    acc(False, r, o.src_buf, o.src_off)
                    acc(True, o.remote_rank, o.dst_buf, o.dst_off)
                elif o.op == "get":
                    acc(False, o.remote_rank, o.src_buf, o.src_off)
                    acc(True, r, o.dst_buf, o.dst_off)
                elif o.op == "copy":
                    acc(False, r, o.src_buf, o.src_off)
                    acc(True, r, o.dst_buf, o.dst_off)
                elif o.op == "reduce":
                    for (buf, off, rk) in o.srcs or []:
                        acc(False, rk if rk >= 0 else r, buf, off)
                    acc(True, r, o.dst_buf, o.dst_off)
    return out


def _check_races(px: _Prog, hb: _HB, rep: CheckReport,
                 max_reports: int = 20) -> None:
    groups: Dict[Tuple[int, str], list] = defaultdict(list)
    for a in _accesses(px):
        groups[(a[2], a[3])].append(a)
    seen_pairs: Set[Tuple[int, int]] = set()
    n_found = 0
    for (rank, buf), accs in sorted(groups.items()):
        accs.sort(key=lambda a: (a[4], a[5]))
        for i, a in enumerate(accs):
            for j in range(i + 1, len(accs)):
                b = accs[j]
                if b[4] >= a[5]:
                    break                        # sorted by lo: no overlap
                if not (a[1] or b[1]):
                    continue                     # read-read
                na, nb = a[0], b[0]
                if na == nb:
                    continue                     # one op's own read+write
                pair = (min(na, nb), max(na, nb))
                if pair in seen_pairs:
                    continue
                ca, cb = px.cursor_of[na], px.cursor_of[nb]
                if ca[:2] == cb[:2]:
                    continue                     # same workgroup: ordered
                if hb.before(na, nb) or hb.before(nb, na):
                    continue
                seen_pairs.add(pair)
                n_found += 1
                if n_found > max_reports:
                    continue
                lo, hi = max(a[4], b[4]), min(a[5], b[5])
                kind = "RACE-WW" if (a[1] and b[1]) else "RACE-RW"
                wa = "write" if a[1] else "read"
                wb = "write" if b[1] else "read"
                rep.add("error", kind, px.loc(na),
                        f"unordered {wa}/{wb} overlap on rank {rank} "
                        f"{buf}[{lo}:{hi}] between (r{ca[0]},wg{ca[1]},"
                        f"op{ca[2]}:{px.op(*ca).op}) and (r{cb[0]},"
                        f"wg{cb[1]},op{cb[2]}:{px.op(*cb).op})",
                        witness={"rank": rank, "buffer": buf,
                                 "overlap": [lo, hi],
                                 "a": {"loc": list(ca), "op": px.op(*ca).op,
                                       "access": wa,
                                       "range": [a[4], a[5]]},
                                 "b": {"loc": list(cb), "op": px.op(*cb).op,
                                       "access": wb,
                                       "range": [b[4], b[5]]}})
    if n_found > max_reports:
        rep.add("error", "RACE-MORE", Location(),
                f"{n_found - max_reports} further racing pairs suppressed")


# ---------------------------------------------------------------------------
# pass 5: output coverage
# ---------------------------------------------------------------------------

def _check_coverage(px: _Prog, rep: CheckReport) -> None:
    p = px.p
    if p.collective not in COVERED_COLLECTIVES:
        return
    size = p.buffers.get("output")
    if not size:
        rep.add("warning", "COV-OUTPUT", Location(),
                f"collective {p.collective!r} declares no 'output' buffer; "
                f"coverage not provable")
        return
    writes: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for (node, is_write, rank, buf, lo, hi) in _accesses(px):
        if is_write and buf == "output":
            writes[rank].append((lo, hi))
    for r in range(p.num_ranks):
        missing = _uncovered(writes.get(r, []), size)
        if missing:
            total = sum(hi - lo for lo, hi in missing)
            rep.add("error", "COV-OUTPUT", Location(rank=r),
                    f"rank {r} output has {total} byte(s) never written "
                    f"(first gap: [{missing[0][0]}:{missing[0][1]}]) — "
                    f"{p.collective} requires full output coverage",
                    witness={"rank": r, "missing": [list(m) for m in
                                                    missing[:10]],
                             "declared": size})


def _uncovered(ivals: List[Tuple[int, int]], size: int
               ) -> List[Tuple[int, int]]:
    out = []
    at = 0
    for lo, hi in sorted(ivals):
        if lo > at:
            out.append((at, lo))
        at = max(at, hi)
        if at >= size:
            break
    if at < size:
        out.append((at, size))
    return out


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def check_program(program: Program) -> CheckReport:
    """Run every static pass over an MSCCL++ Program.

    Never raises on a malformed program — findings come back as
    diagnostics (the CLI and sweep pipelines depend on this).
    """
    rep = CheckReport(source=f"program {program.name!r}")
    if len(program.gpus) != program.num_ranks:
        rep.add("error", "OP-RANK", Location(),
                f"program declares num_ranks={program.num_ranks} but has "
                f"{len(program.gpus)} per-rank op lists")
        return rep
    px = _Prog(program)
    _check_bounds(px, rep)
    sat = _Saturation(px)
    sat.run()
    deadlocked = _check_deadlock(px, sat, rep)
    if deadlocked:
        return rep                  # ordering undefined past the hang
    if px.n_ops > HB_OP_LIMIT:
        rep.add("warning", "CHECK-LIMIT", Location(),
                f"{px.n_ops} ops exceeds the happens-before analysis "
                f"ceiling ({HB_OP_LIMIT}); race detection skipped")
    else:
        hb = _HB(px, sat)
        hb.add_must_signal_edges()
        _check_races(px, hb, rep)
    _check_coverage(px, rep)
    return rep
