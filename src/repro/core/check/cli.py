"""``python -m repro.check`` — the sweep-pipeline face of the verifier.

    python -m repro.check program.json trace.json infra.json
    python -m repro.check --json program.json        # machine-readable
    python -m repro.check --collectives              # verify every builtin

File kind is sniffed from the JSON shape: ``gpus``+``buffers`` is an
MSCCL++ Program, ``nodes`` is an ExecutionTrace, ``devices``+
``instances`` is an InfraGraph Infrastructure.  Exit status: 0 all clean
(warnings allowed with ``--quiet`` semantics intact), 1 at least one
error-severity diagnostic, 2 a file could not be loaded.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Tuple

from .report import CheckReport


def _load(path: str) -> Tuple[str, CheckReport]:
    """Sniff + parse + check one file; returns (kind, report)."""
    with open(path) as f:
        text = f.read()
    d = json.loads(text)
    if isinstance(d, dict) and "gpus" in d and "buffers" in d:
        from ..mscclpp import Program
        from . import check_program
        return "program", check_program(Program.from_json(text))
    if isinstance(d, list) or (isinstance(d, dict) and "nodes" in d):
        from ..chakra import ExecutionTrace
        from . import check_trace
        try:
            trace = ExecutionTrace.from_json(text)
        except ValueError as exc:
            rep = CheckReport(source=f"trace {path}")
            from .report import Location
            rep.add("error", "TR-PARSE", Location(), str(exc))
            return "trace", rep
        return "trace", check_trace(trace)
    if isinstance(d, dict) and "devices" in d and "instances" in d:
        from ..infragraph.graph import Infrastructure
        from . import check_infrastructure
        return "infrastructure", check_infrastructure(
            Infrastructure.from_json(text))
    raise ValueError(
        f"{path}: unrecognized JSON shape (expected an MSCCL++ program "
        f"with 'gpus'+'buffers', a trace with 'nodes', or an "
        f"infrastructure with 'devices'+'instances')")


def builtin_collective_reports(rank_counts=(2, 3, 4, 5, 8),
                               nworkgroups=(1, 2), shard_bytes: int = 96
                               ) -> List[Tuple[str, CheckReport]]:
    """Check every built-in generator at several shapes (the CI sweep).

    ``shard_bytes`` is scaled so per-workgroup slices never degenerate to
    zero bytes at the largest rank count.
    """
    from ..collectives import ALGORITHMS
    from . import check_program
    out = []
    for (kind, algo), gen in sorted(ALGORITHMS.items()):
        protocols = ("put", "get") if algo in ("ring", "direct") else (None,)
        for n in rank_counts:
            if algo == "halving_doubling" and n & (n - 1):
                continue
            for nwg in nworkgroups:
                size = shard_bytes * n * nwg
                for proto in protocols:
                    try:
                        prog = (gen(n, size, nwg) if proto is None
                                else gen(n, size, nwg, protocol=proto))
                    except ValueError:
                        continue    # e.g. protocol not supported
                    label = (f"{kind}/{algo}"
                             + (f"/{proto}" if proto else "")
                             + f" n={n} nwg={nwg}")
                    out.append((label, check_program(prog)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="Statically verify MSCCL++ programs, execution traces "
                    "and InfraGraph infrastructures before simulating them.")
    ap.add_argument("files", nargs="*",
                    help="program/trace/infrastructure JSON files")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit one JSON report object per input")
    ap.add_argument("--collectives", action="store_true",
                    help="verify every built-in collective generator "
                         "across rank counts and workgroup splits")
    ap.add_argument("--max-diags", type=int, default=50,
                    help="human-readable diagnostics shown per input")
    args = ap.parse_args(argv)
    if not args.files and not args.collectives:
        ap.print_usage(sys.stderr)
        return 2

    results: List[Tuple[str, CheckReport]] = []
    status = 0
    for path in args.files:
        try:
            kind, rep = _load(path)
        except (OSError, ValueError, json.JSONDecodeError) as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2
        results.append((f"{kind} {path}", rep))
    if args.collectives:
        results.extend(builtin_collective_reports())

    for label, rep in results:
        if rep.errors:
            status = 1
    if args.as_json:
        print(json.dumps([{"input": label,
                           **json.loads(rep.to_json())}
                          for label, rep in results], indent=1))
    else:
        n_err = sum(len(rep.errors) for _, rep in results)
        n_warn = sum(len(rep.warnings) for _, rep in results)
        for label, rep in results:
            if rep.clean:
                continue
            rep2 = CheckReport(source=label, diagnostics=rep.diagnostics)
            print(rep2.format(limit=args.max_diags))
        print(f"checked {len(results)} input(s): "
              f"{n_err} error(s), {n_warn} warning(s)")
    return status


if __name__ == "__main__":          # pragma: no cover
    raise SystemExit(main())
