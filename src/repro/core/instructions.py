"""Primitive Load-Store granularity GPU instructions (paper §4.1.1).

These are the unit of simulation in ASTRA-sim 3.0.  A GPU instruction either
moves one cache-line of data between a compute unit's register file and a
(local or remote) memory location, manipulates a semaphore, performs abstract
arithmetic (``Reduce``), or fences outstanding memory traffic (``Waitcnt``).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Optional


class IKind(enum.IntEnum):
    LOAD = 0            # memory -> register file (data path)
    STORE = 1           # register file -> memory (data path)
    SEM_ACQUIRE = 2     # load semaphore value, check released (control path)
    SEM_RELEASE = 3     # store semaphore value (control path)
    REDUCE = 4          # abstract ALU work, occupies the CU
    WAITCNT = 5         # stall until in-flight load/store count <= threshold


# plain-int mirrors of IKind for the simulation hot path (enum member access
# and enum __eq__ are measurably slower than int compares at millions of
# instructions per run); derived so they can never desync from the enum
LOAD = int(IKind.LOAD)
STORE = int(IKind.STORE)
SEM_ACQUIRE = int(IKind.SEM_ACQUIRE)
SEM_RELEASE = int(IKind.SEM_RELEASE)
REDUCE = int(IKind.REDUCE)
WAITCNT = int(IKind.WAITCNT)


class Space(enum.IntEnum):
    """Memory spaces an instruction may address."""
    HBM = 0             # high-bandwidth memory, interleaved across channels
    SEM = 1             # semaphore scratch space (one cache line per semaphore)


class MemRef:
    """A memory location: ``(gpu, space, addr)``.

    ``addr`` is a byte address inside the space.  For HBM it selects the
    memory channel by cache-line interleaving; for SEM it is the semaphore id.
    (A plain slotted class — one is allocated per simulated instruction, so
    dataclass machinery is too heavy here.)
    """
    __slots__ = ("gpu", "space", "addr")

    def __init__(self, gpu: int, space: Space, addr: int):
        self.gpu = gpu
        self.space = space
        self.addr = addr

    def __eq__(self, other) -> bool:
        return (isinstance(other, MemRef) and self.gpu == other.gpu
                and self.space == other.space and self.addr == other.addr)

    def __hash__(self) -> int:
        return hash((self.gpu, self.space, self.addr))

    def __repr__(self) -> str:  # compact traces
        return f"g{self.gpu}:{self.space.name.lower()}@{self.addr:#x}"


@dataclass
class Instruction:
    """One primitive GPU instruction.

    Exactly one of the payload fields is meaningful depending on ``kind``:
      * LOAD/STORE/SEM_*: ``mem`` (+ ``size`` bytes, <= one cache line)
      * REDUCE: ``cycles`` the CU is occupied
      * WAITCNT: ``threshold`` of allowed in-flight memory ops
    """
    __slots__ = ("kind", "mem", "size", "cycles", "threshold", "tag")
    kind: IKind
    mem: Optional[MemRef]
    size: int
    cycles: int
    threshold: int
    tag: Optional[str]

    def __init__(self, kind: IKind, mem: Optional[MemRef] = None, size: int = 0,
                 cycles: int = 0, threshold: int = 0, tag: Optional[str] = None):
        self.kind = kind
        self.mem = mem
        self.size = size
        self.cycles = cycles
        self.threshold = threshold
        self.tag = tag

    # ----------------------------------------------------------- constructors
    @staticmethod
    def load(mem: MemRef, size: int, tag: Optional[str] = None) -> "Instruction":
        return Instruction(IKind.LOAD, mem=mem, size=size, tag=tag)

    @staticmethod
    def store(mem: MemRef, size: int, tag: Optional[str] = None) -> "Instruction":
        return Instruction(IKind.STORE, mem=mem, size=size, tag=tag)

    @staticmethod
    def sem_acquire(mem: MemRef, tag: Optional[str] = None) -> "Instruction":
        return Instruction(IKind.SEM_ACQUIRE, mem=mem, size=0, tag=tag)

    @staticmethod
    def sem_release(mem: MemRef, tag: Optional[str] = None) -> "Instruction":
        return Instruction(IKind.SEM_RELEASE, mem=mem, size=0, tag=tag)

    @staticmethod
    def reduce(cycles: int, tag: Optional[str] = None) -> "Instruction":
        return Instruction(IKind.REDUCE, cycles=max(1, int(cycles)), tag=tag)

    @staticmethod
    def waitcnt(threshold: int = 0, tag: Optional[str] = None) -> "Instruction":
        return Instruction(IKind.WAITCNT, threshold=threshold, tag=tag)

    def is_mem(self) -> bool:
        return self.kind in (IKind.LOAD, IKind.STORE, IKind.SEM_ACQUIRE,
                             IKind.SEM_RELEASE)

    def __repr__(self) -> str:
        if self.kind in (IKind.LOAD, IKind.STORE):
            return f"{self.kind.name}({self.mem}, {self.size}B)"
        if self.kind in (IKind.SEM_ACQUIRE, IKind.SEM_RELEASE):
            return f"{self.kind.name}({self.mem})"
        if self.kind == IKind.REDUCE:
            return f"REDUCE({self.cycles}cyc)"
        return f"WAITCNT(<={self.threshold})"


# ---------------------------------------------------------------------------
# Compiled instruction streams (bulk wavefront emission, paper §4.1.1 note on
# scalability: per-line allocation is the detailed model's hot path)
# ---------------------------------------------------------------------------

#: one compiled instruction: (kind, gpu, space, addr, size, aux) where
#: ``aux`` is REDUCE cycles, WAITCNT threshold, or SEM_ACQUIRE expected count
Entry = tuple


def entry_of(ins: Instruction) -> Entry:
    """Compile one boxed :class:`Instruction` into a flat entry tuple."""
    m = ins.mem
    aux = ins.cycles if ins.kind == IKind.REDUCE else ins.threshold
    if m is None:
        return (int(ins.kind), -1, 0, 0, ins.size, aux)
    return (int(ins.kind), m.gpu, int(m.space), m.addr, ins.size, aux)


class InstrStream:
    """The flyweight/arena form of one op's per-wavefront instruction stream.

    Instead of a lazy generator yielding an ``Instruction`` + ``MemRef`` pair
    per cache line (two heap objects and two Python constructor frames on the
    simulator's hottest path), an op compiles — once per wavefront — into a
    flat list of scalar tuples.  ``runs[i]`` is the length of the contiguous
    LOAD/STORE streak starting at entry ``i`` (no intervening ``Waitcnt`` /
    semaphore / reduce), which is exactly what the CU's bulk emission path
    needs to size a batched request train.
    """

    __slots__ = ("entries", "runs", "tag")

    def __init__(self, entries: list, tag: Optional[str] = None):
        self.entries = entries
        self.tag = tag
        n = len(entries)
        runs = [0] * n
        streak = 0
        for i in range(n - 1, -1, -1):
            k = entries[i][0]
            streak = streak + 1 if k <= STORE else 0
            runs[i] = streak
        self.runs = runs

    def __len__(self) -> int:
        return len(self.entries)

    def __repr__(self) -> str:
        return f"InstrStream({len(self.entries)} entries, tag={self.tag!r})"
