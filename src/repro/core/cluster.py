"""Cluster: GPUs + fabric + request/response plumbing.

Implements the paper's four-step remote-write decomposition (§1):
  (i)   a CU loads cache-line-sized data from local HBM to its register file
  (ii)  the CU writes the data to the I/O port of the socket
  (iii) the network transfers the cache line to the remote GPU's I/O port
  (iv)  the remote GPU writes the received data to the destination HBM
— each Load/Store is a request/response round trip on the fabric, with
control messages (load requests, store acks, semaphore ops) and data
messages (load responses, store payloads) arbitrated per-link.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

from heapq import heappush as _heappush

from .engine import Engine
from .gpu_model import GpuConfig, GpuModel, WRequest, _wreq
from .instructions import LOAD, SEM_RELEASE, STORE
from .network.fabric import CONTROL, DATA, EndpointSource, Fabric, Flight
from .workload import Kernel


@dataclass
class NocConfig:
    """On-chip topology (paper §5.1 generic GPU, parameterized)."""
    mesh_x: int = 4
    mesh_y: int = 2
    cus_per_router: int = 2          # paper: 4 (128 CUs over 8x4)
    mem_channels: int = 8            # paper: 32 (16 top + 16 bottom)
    io_ports: int = 8                # paper: 32 (4 x 8 left/right routers)
    onchip_GBps: float = 1099.5      # 1 TiB/s on-chip links
    onchip_lat_ns: float = 5.0
    mem_GBps_per_channel: float = 137.4   # 4 TiB/s cumulative / 32
    mem_lat_ns: float = 80.0
    io_GBps_per_port: float = 34.36       # 1 TiB/s cumulative / 32
    scaleup_lat_ns: float = 1000.0        # 1 us inter-GPU link latency
    arbitration: str = "fifo"             # "fifo" | "fair"  (Fig. 11)
    fabric_mode: str = "coalesce"         # "coalesce" | "exact" | "classic"
    coalesce_window_ns: Optional[float] = None   # None -> fabric default
    bulk_emission: str = "on"             # "on" | "off" (batched CU streaks)
    fabric_ledger: str = "on"             # "on" | "off" | "auto" (per-link
                                          # reservation ledgers / channel
                                          # clocks; auto = on, with per-link
                                          # probe kill switches)
    ledger_depth: int = 4                 # channel-clock recursion budget
    route_policy: str = "lazy"            # "lazy" | "eager" route-table
                                          # registration (lazy registers a
                                          # (src,dst) GPU pair's routes on
                                          # first use — bit-exact with eager,
                                          # near-linear in ranks actually
                                          # talking to each other)
    max_multipath_period: int = 4096      # cap on the per-pair multipath
                                          # period lcm(io_src, io_dst, hbm):
                                          # raise it deliberately rather
                                          # than silently materializing huge
                                          # route tables

    @property
    def num_cus(self) -> int:
        return self.mesh_x * self.mesh_y * self.cus_per_router


class Cluster:
    """A multi-GPU system on a single fabric with a scale-up network."""

    def __init__(self, num_gpus: int, gpu_config: Optional[GpuConfig] = None,
                 noc: Optional[NocConfig] = None,
                 engine: Optional[Engine] = None,
                 topology: str = "switch"):
        self.engine = engine or Engine()
        self.noc = noc or NocConfig()
        cfg = gpu_config or GpuConfig()
        cfg.num_cus = self.noc.num_cus
        cfg.hbm_latency_ns = self.noc.mem_lat_ns
        self.gpu_config = cfg
        self.bulk = self.noc.bulk_emission != "off"
        # every wire message carries at least a request/ack header: promise
        # that to the fabric so the ledger's transit lower bounds are tight
        self.fabric = Fabric(self.engine, default_policy=self.noc.arbitration,
                             mode=self.noc.fabric_mode,
                             coalesce_window_ns=self.noc.coalesce_window_ns,
                             ledger=self.noc.fabric_ledger,
                             min_msg_bytes=cfg.header_bytes,
                             ledger_depth=self.noc.ledger_depth)
        # lookahead regions, one per GPU: every link is tagged with the
        # region whose events admit traffic onto it (on-chip links and the
        # GPU's outbound scale-up side), so a region's horizon provably
        # covers all traffic headed its way and chains can run ahead of
        # other GPUs' clocks (engine docstring: Chandy-Misra-style
        # lookahead).  Inbound scale-up links belong to the *destination*
        # GPU's region: a train parking there becomes visible to that
        # region's horizon before any of its downstream arrivals.
        self.regions = [self.engine.new_region() for _ in range(num_gpus)]
        self._hbm_lat_ps = int(round(cfg.hbm_latency_ns * 1000))
        self._cl = cfg.cache_line
        self._hdr = cfg.header_bytes
        if self.noc.route_policy not in ("lazy", "eager"):
            raise ValueError(
                f"NocConfig.route_policy must be 'lazy' or 'eager', "
                f"got {self.noc.route_policy!r}")
        self._lazy = self.noc.route_policy == "lazy"
        self.pairs_registered = 0
        self._maxp = 1                  # key stride: max multipath period
        self.gpus: List[GpuModel] = []
        self._routes: Dict[tuple, list] = {}   # (src, dst, mp-key) -> route
        self._build(num_gpus, topology)
        if topology != "none":
            # "none" clusters get their scale-up wiring from the caller
            # (to_cluster), which must call warm_routes() itself
            self.warm_routes()
        self._inflight = 0
        self.request_count = 0
        # sealed: the owner promises that every kernel dispatch either
        # already happened or is already scheduled as an engine event —
        # no event callback will spring a new dispatch on an idle CU.
        # The ledger can then treat idle CUs as quiet (see
        # ComputeUnit.inj_ge); FineBackend seals after dispatching,
        # chakra.TraceExecutor (on_done-chained dispatches) must not.
        self.sealed = False

    def seal(self) -> None:
        """Promise the ledger that no event callback dispatches new kernels.

        Single-program runs (FineBackend) seal right after dispatching;
        trace runs must NOT — the workload seam (`backends/workload.py`)
        launches each trace node from its dependencies' `on_done`
        callbacks, which is exactly the mid-run dispatch `seal()` forbids.
        """
        self.sealed = True

    # ------------------------------------------------------------- topology
    def _build(self, num_gpus: int, topology: str) -> None:
        fab = self.fabric
        n = self.noc
        for g in range(num_gpus):
            rg = self.regions[g]
            routers = [[fab.add_node(f"g{g}.r{x}_{y}") for y in range(n.mesh_y)]
                       for x in range(n.mesh_x)]
            # 2-D mesh of routers
            for x in range(n.mesh_x):
                for y in range(n.mesh_y):
                    if x + 1 < n.mesh_x:
                        fab.add_bidi(routers[x][y], routers[x + 1][y],
                                     n.onchip_GBps, n.onchip_lat_ns,
                                     region=rg)
                    if y + 1 < n.mesh_y:
                        fab.add_bidi(routers[x][y], routers[x][y + 1],
                                     n.onchip_GBps, n.onchip_lat_ns,
                                     region=rg)
            # CUs
            cu_nodes = []
            for i in range(n.num_cus):
                r = routers[(i // n.cus_per_router) % n.mesh_x][
                    (i // n.cus_per_router) // n.mesh_x % n.mesh_y]
                c = fab.add_node(f"g{g}.cu{i}")
                fab.add_bidi(c, r, n.onchip_GBps, 1.0, region=rg)
                cu_nodes.append(c)
            # HBM channels on the top (y=0) and bottom (y=max) rows
            hbm_nodes = []
            for i in range(n.mem_channels):
                row = 0 if i < n.mem_channels // 2 else n.mesh_y - 1
                col = i % n.mesh_x
                h = fab.add_node(f"g{g}.hbm{i}")
                fab.add_bidi(h, routers[col][row],
                             n.mem_GBps_per_channel, 1.0, region=rg)
                hbm_nodes.append(h)
            # I/O ports on the left (x=0) and right (x=max) columns
            io_nodes = []
            for i in range(n.io_ports):
                col = 0 if i < n.io_ports // 2 else n.mesh_x - 1
                row = i % n.mesh_y
                p = fab.add_node(f"g{g}.io{i}")
                fab.add_bidi(p, routers[col][row], n.io_GBps_per_port, 1.0,
                             region=rg)
                io_nodes.append(p)
            gpu = GpuModel(g, self.gpu_config, self.engine, fab, self,
                           cu_nodes, hbm_nodes, io_nodes, region=rg,
                           bulk=self.bulk)
            self.gpus.append(gpu)
        # scale-up fabric between the GPUs' I/O ports ("none" leaves the
        # wiring to the caller — e.g. infragraph.translate.to_cluster,
        # which wires it from InfraGraph fabric edges)
        if num_gpus > 1 and topology != "none":
            if topology == "switch":
                sw = fab.add_node("scaleup.sw0")
                for g in range(num_gpus):
                    for p, io in enumerate(self.gpus[g].io_nodes):
                        # both directions belong to GPU g's region: io->sw
                        # is fed solely by g's chains, and sw->io is where
                        # inbound trains park — the park must be visible to
                        # g's horizon before any downstream arrival
                        fab.add_bidi(io, sw, n.io_GBps_per_port,
                                     n.scaleup_lat_ns / 2,
                                     region=self.regions[g])
            elif topology == "ring":
                for g in range(num_gpus):
                    nxt = (g + 1) % num_gpus
                    half = len(self.gpus[g].io_nodes) // 2
                    for p in range(half):
                        a = self.gpus[g].io_nodes[half + p]
                        b = self.gpus[nxt].io_nodes[p]
                        # each direction tagged with the receiving GPU
                        fab.add_link(a, b, n.io_GBps_per_port,
                                     n.scaleup_lat_ns,
                                     region=self.regions[nxt])
                        fab.add_link(b, a, n.io_GBps_per_port,
                                     n.scaleup_lat_ns,
                                     region=self.regions[g])
            else:
                raise ValueError(f"unknown scale-up topology {topology!r}")
            # cross-GPU traffic enters a region through its inbound
            # scale-up hop: that hop's latency bounds how fast foreign
            # events can reach interior links
            guard = (n.scaleup_lat_ns / 2 if topology == "switch"
                     else n.scaleup_lat_ns)
            for g in range(num_gpus):
                fab.set_region_guard(self.regions[g], guard)
                self.gpus[g].region_guard_ps = int(round(guard * 1000))

    def warm_routes(self) -> None:
        """Initialize the per-CU multipath route tables the hot path
        indexes, and wire the per-link reservation ledgers.

        Two policies (``NocConfig.route_policy``):

        * ``"eager"`` — pre-register the whole (CU x memory endpoint x
          multipath-key) route space up front: the feeder census is final
          before the first event, but cost is quadratic in ranks.
        * ``"lazy"`` (default) — allocate empty tables; a (src-GPU,
          dst-GPU) pair's route bundle is registered on first use
          (kernel dispatch scans operand GPUs; ``send_request`` has a
          backstop).  Each registration batch is sealed by
          ``Fabric.commit_census()``: a census *epoch* that re-arms probe
          policies, refreshes the static transit floors incrementally
          through the affected feeder cones, and (mid-run) bumps the
          memo epoch so no stale clock conclusion survives.  Bit-exact
          with eager: traffic only ever rides registered routes, so the
          census is always complete for currently-possible traffic, and
          route tie-break keys are positional — order-isomorphic to the
          eager enumeration — so same-tick heap ties resolve identically.
          The per-link FIFO monitor (``order_violations``) certifies that
          no ahead-commit window was widened retroactively.

        Speed: a request's route and destination node are then a single
        list index by cache-line residue (``cu.reqtab`` / ``cu.resptab``)
        instead of hashing/multipath arithmetic per Wavefront Request.

        The per-link reservation ledgers are wired here too: each CU
        becomes the injection source of its own route heads and the
        delivery sink of its inbound links (its wake heap), and each
        memory endpoint bounds its response injections by its inbound
        channel clocks plus the access latency.
        """
        ng = len(self.gpus)
        # key stride: the largest multipath period any pair can have (also
        # validates every period against NocConfig.max_multipath_period —
        # cheap, since it only iterates distinct endpoint-count signatures)
        sizes = {(len(g.io_nodes), len(g.hbm_nodes)) for g in self.gpus}
        maxp = 1
        for io_s, _ in sizes:
            for io_d, h_d in sizes:
                maxp = max(maxp, self._check_period(h_d),
                           self._check_period(
                               math.lcm(io_s, io_d, h_d)) if ng > 1 else 1)
        self._maxp = maxp
        for src in self.gpus:
            for cu in src.cus:
                cu.reqtab = [None] * ng
                cu.resptab = [None] * ng
        if self._lazy:
            if self.fabric.ledger:
                self._wire_ledger()
                # compile the (initially route-free) static transit floors;
                # commit_census() refreshes them per registration epoch
                self.fabric.build_transit_tables()
            return
        for src in self.gpus:
            for dst in self.gpus:
                self._register_pair(src.gid, dst.gid)
        if self.fabric.ledger:
            self._wire_ledger()
            # census final: compile the static feeder-cone transit floors
            # the clock kernel short-circuits on (fabric.ledger_tables)
            self.fabric.build_transit_tables()

    def _check_period(self, period: int) -> int:
        cap = self.noc.max_multipath_period
        if period > cap:
            raise ValueError(
                f"multipath period {period} (lcm of I/O port and HBM "
                f"channel counts) exceeds NocConfig.max_multipath_period="
                f"{cap}; use port/channel counts with smaller lcm or "
                f"raise the cap deliberately")
        return period

    def _pair_period(self, src: GpuModel, dst: GpuModel) -> int:
        if dst is src:
            # local: route per HBM channel, both legs
            return len(dst.hbm_nodes)
        # cross-GPU: the multipath key space is the cache-line residue
        # modulo (io ports x channels)
        return math.lcm(len(src.io_nodes), len(dst.io_nodes),
                        len(dst.hbm_nodes))

    def _register_pair(self, sgid: int, dgid: int) -> None:
        """Register the (src-GPU, dst-GPU) pair's route bundle: one
        request + one response route per CU and multipath residue, with
        positional tie-break keys (order-isomorphic to the eager
        enumeration: src asc, cu asc, dst asc, line asc, request before
        response; via-segment keys nest in the per-route stride of 8)."""
        src = self.gpus[sgid]
        dst = self.gpus[dgid]
        period = self._pair_period(src, dst)
        ng = len(self.gpus)
        ncu = len(src.cus)
        maxp = self._maxp
        cl = self._cl
        for c, cu in enumerate(src.cus):
            base = ((sgid * ncu + c) * ng + dgid) * maxp
            req_routes, resp_routes, nodes = [], [], []
            for line in range(period):
                addr = line * cl
                hnode = dst.hbm_node_for(addr, 0)
                nodes.append(hnode)
                pos = (base + line) * 2
                req_routes.append(
                    self._route(src, cu.node, dst, hnode, addr,
                                key=(pos << 3) + 1))
                resp_routes.append(
                    self._route(dst, hnode, src, cu.node, addr,
                                key=((pos + 1) << 3) + 1))
            cu.reqtab[dgid] = (period, req_routes, nodes)
            cu.resptab[dgid] = (period, resp_routes)
        self.pairs_registered += 1
        self.fabric.commit_census()

    def _ensure_pair(self, sgid: int, dgid: int) -> None:
        if self.gpus[sgid].cus[0].reqtab[dgid] is None:
            self._register_pair(sgid, dgid)

    def _ensure_kernel_routes(self, kernel: Kernel) -> None:
        """Register every (src, dst) GPU pair a kernel's operands can
        touch, before any of its wavefronts issues a request.  Scanning
        operand ``MemRef.gpu`` fields covers the compiled entry stream
        (every Load/Store/Memcpy/Reduce/Semaphore entry's target comes
        from one of these refs); ``send_request`` keeps a backstop."""
        g = kernel.gpu
        self._ensure_pair(g, g)
        for wg in kernel.workgroups:
            for op in wg.ops:
                for attr in ("src", "dst", "sem"):
                    ref = getattr(op, attr, None)
                    tg = getattr(ref, "gpu", None)
                    if tg is not None and tg != g:
                        self._ensure_pair(g, tg)
                srcs = getattr(op, "srcs", None)
                if srcs:
                    for ref in srcs:
                        if ref.gpu != g:
                            self._ensure_pair(g, ref.gpu)

    def _wire_ledger(self) -> None:
        """Install injection sources and delivery sinks (see warm_routes)."""
        fab = self.fabric
        inbound = fab.inbound_map()
        for gpu in self.gpus:
            for cu in gpu.cus:
                cu.in_links = inbound.get(cu.node, [])
                for link in cu.in_links:
                    link._sink = cu._wake_heap
                fab.set_injection_source(cu.node, cu)
            lat_ps = self._hbm_lat_ps
            for node in gpu.hbm_nodes:
                fab.set_injection_source(
                    node, EndpointSource(inbound.get(node, []), lat_ps))

    # ------------------------------------------------------------ dispatch
    def dispatch(self, kernel: Kernel) -> None:
        if self.gpus[kernel.gpu].cus[0].reqtab is None:
            raise RuntimeError(
                "cluster routes not initialized: a topology='none' Cluster "
                "must have its scale-up fabric wired by the caller and then "
                "warm_routes() called before dispatching kernels")
        if self.sealed and self.engine._running:
            raise RuntimeError(
                "mid-run dispatch on a sealed cluster: seal() promises the "
                "ledger that no event callback dispatches new kernels "
                "(use dispatch_at() before sealing, or leave the cluster "
                "unsealed)")
        if self._lazy:
            self._ensure_kernel_routes(kernel)
        self.gpus[kernel.gpu].dispatch(kernel)

    def dispatch_at(self, delay_ns: float, kernel: Kernel) -> None:
        """Pre-schedule a dispatch (e.g. straggler launch skew).  Safe on a
        sealed cluster: the dispatch rides an untagged engine event, which
        every ledger injection bound already floors on."""
        if self.gpus[kernel.gpu].cus[0].reqtab is None:
            raise RuntimeError(
                "cluster routes not initialized: a topology='none' Cluster "
                "must have its scale-up fabric wired by the caller and then "
                "warm_routes() called before dispatching kernels")
        if self._lazy:
            self._ensure_kernel_routes(kernel)
        self.engine.schedule(delay_ns, self.gpus[kernel.gpu].dispatch, kernel)

    def run(self, until_ns: Optional[float] = None) -> float:
        return self.engine.run(until_ns)

    # -------------------------------------------------- request/response flow
    def _route(self, src_gpu: GpuModel, src_node: int, dst_gpu: GpuModel,
               dst_node: int, addr: int,
               key: Optional[int] = None) -> List:
        if src_gpu.gid == dst_gpu.gid:
            return self.fabric.route(src_node, dst_node, key)
        # cross-GPU: hash the cache line across I/O ports for multipathing
        line = addr // self._cl
        skey = line % len(src_gpu.io_nodes)
        dkey = line % len(dst_gpu.io_nodes)
        rkey = (src_node, dst_node, skey, dkey)
        route = self._routes.get(rkey)
        if route is None:
            via = [src_node, src_gpu.io_nodes[skey], dst_gpu.io_nodes[dkey],
                   dst_node]
            route = self.fabric.route_via(via, key)
            self._routes[rkey] = route
        return route

    def send_request(self, req: WRequest, at_ps: Optional[int] = None) -> None:
        """CU -> memory endpoint request leg (at ``at_ps``, default now)."""
        self.request_count += 1
        tab = req.cu.reqtab[req.gpu]
        if tab is None:                # lazy backstop (see warm_routes)
            self._register_pair(req.cu.gpu.gid, req.gpu)
            tab = req.cu.reqtab[req.gpu]
        period, routes, _ = tab
        req.route = routes[(req.addr // self._cl) % period]
        if req.kind == STORE:          # payload travels on the request leg
            req.size = req.psize + self._hdr
            req.cls = DATA
        else:                          # LOAD / SEM_*: control-class header
            req.size = self._hdr
            req.cls = CONTROL
        req.eager = True
        req.on_arrive = self._arrive_at_memory
        if at_ps is None:
            at_ps = self.engine._now_ps
        if req.gpu != req.cu.gpu.gid and self.engine._batch:
            # cross-GPU requests ride multipath via-routes, which can
            # reconverge with this batch's later (differently-keyed)
            # issues — the same-source FIFO argument behind mid-batch
            # horizon proofs only holds for single-tree routes, so chain
            # on ledger evidence alone
            self._chain_ledger_only(self.fabric.send_flight_at, req, at_ps)
        else:
            self.fabric.send_flight_at(req, at_ps, chain=True)

    def _chain_ledger_only(self, send, *args) -> None:
        """Run one chained injection with horizon proofs disabled (see
        Engine._no_hz): used for every walk folded into a CU batch whose
        traffic is not same-source-FIFO against the batch's later issues."""
        eng = self.engine
        prev = eng._no_hz
        eng._no_hz = True
        try:
            send(*args, chain=True)
        finally:
            eng._no_hz = prev

    def send_request_bulk(self, cu, wf, n: int, t0_ps: int) -> None:
        """Emit ``n`` lines of ``wf``'s load/store streak in one batch.

        Issue ticks are ``t0, t0+cycle, ...`` — exactly the per-cycle
        cadence the per-instruction path would produce.  Consecutive lines
        that share a route ride one request train
        (:meth:`Fabric.inject_train`); route changes (cache lines
        interleaving across HBM channels / I/O ports) flush the group.
        """
        self.request_count += n
        cu.outstanding += n
        wf.outstanding += n
        entries = wf.entries
        pc = wf.pc
        wf.pc = pc + n
        cyc = cu._cyc_ps
        cl = self._cl
        hdr = self._hdr
        reqtab = cu.reqtab
        gid = cu.gpu.gid
        arrive = self._arrive_at_memory
        group: List[WRequest] = []
        ats: List[int] = []
        group_route = None
        at = t0_ps
        for j in range(n):
            e = entries[pc + j]
            kind = e[0]
            tab = reqtab[e[1]]
            if tab is None:            # lazy backstop (see warm_routes)
                self._register_pair(gid, e[1])
                tab = reqtab[e[1]]
            period, routes, _ = tab
            route = routes[(e[3] // cl) % period]
            req = _wreq(kind, e[1], e[2], e[3], e[4], cu, wf)
            req.route = route
            if kind == STORE:
                req.size = e[4] + hdr
                req.cls = DATA
            else:
                req.size = hdr
                req.cls = CONTROL
            req.eager = True
            req.on_arrive = arrive
            if route is not group_route:
                if group:
                    self._inject_group(gid, group_route, group, ats)
                group = []
                ats = []
                group_route = route
            group.append(req)
            ats.append(at)
            at += cyc
        if group:
            self._inject_group(gid, group_route, group, ats)

    def send_request_bulk_rr(self, cu, ready: List, n: int,
                             t0_ps: int) -> None:
        """Emit ``n`` load/store lines round-robin across a stable set of
        ready wavefronts, one batch (see ``ComputeUnit._streak_rr``).

        ``ready`` is the CU's ready set in scan order; line ``l`` issues
        wavefront ``ready[l % len(ready)]``'s next entry at
        ``t0 + l*cycle`` — exactly the per-cycle round-robin cadence the
        per-instruction scan would produce while the ready set stays
        stable.  Global tick order is preserved across the interleaved
        per-wavefront streams, so same-route runs still coalesce into
        trains and FIFO arrival order on shared first links is unchanged.
        """
        m = len(ready)
        self.request_count += n
        cu.outstanding += n
        entries_l = []
        pcs = []
        q, r = divmod(n, m)
        for j, (_, w) in enumerate(ready):
            cnt = q + (1 if j < r else 0)
            entries_l.append(w.entries)
            pcs.append(w.pc)
            w.pc += cnt
            w.outstanding += cnt
        cyc = cu._cyc_ps
        cl = self._cl
        hdr = self._hdr
        reqtab = cu.reqtab
        gid = cu.gpu.gid
        arrive = self._arrive_at_memory
        group: List[WRequest] = []
        ats: List[int] = []
        group_route = None
        at = t0_ps
        taken = [0] * m
        for line in range(n):
            j = line % m
            wf = ready[j][1]
            e = entries_l[j][pcs[j] + taken[j]]
            taken[j] += 1
            kind = e[0]
            tab = reqtab[e[1]]
            if tab is None:            # lazy backstop (see warm_routes)
                self._register_pair(gid, e[1])
                tab = reqtab[e[1]]
            period, routes, _ = tab
            route = routes[(e[3] // cl) % period]
            req = _wreq(kind, e[1], e[2], e[3], e[4], cu, wf)
            req.route = route
            if kind == STORE:
                req.size = e[4] + hdr
                req.cls = DATA
            else:
                req.size = hdr
                req.cls = CONTROL
            req.eager = True
            req.on_arrive = arrive
            if route is not group_route:
                if group:
                    self._inject_group(gid, group_route, group, ats)
                group = []
                ats = []
                group_route = route
            group.append(req)
            ats.append(at)
            at += cyc
        if group:
            self._inject_group(gid, group_route, group, ats)

    def _inject_group(self, src_gid: int, route, group, ats) -> None:
        """Inject one bulk request train, ledger-only when it is a
        cross-GPU via-route chained from inside a batch (see
        send_request)."""
        if group[0].gpu != src_gid and self.engine._batch:
            self._chain_ledger_only(self.fabric.inject_train, route, group,
                                    ats)
        else:
            self.fabric.inject_train(route, group, ats, chain=True)

    def _arrive_at_memory(self, flight: Flight) -> None:
        """Request delivery at a memory endpoint.

        This callback is *eager* (time-stamp driven): it may run at final-
        hop commit time, before the simulated arrival — it reads the
        arrival tick from ``flight.eta_ps`` and only schedules absolute-
        time effects.  Per-endpoint FIFO makes those effects monotone.
        """
        req: WRequest = flight           # the request IS its own flight
        kind = req.kind
        eta = req.eta_ps
        if eta < 0:
            eta = self.engine._now_ps
        if kind == LOAD:               # data response
            req.size = req.psize + self._hdr
            req.cls = DATA
        else:
            if kind == SEM_RELEASE:
                # the value lands at its home endpoint after the access
                # latency; the state change needs its own correctly-timed
                # event.  Its tick also floors the home GPU's ledger (a
                # bump can re-poll any subscribed CU at that tick).
                home = self.gpus[req.gpu]
                bump_ps = eta + self._hbm_lat_ps
                _heappush(home._sem_floor, bump_ps)
                # a new sem-floor entry can undercut a cached ledger bound
                # proven before this release was visible
                self.engine._led_gen += 1
                self.engine.schedule_abs_ps(bump_ps, home.sem_bump, req.addr,
                                            region=self.regions[req.gpu])
            req.size = self._hdr       # STORE ack / SEM value response
            req.cls = CONTROL
        # every response leaves exactly one fixed access latency after its
        # request arrived, and requests arrive in per-endpoint FIFO order —
        # so response injections per endpoint are monotone and the whole
        # injection folds into this event via ``send_flight_at`` (one heap
        # event saved per round trip).  Folding *all* kinds keeps the
        # per-link monotonicity contract airtight.  The flight is re-armed
        # in place for the return leg; its delivery calls ``complete``.
        period, routes = req.cu.resptab[req.gpu]
        req.route = routes[(req.addr // self._cl) % period]
        req.hop = 0
        req.eager = False
        req.on_arrive = req.cu.complete
        if self.engine._batch:
            # folded into an in-progress CU issue batch: the batch's own
            # future issues are invisible to region horizons, so this
            # response walk must chain on ledger evidence alone
            self._chain_ledger_only(self.fabric.send_flight_at, req,
                                    eta + self._hbm_lat_ps)
        else:
            self.fabric.send_flight_at(req, eta + self._hbm_lat_ps,
                                       chain=True)
