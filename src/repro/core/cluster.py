"""Cluster: GPUs + fabric + request/response plumbing.

Implements the paper's four-step remote-write decomposition (§1):
  (i)   a CU loads cache-line-sized data from local HBM to its register file
  (ii)  the CU writes the data to the I/O port of the socket
  (iii) the network transfers the cache line to the remote GPU's I/O port
  (iv)  the remote GPU writes the received data to the destination HBM
— each Load/Store is a request/response round trip on the fabric, with
control messages (load requests, store acks, semaphore ops) and data
messages (load responses, store payloads) arbitrated per-link.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .engine import Engine
from .gpu_model import ComputeUnit, GpuConfig, GpuModel, WRequest
from .instructions import IKind, MemRef, Space
from .network.fabric import CONTROL, DATA, Fabric, Flight
from .workload import Kernel


@dataclass
class NocConfig:
    """On-chip topology (paper §5.1 generic GPU, parameterized)."""
    mesh_x: int = 4
    mesh_y: int = 2
    cus_per_router: int = 2          # paper: 4 (128 CUs over 8x4)
    mem_channels: int = 8            # paper: 32 (16 top + 16 bottom)
    io_ports: int = 8                # paper: 32 (4 x 8 left/right routers)
    onchip_GBps: float = 1099.5      # 1 TiB/s on-chip links
    onchip_lat_ns: float = 5.0
    mem_GBps_per_channel: float = 137.4   # 4 TiB/s cumulative / 32
    mem_lat_ns: float = 80.0
    io_GBps_per_port: float = 34.36       # 1 TiB/s cumulative / 32
    scaleup_lat_ns: float = 1000.0        # 1 us inter-GPU link latency
    arbitration: str = "fifo"             # "fifo" | "fair"  (Fig. 11)
    fabric_mode: str = "coalesce"         # "coalesce" | "exact" | "classic"
    coalesce_window_ns: Optional[float] = None   # None -> fabric default

    @property
    def num_cus(self) -> int:
        return self.mesh_x * self.mesh_y * self.cus_per_router


class Cluster:
    """A multi-GPU system on a single fabric with a scale-up network."""

    def __init__(self, num_gpus: int, gpu_config: Optional[GpuConfig] = None,
                 noc: Optional[NocConfig] = None,
                 engine: Optional[Engine] = None,
                 topology: str = "switch"):
        self.engine = engine or Engine()
        self.noc = noc or NocConfig()
        cfg = gpu_config or GpuConfig()
        cfg.num_cus = self.noc.num_cus
        cfg.hbm_latency_ns = self.noc.mem_lat_ns
        self.gpu_config = cfg
        self.fabric = Fabric(self.engine, default_policy=self.noc.arbitration,
                             mode=self.noc.fabric_mode,
                             coalesce_window_ns=self.noc.coalesce_window_ns)
        # lookahead regions, one per GPU: every link is tagged with the
        # region whose events admit traffic onto it (on-chip links and the
        # GPU's outbound scale-up side), so a region's horizon provably
        # covers all traffic headed its way and chains can run ahead of
        # other GPUs' clocks (engine docstring: Chandy-Misra-style
        # lookahead).  Inbound scale-up links belong to the *destination*
        # GPU's region: a train parking there becomes visible to that
        # region's horizon before any of its downstream arrivals.
        self.regions = [self.engine.new_region() for _ in range(num_gpus)]
        self._hbm_lat_ps = int(round(cfg.hbm_latency_ns * 1000))
        self.gpus: List[GpuModel] = []
        self._build(num_gpus, topology)
        self._inflight = 0
        self.request_count = 0

    # ------------------------------------------------------------- topology
    def _build(self, num_gpus: int, topology: str) -> None:
        fab = self.fabric
        n = self.noc
        for g in range(num_gpus):
            rg = self.regions[g]
            routers = [[fab.add_node(f"g{g}.r{x}_{y}") for y in range(n.mesh_y)]
                       for x in range(n.mesh_x)]
            # 2-D mesh of routers
            for x in range(n.mesh_x):
                for y in range(n.mesh_y):
                    if x + 1 < n.mesh_x:
                        fab.add_bidi(routers[x][y], routers[x + 1][y],
                                     n.onchip_GBps, n.onchip_lat_ns,
                                     region=rg)
                    if y + 1 < n.mesh_y:
                        fab.add_bidi(routers[x][y], routers[x][y + 1],
                                     n.onchip_GBps, n.onchip_lat_ns,
                                     region=rg)
            # CUs
            cu_nodes = []
            for i in range(n.num_cus):
                r = routers[(i // n.cus_per_router) % n.mesh_x][
                    (i // n.cus_per_router) // n.mesh_x % n.mesh_y]
                c = fab.add_node(f"g{g}.cu{i}")
                fab.add_bidi(c, r, n.onchip_GBps, 1.0, region=rg)
                cu_nodes.append(c)
            # HBM channels on the top (y=0) and bottom (y=max) rows
            hbm_nodes = []
            for i in range(n.mem_channels):
                row = 0 if i < n.mem_channels // 2 else n.mesh_y - 1
                col = i % n.mesh_x
                h = fab.add_node(f"g{g}.hbm{i}")
                fab.add_bidi(h, routers[col][row],
                             n.mem_GBps_per_channel, 1.0, region=rg)
                hbm_nodes.append(h)
            # I/O ports on the left (x=0) and right (x=max) columns
            io_nodes = []
            for i in range(n.io_ports):
                col = 0 if i < n.io_ports // 2 else n.mesh_x - 1
                row = i % n.mesh_y
                p = fab.add_node(f"g{g}.io{i}")
                fab.add_bidi(p, routers[col][row], n.io_GBps_per_port, 1.0,
                             region=rg)
                io_nodes.append(p)
            gpu = GpuModel(g, self.gpu_config, self.engine, fab, self,
                           cu_nodes, hbm_nodes, io_nodes, region=rg)
            self.gpus.append(gpu)
        # scale-up fabric between the GPUs' I/O ports ("none" leaves the
        # wiring to the caller — e.g. infragraph.translate.to_cluster,
        # which wires it from InfraGraph fabric edges)
        if num_gpus > 1 and topology != "none":
            if topology == "switch":
                sw = fab.add_node("scaleup.sw0")
                for g in range(num_gpus):
                    for p, io in enumerate(self.gpus[g].io_nodes):
                        # both directions belong to GPU g's region: io->sw
                        # is fed solely by g's chains, and sw->io is where
                        # inbound trains park — the park must be visible to
                        # g's horizon before any downstream arrival
                        fab.add_bidi(io, sw, n.io_GBps_per_port,
                                     n.scaleup_lat_ns / 2,
                                     region=self.regions[g])
            elif topology == "ring":
                for g in range(num_gpus):
                    nxt = (g + 1) % num_gpus
                    half = len(self.gpus[g].io_nodes) // 2
                    for p in range(half):
                        a = self.gpus[g].io_nodes[half + p]
                        b = self.gpus[nxt].io_nodes[p]
                        # each direction tagged with the receiving GPU
                        fab.add_link(a, b, n.io_GBps_per_port,
                                     n.scaleup_lat_ns,
                                     region=self.regions[nxt])
                        fab.add_link(b, a, n.io_GBps_per_port,
                                     n.scaleup_lat_ns,
                                     region=self.regions[g])
            else:
                raise ValueError(f"unknown scale-up topology {topology!r}")
            # cross-GPU traffic enters a region through its inbound
            # scale-up hop: that hop's latency bounds how fast foreign
            # events can reach interior links
            guard = (n.scaleup_lat_ns / 2 if topology == "switch"
                     else n.scaleup_lat_ns)
            for g in range(num_gpus):
                fab.set_region_guard(self.regions[g], guard)
                self.gpus[g].region_guard_ps = int(round(guard * 1000))

    # ------------------------------------------------------------ dispatch
    def dispatch(self, kernel: Kernel) -> None:
        self.gpus[kernel.gpu].dispatch(kernel)

    def run(self, until_ns: Optional[float] = None) -> float:
        return self.engine.run(until_ns)

    # -------------------------------------------------- request/response flow
    def send_request(self, req: WRequest, at_ps: Optional[int] = None) -> None:
        """CU -> memory endpoint request leg (at ``at_ps``, default now)."""
        self.request_count += 1
        mem = req.mem
        target_gpu = self.gpus[mem.gpu]
        dst_node = target_gpu.hbm_node_for(mem.addr, mem.space)
        src_cu = req.cu
        src_gpu = src_cu.gpu
        hdr = src_gpu.config.header_bytes
        if req.kind in (IKind.LOAD, IKind.SEM_ACQUIRE):
            size, cls = hdr, CONTROL
        elif req.kind == IKind.SEM_RELEASE:
            size, cls = hdr, CONTROL
        else:  # STORE: payload travels on the request leg
            size, cls = req.size + hdr, DATA
        route = self._route(src_gpu, src_cu.node, target_gpu, dst_node,
                            mem.addr)
        self.fabric.send_at(route, size, cls, self._arrive_at_memory,
                            payload=req, at_ps=at_ps, eager=True)

    def _route(self, src_gpu: GpuModel, src_node: int, dst_gpu: GpuModel,
               dst_node: int, addr: int) -> List:
        if src_gpu.gid == dst_gpu.gid:
            return self.fabric.route(src_node, dst_node)
        # cross-GPU: hash the cache line across I/O ports for multipathing
        key = addr // src_gpu.config.cache_line
        via = [src_node,
               src_gpu.io_node_for(key),
               dst_gpu.io_node_for(key),
               dst_node]
        return self.fabric.route_via(via)

    def _arrive_at_memory(self, flight: Flight) -> None:
        """Request delivery at a memory endpoint.

        This callback is *eager* (time-stamp driven): it may run at final-
        hop commit time, before the simulated arrival — it reads the
        arrival tick from ``flight.eta_ps`` and only schedules absolute-
        time effects.  Per-endpoint FIFO makes those effects monotone.
        """
        req: WRequest = flight.payload
        mem = req.mem
        target_gpu = self.gpus[mem.gpu]
        hdr = target_gpu.config.header_bytes
        kind = req.kind
        eta = flight.eta_ps
        if eta < 0:
            eta = self.engine.now_ps
        if kind == IKind.LOAD:
            size, cls = req.size + hdr, DATA      # data response
        elif kind == IKind.SEM_RELEASE:
            # the value lands at its home endpoint after the access latency;
            # the state change needs its own correctly-timed event
            self.engine.schedule_abs_ps(eta + self._hbm_lat_ps,
                                        target_gpu.sem_bump, mem.addr,
                                        region=self.regions[mem.gpu])
            size, cls = hdr, CONTROL              # ack
        else:  # STORE ack / SEM_ACQUIRE value response
            size, cls = hdr, CONTROL
        # every response leaves exactly one fixed access latency after its
        # request arrived, and requests arrive in per-endpoint FIFO order —
        # so response injections per endpoint are monotone and the whole
        # injection folds into this event via ``send_at`` (one heap event
        # saved per round trip).  Folding *all* kinds keeps the per-link
        # monotonicity contract airtight.
        src_cu = req.cu
        src_node = target_gpu.hbm_node_for(mem.addr, mem.space)
        route = self._route(target_gpu, src_node, src_cu.gpu, src_cu.node,
                            mem.addr)
        self.fabric.send_at(route, size, cls, self._arrive_at_cu,
                            payload=req, at_ps=eta + self._hbm_lat_ps)

    def _arrive_at_cu(self, flight: Flight) -> None:
        req: WRequest = flight.payload
        req.cu.complete(req)
