"""Cluster: GPUs + fabric + request/response plumbing.

Implements the paper's four-step remote-write decomposition (§1):
  (i)   a CU loads cache-line-sized data from local HBM to its register file
  (ii)  the CU writes the data to the I/O port of the socket
  (iii) the network transfers the cache line to the remote GPU's I/O port
  (iv)  the remote GPU writes the received data to the destination HBM
— each Load/Store is a request/response round trip on the fabric, with
control messages (load requests, store acks, semaphore ops) and data
messages (load responses, store payloads) arbitrated per-link.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .engine import Engine
from .gpu_model import ComputeUnit, GpuConfig, GpuModel, WRequest
from .instructions import IKind, MemRef, Space
from .network.fabric import CONTROL, DATA, Fabric, Flight
from .workload import Kernel


@dataclass
class NocConfig:
    """On-chip topology (paper §5.1 generic GPU, parameterized)."""
    mesh_x: int = 4
    mesh_y: int = 2
    cus_per_router: int = 2          # paper: 4 (128 CUs over 8x4)
    mem_channels: int = 8            # paper: 32 (16 top + 16 bottom)
    io_ports: int = 8                # paper: 32 (4 x 8 left/right routers)
    onchip_GBps: float = 1099.5      # 1 TiB/s on-chip links
    onchip_lat_ns: float = 5.0
    mem_GBps_per_channel: float = 137.4   # 4 TiB/s cumulative / 32
    mem_lat_ns: float = 80.0
    io_GBps_per_port: float = 34.36       # 1 TiB/s cumulative / 32
    scaleup_lat_ns: float = 1000.0        # 1 us inter-GPU link latency
    arbitration: str = "fifo"             # "fifo" | "fair"  (Fig. 11)

    @property
    def num_cus(self) -> int:
        return self.mesh_x * self.mesh_y * self.cus_per_router


class Cluster:
    """A multi-GPU system on a single fabric with a scale-up network."""

    def __init__(self, num_gpus: int, gpu_config: Optional[GpuConfig] = None,
                 noc: Optional[NocConfig] = None,
                 engine: Optional[Engine] = None,
                 topology: str = "switch"):
        self.engine = engine or Engine()
        self.noc = noc or NocConfig()
        cfg = gpu_config or GpuConfig()
        cfg.num_cus = self.noc.num_cus
        cfg.hbm_latency_ns = self.noc.mem_lat_ns
        self.gpu_config = cfg
        self.fabric = Fabric(self.engine, default_policy=self.noc.arbitration)
        self.gpus: List[GpuModel] = []
        self._build(num_gpus, topology)
        self._inflight = 0
        self.request_count = 0

    # ------------------------------------------------------------- topology
    def _build(self, num_gpus: int, topology: str) -> None:
        fab = self.fabric
        n = self.noc
        for g in range(num_gpus):
            routers = [[fab.add_node(f"g{g}.r{x}_{y}") for y in range(n.mesh_y)]
                       for x in range(n.mesh_x)]
            # 2-D mesh of routers
            for x in range(n.mesh_x):
                for y in range(n.mesh_y):
                    if x + 1 < n.mesh_x:
                        fab.add_bidi(routers[x][y], routers[x + 1][y],
                                     n.onchip_GBps, n.onchip_lat_ns)
                    if y + 1 < n.mesh_y:
                        fab.add_bidi(routers[x][y], routers[x][y + 1],
                                     n.onchip_GBps, n.onchip_lat_ns)
            # CUs
            cu_nodes = []
            for i in range(n.num_cus):
                r = routers[(i // n.cus_per_router) % n.mesh_x][
                    (i // n.cus_per_router) // n.mesh_x % n.mesh_y]
                c = fab.add_node(f"g{g}.cu{i}")
                fab.add_bidi(c, r, n.onchip_GBps, 1.0)
                cu_nodes.append(c)
            # HBM channels on the top (y=0) and bottom (y=max) rows
            hbm_nodes = []
            for i in range(n.mem_channels):
                row = 0 if i < n.mem_channels // 2 else n.mesh_y - 1
                col = i % n.mesh_x
                h = fab.add_node(f"g{g}.hbm{i}")
                fab.add_bidi(h, routers[col][row],
                             n.mem_GBps_per_channel, 1.0)
                hbm_nodes.append(h)
            # I/O ports on the left (x=0) and right (x=max) columns
            io_nodes = []
            for i in range(n.io_ports):
                col = 0 if i < n.io_ports // 2 else n.mesh_x - 1
                row = i % n.mesh_y
                p = fab.add_node(f"g{g}.io{i}")
                fab.add_bidi(p, routers[col][row], n.io_GBps_per_port, 1.0)
                io_nodes.append(p)
            gpu = GpuModel(g, self.gpu_config, self.engine, fab, self,
                           cu_nodes, hbm_nodes, io_nodes)
            self.gpus.append(gpu)
        # scale-up fabric between the GPUs' I/O ports
        if num_gpus > 1:
            if topology == "switch":
                sw = fab.add_node("scaleup.sw0")
                for g in range(num_gpus):
                    for p, io in enumerate(self.gpus[g].io_nodes):
                        fab.add_bidi(io, sw, n.io_GBps_per_port,
                                     n.scaleup_lat_ns / 2)
            elif topology == "ring":
                for g in range(num_gpus):
                    nxt = (g + 1) % num_gpus
                    half = len(self.gpus[g].io_nodes) // 2
                    for p in range(half):
                        fab.add_bidi(self.gpus[g].io_nodes[half + p],
                                     self.gpus[nxt].io_nodes[p],
                                     n.io_GBps_per_port, n.scaleup_lat_ns)
            else:
                raise ValueError(f"unknown scale-up topology {topology!r}")

    # ------------------------------------------------------------ dispatch
    def dispatch(self, kernel: Kernel) -> None:
        self.gpus[kernel.gpu].dispatch(kernel)

    def run(self, until_ns: Optional[float] = None) -> float:
        return self.engine.run(until_ns)

    # -------------------------------------------------- request/response flow
    def send_request(self, req: WRequest) -> None:
        """CU -> memory endpoint request leg."""
        self.request_count += 1
        mem = req.mem
        target_gpu = self.gpus[mem.gpu]
        dst_node = target_gpu.hbm_node_for(mem.addr, mem.space)
        src_cu = req.cu
        src_gpu = src_cu.gpu
        hdr = src_gpu.config.header_bytes
        if req.kind in (IKind.LOAD, IKind.SEM_ACQUIRE):
            size, cls = hdr, CONTROL
        elif req.kind == IKind.SEM_RELEASE:
            size, cls = hdr, CONTROL
        else:  # STORE: payload travels on the request leg
            size, cls = req.size + hdr, DATA
        route = self._route(src_gpu, src_cu.node, target_gpu, dst_node,
                            mem.addr)
        self.fabric.send(route, size, cls, self._arrive_at_memory, payload=req)

    def _route(self, src_gpu: GpuModel, src_node: int, dst_gpu: GpuModel,
               dst_node: int, addr: int) -> List:
        if src_gpu.gid == dst_gpu.gid:
            return self.fabric.route(src_node, dst_node)
        # cross-GPU: hash the cache line across I/O ports for multipathing
        key = addr // src_gpu.config.cache_line
        via = [src_node,
               src_gpu.io_node_for(key),
               dst_gpu.io_node_for(key),
               dst_node]
        return self.fabric.route_via(via)

    def _arrive_at_memory(self, flight: Flight) -> None:
        req: WRequest = flight.payload
        mem = req.mem
        target_gpu = self.gpus[mem.gpu]
        # memory access latency, then the response leg
        self.engine.schedule(target_gpu.config.hbm_latency_ns,
                             self._respond, req)

    def _respond(self, req: WRequest) -> None:
        mem = req.mem
        target_gpu = self.gpus[mem.gpu]
        src_cu = req.cu
        hdr = target_gpu.config.header_bytes
        if req.kind == IKind.LOAD:
            size, cls = req.size + hdr, DATA      # data response
        elif req.kind == IKind.SEM_ACQUIRE:
            size, cls = hdr, CONTROL              # value response
        elif req.kind == IKind.SEM_RELEASE:
            target_gpu.sem_bump(mem.addr)         # value lands at home
            size, cls = hdr, CONTROL              # ack
        else:  # STORE ack
            size, cls = hdr, CONTROL
        src_node = target_gpu.hbm_node_for(mem.addr, mem.space)
        route = self._route(target_gpu, src_node, src_cu.gpu, src_cu.node,
                            mem.addr)
        self.fabric.send(route, size, cls, self._arrive_at_cu, payload=req)

    def _arrive_at_cu(self, flight: Flight) -> None:
        req: WRequest = flight.payload
        req.cu.complete(req)
