"""MSCCL++ collective-algorithm representation (paper §2.4, §4.2).

A ``Program`` captures a custom collective algorithm as per-GPU,
per-workgroup operation lists — the JSON schema of paper Fig. 3:
``put``/``get``/``copy``/``reduce`` data operations plus ``signal``/``wait``
control dependencies and ``barrier``/``nop`` synchronization.

This module provides:
  * the in-memory representation + JSON (de)serialization,
  * a small authoring DSL (``ProgramBuilder``) used by
    :mod:`repro.core.collectives` to emit textbook algorithms, and
  * the translator (paper §4.2) lowering a Program into the fine-grained
    Load-Store kernels executed by the GPU model: put/get/copy → MemcpyOp,
    reduce → LoadOp×k + Fence + ReduceOp + StoreOp, signal → Semaphore
    ReleaseOp, wait → SemaphoreAcquireOp.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Tuple

from .instructions import MemRef, Space
from .operations import (BarrierOp, FenceOp, FusedReduceOp, GpuOp, MemcpyOp,
                         NopOp, SemaphoreAcquireOp, SemaphoreReleaseOp)
from .workload import Kernel, Workgroup

VALID_OPS = ("put", "get", "copy", "reduce", "signal", "wait", "barrier",
             "nop", "flush")


@dataclass
class CollOp:
    """One MSCCL++ operation inside a workgroup's program."""
    op: str
    # data movement (put/get/copy/reduce)
    src_buf: str = ""
    src_off: int = 0
    dst_buf: str = ""
    dst_off: int = 0
    size: int = 0
    remote_rank: int = -1          # peer for put/get; signal target
    # reduce: list of (buf, off, rank) sources combined into dst; rank == -1
    # means local, otherwise a remote read fused into the reduction
    srcs: Optional[List[Tuple[str, int, int]]] = None
    # control (signal/wait)
    sem: int = -1
    expected: int = 1

    def to_json(self) -> dict:
        d = {k: v for k, v in asdict(self).items()
             if v not in ("", -1, None) or k == "op"}
        return d

    @staticmethod
    def from_json(d: dict) -> "CollOp":
        srcs = d.get("srcs")
        if srcs is not None:
            srcs = [tuple(s) for s in srcs]
        return CollOp(op=d["op"], src_buf=d.get("src_buf", ""),
                      src_off=d.get("src_off", 0), dst_buf=d.get("dst_buf", ""),
                      dst_off=d.get("dst_off", 0), size=d.get("size", 0),
                      remote_rank=d.get("remote_rank", -1), srcs=srcs,
                      sem=d.get("sem", -1), expected=d.get("expected", 1))


@dataclass
class Program:
    """A collective algorithm: per-rank, per-workgroup operation lists."""
    name: str
    collective: str                       # all_gather | reduce_scatter | ...
    num_ranks: int
    buffers: Dict[str, int]               # buffer name -> bytes per rank
    gpus: List[List[List[CollOp]]]        # [rank][workgroup][op]

    # ------------------------------------------------------------- JSON I/O
    def to_json(self) -> str:
        return json.dumps({
            "name": self.name,
            "collective": self.collective,
            "num_ranks": self.num_ranks,
            "buffers": self.buffers,
            "gpus": [{"id": r,
                      "workgroups": [{"ops": [o.to_json() for o in wg]}
                                     for wg in wgs]}
                     for r, wgs in enumerate(self.gpus)],
        }, indent=1)

    @staticmethod
    def from_json(text: str) -> "Program":
        d = json.loads(text)
        gpus: List[List[List[CollOp]]] = [[] for _ in range(d["num_ranks"])]
        for g in d["gpus"]:
            gpus[g["id"]] = [[CollOp.from_json(o) for o in wg["ops"]]
                             for wg in g["workgroups"]]
        return Program(d["name"], d["collective"], d["num_ranks"],
                       {k: int(v) for k, v in d["buffers"].items()}, gpus)

    def content_hash(self) -> str:
        """Canonical sha256 over the program's semantic content.

        Stable across processes and sessions (sorted-key JSON, no
        ``id()``/``hash()`` leakage) — the sweep cache's workload key.
        Two programs hash equal iff name, collective, rank count, buffer
        sizes and every per-workgroup op list agree.
        """
        from .canonical import content_hash
        return content_hash({
            "kind": "Program",
            "name": self.name,
            "collective": self.collective,
            "num_ranks": self.num_ranks,
            "buffers": {k: int(v) for k, v in self.buffers.items()},
            "gpus": [[[o.to_json() for o in wg] for wg in wgs]
                     for wgs in self.gpus],
        })

    def validate(self) -> None:
        """Structural validation: cheap per-op invariants that make the
        program meaningless if violated.  Raises ``ValueError`` at the
        first offense (with the ``(rank, wg, op)`` cursor).  Semantic
        analysis — deadlock, race, coverage — lives in
        :mod:`repro.core.check` and returns a report instead of raising.
        """
        if len(self.gpus) != self.num_ranks:
            raise ValueError(f"program {self.name!r}: num_ranks="
                             f"{self.num_ranks} but {len(self.gpus)} gpu "
                             f"entries")
        for r, wgs in enumerate(self.gpus):
            for w, wg in enumerate(wgs):
                for i, o in enumerate(wg):
                    try:
                        self._validate_op(o)
                    except ValueError as exc:
                        raise ValueError(
                            f"program {self.name!r} (rank {r}, wg {w}, "
                            f"op {i}): {exc}") from None

    def _validate_op(self, o: CollOp) -> None:
        if o.op not in VALID_OPS:
            raise ValueError(f"bad op {o.op!r}")
        if o.op in ("put", "get") and not (0 <= o.remote_rank < self.num_ranks):
            raise ValueError(f"{o.op} remote_rank {o.remote_rank} outside "
                             f"0..{self.num_ranks - 1}")
        if o.op == "signal" and not (0 <= o.remote_rank < self.num_ranks):
            raise ValueError(f"signal remote_rank {o.remote_rank} outside "
                             f"0..{self.num_ranks - 1}")
        if o.op in ("signal", "wait") and o.sem < 0:
            raise ValueError(f"{o.op} needs sem >= 0, got {o.sem}")
        if o.op == "wait" and o.expected < 1:
            raise ValueError(f"wait needs expected >= 1, got {o.expected}")
        if o.op in ("put", "get", "copy", "reduce"):
            if o.size <= 0:
                raise ValueError(f"{o.op} needs size > 0, got {o.size}")
            srcs = (o.srcs or []) if o.op == "reduce" else \
                [(o.src_buf, o.src_off, -1)]
            for (buf, off, src_rank) in srcs:
                self._validate_range(o.op, "src", buf, off, o.size)
                if o.op == "reduce" and not (-1 <= src_rank < self.num_ranks):
                    raise ValueError(f"reduce src rank {src_rank} outside "
                                     f"-1..{self.num_ranks - 1}")
            self._validate_range(o.op, "dst", o.dst_buf, o.dst_off, o.size)

    def _validate_range(self, op: str, role: str, buf: str, off: int,
                        size: int) -> None:
        if buf not in self.buffers:
            raise ValueError(f"{op} {role} references unknown buffer {buf!r} "
                             f"(declared: {sorted(self.buffers)})")
        cap = self.buffers[buf]
        if off < 0 or off + size > cap:
            raise ValueError(f"{op} {role} range {buf}[{off}:{off + size}] "
                             f"outside buffer of {cap} bytes")

    def op_count(self) -> int:
        return sum(len(wg) for wgs in self.gpus for wg in wgs)


class ProgramBuilder:
    """Authoring DSL for MSCCL++ programs.

    >>> b = ProgramBuilder("ring_ag", "all_gather", nranks=4,
    ...                    buffers={"input": 1024, "output": 4096})
    >>> b.put(rank=0, wg=0, src=("input", 0), dst=("output", 0),
    ...       size=1024, remote=1)
    >>> b.signal(rank=0, wg=0, remote=1, sem=b.sem_id(1, "step0"))
    >>> prog = b.build()
    """

    def __init__(self, name: str, collective: str, nranks: int,
                 buffers: Dict[str, int], nworkgroups: int = 1):
        self.name = name
        self.collective = collective
        self.nranks = nranks
        self.buffers = dict(buffers)
        self.nwg = nworkgroups
        self.gpus: List[List[List[CollOp]]] = [
            [[] for _ in range(nworkgroups)] for _ in range(nranks)]
        self._sem_ids: Dict[Tuple[int, str], int] = {}

    # --------------------------------------------------------- sem id space
    def sem_id(self, rank: int, key: str) -> int:
        """A distinct semaphore id on ``rank`` for logical channel ``key``."""
        k = (rank, key)
        if k not in self._sem_ids:
            self._sem_ids[k] = len(self._sem_ids)
        return self._sem_ids[k]

    # ------------------------------------------------------------- emitters
    def _emit(self, rank: int, wg: int, op: CollOp) -> None:
        self.gpus[rank][wg].append(op)

    def put(self, rank: int, wg: int, src: Tuple[str, int],
            dst: Tuple[str, int], size: int, remote: int) -> None:
        self._emit(rank, wg, CollOp("put", src_buf=src[0], src_off=src[1],
                                    dst_buf=dst[0], dst_off=dst[1],
                                    size=size, remote_rank=remote))

    def get(self, rank: int, wg: int, src: Tuple[str, int],
            dst: Tuple[str, int], size: int, remote: int) -> None:
        self._emit(rank, wg, CollOp("get", src_buf=src[0], src_off=src[1],
                                    dst_buf=dst[0], dst_off=dst[1],
                                    size=size, remote_rank=remote))

    def copy(self, rank: int, wg: int, src: Tuple[str, int],
             dst: Tuple[str, int], size: int) -> None:
        self._emit(rank, wg, CollOp("copy", src_buf=src[0], src_off=src[1],
                                    dst_buf=dst[0], dst_off=dst[1], size=size))

    def reduce(self, rank: int, wg: int, srcs: List[Tuple],
               dst: Tuple[str, int], size: int) -> None:
        """``srcs``: (buf, off) for local or (buf, off, rank) for remote."""
        norm = [(s[0], s[1], s[2] if len(s) > 2 else -1) for s in srcs]
        self._emit(rank, wg, CollOp("reduce", srcs=norm, dst_buf=dst[0],
                                    dst_off=dst[1], size=size))

    def signal(self, rank: int, wg: int, remote: int, sem: int) -> None:
        self._emit(rank, wg, CollOp("signal", remote_rank=remote, sem=sem))

    def wait(self, rank: int, wg: int, sem: int, expected: int = 1) -> None:
        self._emit(rank, wg, CollOp("wait", sem=sem, expected=expected))

    def barrier(self, rank: int, wg: int) -> None:
        self._emit(rank, wg, CollOp("barrier"))

    def nop(self, rank: int, wg: int) -> None:
        self._emit(rank, wg, CollOp("nop"))

    def flush(self, rank: int, wg: int) -> None:
        self._emit(rank, wg, CollOp("flush"))

    def build(self) -> Program:
        p = Program(self.name, self.collective, self.nranks, self.buffers,
                    self.gpus)
        p.validate()
        return p


# ---------------------------------------------------------------------------
# Translator: MSCCL++ Program -> fine-grained kernels (paper §4.2)
# ---------------------------------------------------------------------------

class BufferMap:
    """Assigns each (rank, buffer) a base address in that rank's HBM."""

    def __init__(self, program: Program, align: int = 4096):
        self.bases: Dict[str, int] = {}
        addr = 0
        for name, size in sorted(program.buffers.items()):
            self.bases[name] = addr
            addr += (size + align - 1) // align * align
        self.total = addr

    def ref(self, rank: int, buf: str, off: int) -> MemRef:
        return MemRef(rank, Space.HBM, self.bases[buf] + off)


def lower_program(program: Program, unroll: Optional[int] = None,
                  sem_base: int = 0) -> List[Kernel]:
    """Lower an MSCCL++ Program into one fine-grained Kernel per rank.

    ``sem_base`` namespaces this instance's semaphores so several collectives
    can share one Cluster without their monotonic counters colliding.
    """
    program.validate()
    bufmap = BufferMap(program)
    kernels: List[Kernel] = []
    for rank, wgs in enumerate(program.gpus):
        workgroups: List[Workgroup] = []
        for wg_ops in wgs:
            ops: List[GpuOp] = []
            for o in wg_ops:
                ops.extend(_lower_op(o, rank, bufmap, unroll, sem_base))
            workgroups.append(Workgroup(ops, name=f"r{rank}"))
        if workgroups:
            kernels.append(Kernel(workgroups, name=f"{program.name}.r{rank}",
                                  gpu=rank))
    return kernels


def _lower_op(o: CollOp, rank: int, bufmap: BufferMap,
              unroll: Optional[int], sem_base: int = 0) -> List[GpuOp]:
    tag = o.op
    if o.op == "put":
        # local read + remote write
        return [MemcpyOp(bufmap.ref(rank, o.src_buf, o.src_off),
                         bufmap.ref(o.remote_rank, o.dst_buf, o.dst_off),
                         o.size, unroll=unroll, tag=tag)]
    if o.op == "get":
        # remote read + local write
        return [MemcpyOp(bufmap.ref(o.remote_rank, o.src_buf, o.src_off),
                         bufmap.ref(rank, o.dst_buf, o.dst_off),
                         o.size, unroll=unroll, tag=tag)]
    if o.op == "copy":
        return [MemcpyOp(bufmap.ref(rank, o.src_buf, o.src_off),
                         bufmap.ref(rank, o.dst_buf, o.dst_off),
                         o.size, unroll=unroll, tag=tag)]
    if o.op == "reduce":
        srcs = [bufmap.ref(r if r >= 0 else rank, b, off)
                for (b, off, r) in (o.srcs or [])]
        return [FusedReduceOp(srcs=srcs,
                              dst=bufmap.ref(rank, o.dst_buf, o.dst_off),
                              size=o.size, unroll=unroll, tag=tag)]
    if o.op == "signal":
        return [FenceOp(0, tag=tag),   # data must land before the signal
                SemaphoreReleaseOp(
                    MemRef(o.remote_rank, Space.SEM, sem_base + o.sem),
                    tag=tag)]
    if o.op == "wait":
        op = SemaphoreAcquireOp(MemRef(rank, Space.SEM, sem_base + o.sem),
                                expected=o.expected, tag=tag)
        return [op]
    if o.op == "barrier":
        return [BarrierOp(tag=tag)]
    if o.op == "nop":
        return [NopOp(tag=tag)]
    if o.op == "flush":
        return [FenceOp(0, tag=tag)]
    raise ValueError(o.op)
