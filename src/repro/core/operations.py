"""GPU operations (paper §4.1.2).

A *GPU operation* is a sequence of primitive Load-Store instructions denoting
a meaningful functional unit (load a memory range, synchronize a workgroup,
...).  Operations are expanded lazily, per wavefront, into instruction
streams; data operations stripe their memory range across the workgroup's
wavefronts (wavefront ``i`` handles cache lines ``i, i+W, i+2W, ...``), while
control operations are issued by wavefront zero only (paper §4.1.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional

from .instructions import (Instruction, InstrStream, LOAD, MemRef, REDUCE,
                           STORE, WAITCNT, entry_of)


@dataclass
class OpContext:
    """Expansion-time parameters handed down by the GPU model."""
    cache_line: int = 128          # bytes per Wavefront Request
    unroll: int = 1                # loop-unrolling factor (intra-wavefront ILP)
    reduce_cycles_per_line: int = 1


class GpuOp:
    """Base class.  Subclasses yield instructions for one wavefront."""

    #: operations with no instruction stream handled specially by the CU
    sync_kind: Optional[str] = None  # None | "nop" | "barrier"

    def instructions(self, wf: int, num_wf: int, ctx: OpContext) -> Iterator[Instruction]:
        return iter(())

    def compile(self, wf: int, num_wf: int, ctx: OpContext) -> InstrStream:
        """Compiled (flat-tuple) form of :meth:`instructions`.

        The generator form remains the op's *specification* — tests compare
        the two — but execution runs on the compiled stream.  Data ops
        override this with arithmetic builders that never box an
        ``Instruction``/``MemRef`` pair per cache line; this fallback keeps
        custom/control ops correct by construction.
        """
        tag = getattr(self, "tag", None)
        return InstrStream([entry_of(i)
                            for i in self.instructions(wf, num_wf, ctx)], tag)

    def lines(self, wf: int, num_wf: int, ctx: OpContext) -> int:
        """Number of cache lines wavefront ``wf`` is responsible for."""
        return 0


def _nlines(size: int, cache_line: int) -> int:
    return (size + cache_line - 1) // cache_line


def _range_entries(kind: int, mem: MemRef, size: int, wf: int, num_wf: int,
                   cl: int, out: Optional[list] = None) -> list:
    """Append ``(kind, gpu, space, addr, size, 0)`` entries for wavefront
    ``wf``'s stripe of a memory range — the arithmetic core all data-op
    compilers share (no per-line object boxing)."""
    if out is None:
        out = []
    total = _nlines(size, cl)
    gpu, space, base = mem.gpu, int(mem.space), mem.addr
    ap = out.append
    for line in range(wf, total, num_wf):
        off = line * cl
        sz = size - off
        if sz > cl:
            sz = cl
        ap((kind, gpu, space, base + off, sz, 0))
    return out


@dataclass
class LoadOp(GpuOp):
    """Load a memory range into the CU (wrapper of ``Load``)."""
    src: MemRef
    size: int
    tag: Optional[str] = None

    def lines(self, wf: int, num_wf: int, ctx: OpContext) -> int:
        total = _nlines(self.size, ctx.cache_line)
        return (total // num_wf) + (1 if wf < total % num_wf else 0)

    def instructions(self, wf: int, num_wf: int, ctx: OpContext) -> Iterator[Instruction]:
        cl = ctx.cache_line
        total = _nlines(self.size, cl)
        for line in range(wf, total, num_wf):
            addr = self.src.addr + line * cl
            sz = min(cl, self.size - line * cl)
            yield Instruction.load(MemRef(self.src.gpu, self.src.space, addr), sz, self.tag)

    def compile(self, wf: int, num_wf: int, ctx: OpContext) -> InstrStream:
        return InstrStream(_range_entries(LOAD, self.src, self.size, wf,
                                          num_wf, ctx.cache_line), self.tag)


@dataclass
class StoreOp(GpuOp):
    """Store a memory range from the CU (wrapper of ``Store``)."""
    dst: MemRef
    size: int
    tag: Optional[str] = None

    def lines(self, wf: int, num_wf: int, ctx: OpContext) -> int:
        total = _nlines(self.size, ctx.cache_line)
        return (total // num_wf) + (1 if wf < total % num_wf else 0)

    def instructions(self, wf: int, num_wf: int, ctx: OpContext) -> Iterator[Instruction]:
        cl = ctx.cache_line
        total = _nlines(self.size, cl)
        for line in range(wf, total, num_wf):
            addr = self.dst.addr + line * cl
            sz = min(cl, self.size - line * cl)
            yield Instruction.store(MemRef(self.dst.gpu, self.dst.space, addr), sz, self.tag)

    def compile(self, wf: int, num_wf: int, ctx: OpContext) -> InstrStream:
        return InstrStream(_range_entries(STORE, self.dst, self.size, wf,
                                          num_wf, ctx.cache_line), self.tag)


@dataclass
class MemcpyOp(GpuOp):
    """Memory-to-memory copy: Load xU -> Waitcnt -> Store xU groups (Fig. 7).

    ``unroll`` (from the context unless overridden) controls how many loads
    are put in flight before the ``Waitcnt`` memory fence, modeling
    intra-wavefront instruction-level parallelism.
    """
    src: MemRef
    dst: MemRef
    size: int
    unroll: Optional[int] = None   # None -> ctx.unroll
    tag: Optional[str] = None

    def lines(self, wf: int, num_wf: int, ctx: OpContext) -> int:
        total = _nlines(self.size, ctx.cache_line)
        return (total // num_wf) + (1 if wf < total % num_wf else 0)

    def instructions(self, wf: int, num_wf: int, ctx: OpContext) -> Iterator[Instruction]:
        cl = ctx.cache_line
        u = max(1, self.unroll if self.unroll is not None else ctx.unroll)
        total = _nlines(self.size, cl)
        my_lines = list(range(wf, total, num_wf))
        for g in range(0, len(my_lines), u):
            group = my_lines[g:g + u]
            for line in group:
                sz = min(cl, self.size - line * cl)
                yield Instruction.load(
                    MemRef(self.src.gpu, self.src.space, self.src.addr + line * cl),
                    sz, self.tag)
            # fence: all loads of this group must land before stores issue
            yield Instruction.waitcnt(0, self.tag)
            for line in group:
                sz = min(cl, self.size - line * cl)
                yield Instruction.store(
                    MemRef(self.dst.gpu, self.dst.space, self.dst.addr + line * cl),
                    sz, self.tag)

    def compile(self, wf: int, num_wf: int, ctx: OpContext) -> InstrStream:
        cl = ctx.cache_line
        u = max(1, self.unroll if self.unroll is not None else ctx.unroll)
        total = _nlines(self.size, cl)
        size = self.size
        sg, ssp, sbase = self.src.gpu, int(self.src.space), self.src.addr
        dg, dsp, dbase = self.dst.gpu, int(self.dst.space), self.dst.addr
        fence = (WAITCNT, -1, 0, 0, 0, 0)
        ents: list = []
        ap = ents.append
        my_lines = range(wf, total, num_wf)
        for g in range(0, len(my_lines), u):
            group = my_lines[g:g + u]
            for line in group:
                off = line * cl
                sz = size - off
                ap((LOAD, sg, ssp, sbase + off, cl if sz > cl else sz, 0))
            ap(fence)
            for line in group:
                off = line * cl
                sz = size - off
                ap((STORE, dg, dsp, dbase + off, cl if sz > cl else sz, 0))
        return InstrStream(ents, self.tag)


@dataclass
class SemaphoreAcquireOp(GpuOp):
    """Acquire (wait on) a semaphore.  Wavefront zero only."""
    sem: MemRef
    expected: int = 1              # wait until value >= expected
    tag: Optional[str] = None

    def instructions(self, wf: int, num_wf: int, ctx: OpContext) -> Iterator[Instruction]:
        if wf != 0:
            return
        ins = Instruction.sem_acquire(self.sem, self.tag)
        ins.threshold = self.expected
        yield ins


@dataclass
class SemaphoreReleaseOp(GpuOp):
    """Release (signal) a semaphore.  Wavefront zero only."""
    sem: MemRef
    tag: Optional[str] = None

    def instructions(self, wf: int, num_wf: int, ctx: OpContext) -> Iterator[Instruction]:
        if wf != 0:
            return
        yield Instruction.sem_release(self.sem, self.tag)


@dataclass
class ReduceOp(GpuOp):
    """Abstract arithmetic work occupying the CU for some cycles.

    ``size`` bytes of reduction work are striped over wavefronts; each
    wavefront occupies the CU for ``lines * reduce_cycles_per_line`` cycles.
    Alternatively pass explicit ``cycles``.
    """
    size: int = 0
    cycles: Optional[int] = None
    tag: Optional[str] = None

    def instructions(self, wf: int, num_wf: int, ctx: OpContext) -> Iterator[Instruction]:
        if self.cycles is not None:
            if wf == 0:
                yield Instruction.reduce(self.cycles, self.tag)
            return
        total = _nlines(self.size, ctx.cache_line)
        mine = (total // num_wf) + (1 if wf < total % num_wf else 0)
        if mine > 0:
            yield Instruction.reduce(mine * ctx.reduce_cycles_per_line, self.tag)


@dataclass
class FusedReduceOp(GpuOp):
    """Load k sources (local or remote), reduce, store — pipelined in
    ``unroll``-sized line groups so reduction overlaps data movement at
    cache-line granularity (the paper's get-based Reduce-Scatter insight,
    §5.2: "This enables compute-communication overlap at cache-line
    granularity")."""
    srcs: List[MemRef] = field(default_factory=list)
    dst: Optional[MemRef] = None
    size: int = 0
    unroll: Optional[int] = None
    tag: Optional[str] = None

    def lines(self, wf: int, num_wf: int, ctx: OpContext) -> int:
        total = _nlines(self.size, ctx.cache_line)
        return (total // num_wf) + (1 if wf < total % num_wf else 0)

    def instructions(self, wf: int, num_wf: int, ctx: OpContext) -> Iterator[Instruction]:
        cl = ctx.cache_line
        u = max(1, self.unroll if self.unroll is not None else ctx.unroll)
        total = _nlines(self.size, cl)
        my_lines = list(range(wf, total, num_wf))
        k = len(self.srcs)
        for g in range(0, len(my_lines), u):
            group = my_lines[g:g + u]
            for src in self.srcs:
                for line in group:
                    sz = min(cl, self.size - line * cl)
                    yield Instruction.load(
                        MemRef(src.gpu, src.space, src.addr + line * cl),
                        sz, self.tag)
            yield Instruction.waitcnt(0, self.tag)
            # accumulate: (k-1) adds per line group, at least 1 cycle
            yield Instruction.reduce(
                max(1, len(group) * max(1, k - 1) * ctx.reduce_cycles_per_line),
                self.tag)
            if self.dst is not None:
                for line in group:
                    sz = min(cl, self.size - line * cl)
                    yield Instruction.store(
                        MemRef(self.dst.gpu, self.dst.space,
                               self.dst.addr + line * cl), sz, self.tag)

    def compile(self, wf: int, num_wf: int, ctx: OpContext) -> InstrStream:
        cl = ctx.cache_line
        u = max(1, self.unroll if self.unroll is not None else ctx.unroll)
        total = _nlines(self.size, cl)
        size = self.size
        k = len(self.srcs)
        rcpl = ctx.reduce_cycles_per_line
        srcs = [(s.gpu, int(s.space), s.addr) for s in self.srcs]
        dst = self.dst
        if dst is not None:
            dg, dsp, dbase = dst.gpu, int(dst.space), dst.addr
        fence = (WAITCNT, -1, 0, 0, 0, 0)
        ents: list = []
        ap = ents.append
        my_lines = range(wf, total, num_wf)
        for g in range(0, len(my_lines), u):
            group = my_lines[g:g + u]
            for sg, ssp, sbase in srcs:
                for line in group:
                    off = line * cl
                    sz = size - off
                    ap((LOAD, sg, ssp, sbase + off, cl if sz > cl else sz, 0))
            ap(fence)
            cyc = len(group) * max(1, k - 1) * rcpl
            ap((REDUCE, -1, 0, 0, 0, cyc if cyc > 1 else 1))
            if dst is not None:
                for line in group:
                    off = line * cl
                    sz = size - off
                    ap((STORE, dg, dsp, dbase + off, cl if sz > cl else sz, 0))
        return InstrStream(ents, self.tag)


@dataclass
class FenceOp(GpuOp):
    """Standalone memory fence: wait until this wavefront's in-flight
    load/store count drops to ``threshold`` (a bare ``Waitcnt``)."""
    threshold: int = 0
    tag: Optional[str] = None

    def instructions(self, wf: int, num_wf: int, ctx: OpContext) -> Iterator[Instruction]:
        yield Instruction.waitcnt(self.threshold, self.tag)


@dataclass
class NopOp(GpuOp):
    """Intra-workgroup synchronization (``__syncthreads``): all wavefronts
    of the workgroup must arrive before any proceeds (paper §4.4.2)."""
    sync_kind = "nop"
    tag: Optional[str] = None


@dataclass
class BarrierOp(GpuOp):
    """Inter-workgroup synchronization: all workgroups of the kernel must
    arrive before any proceeds."""
    sync_kind = "barrier"
    tag: Optional[str] = None
