"""Textbook collective algorithms emitted as MSCCL++ programs (paper §2.3).

ASTRA-sim ≤2.0 hard-coded these algorithms; 3.0's insight is that once
custom collectives are first-class (MSCCL++), the textbook algorithms are
just *programs* — so we emit ring, all-pairs (direct), double binary tree
and recursive halving-doubling into the same representation, parameterized
by workgroup count and put/get protocol (paper §5.2's design axis).

Every generator here is validated against the collective's data
postcondition by :mod:`repro.core.verify`'s functional executor (tests
sweep nranks × workgroups × protocol with randomized interleavings).

Buffer convention (per rank):
  all_gather:      input = S bytes (own shard),  output = n*S
  reduce_scatter:  input = n*S,                  output = S (own shard)
  all_reduce:      input = S,                    output = S
  all_to_all:      input = n*S,                  output = n*S

Chunk bookkeeping for the ring algorithms (derived so that rank ``r`` ends
owning chunk ``r``):  at step ``s`` rank ``r`` *sends* its partial of chunk
``(r - s - 1) mod n`` and *receives* the partial of chunk ``(r - s - 2)
mod n``; after ``n - 1`` steps the fully-reduced chunk ``r`` lands on rank
``r``.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .mscclpp import Program, ProgramBuilder


def _slices(total: int, parts: int) -> List[Tuple[int, int]]:
    """Split ``total`` bytes into ``parts`` contiguous (off, size) slices."""
    out = []
    base = 0
    for p in range(parts):
        size = total // parts + (1 if p < total % parts else 0)
        out.append((base, size))
        base += size
    return out


# ---------------------------------------------------------------------------
# All-Gather
# ---------------------------------------------------------------------------

def ring_all_gather(nranks: int, shard_bytes: int, nworkgroups: int = 1,
                    protocol: str = "put") -> Program:
    """Ring AG.  put: step ``s`` forwards chunk ``(r - s) mod n`` rightward.
    get: step ``s`` pulls chunk ``(r - 1 - s) mod n`` from the left."""
    n, S = nranks, shard_bytes
    b = ProgramBuilder(f"ring_all_gather_{protocol}", "all_gather", n,
                       {"input": S, "output": n * S}, nworkgroups)
    for r in range(n):
        right, left = (r + 1) % n, (r - 1) % n
        for w, (woff, wsz) in enumerate(_slices(S, nworkgroups)):
            b.copy(r, w, ("input", woff), ("output", r * S + woff), wsz)
            if protocol == "put":
                # sem "rdy" at rank r counts chunks present in r's output:
                # 1 (own, self-signaled) + one per reception from the left.
                b.signal(r, w, remote=r, sem=b.sem_id(r, f"rdy.{w}"))
                for s in range(n - 1):
                    c = (r - s) % n
                    b.wait(r, w, sem=b.sem_id(r, f"rdy.{w}"), expected=s + 1)
                    b.put(r, w, ("output", c * S + woff),
                          ("output", c * S + woff), wsz, remote=right)
                    b.signal(r, w, remote=right,
                             sem=b.sem_id(right, f"rdy.{w}"))
                # completion: all n-1 foreign chunks arrived
                b.wait(r, w, sem=b.sem_id(r, f"rdy.{w}"), expected=n)
            elif protocol == "get":
                # sem "avail" at rank r counts chunks present at r's LEFT
                # neighbor, announced by the left (self copy => 1).
                b.signal(r, w, remote=right, sem=b.sem_id(right, f"avail.{w}"))
                for s in range(n - 1):
                    c = (left - s) % n
                    b.wait(r, w, sem=b.sem_id(r, f"avail.{w}"), expected=s + 1)
                    b.get(r, w, ("output", c * S + woff),
                          ("output", c * S + woff), wsz, remote=left)
                    if s < n - 2:
                        b.flush(r, w)
                        b.signal(r, w, remote=right,
                                 sem=b.sem_id(right, f"avail.{w}"))
            else:
                raise ValueError(protocol)
    return b.build()


def direct_all_gather(nranks: int, shard_bytes: int, nworkgroups: int = 1,
                      protocol: str = "get") -> Program:
    """All-pairs AG (paper §5.2 / Fig. 11).

    get: every rank reads every peer's immutable input — *zero* semaphores,
    but each read is a control request whose data response can be blocked
    behind other data traffic (the arbitration pathology).
    put: every rank pushes its shard into every peer's output and signals;
    receivers only wait at the end.
    """
    n, S = nranks, shard_bytes
    b = ProgramBuilder(f"direct_all_gather_{protocol}", "all_gather", n,
                       {"input": S, "output": n * S}, nworkgroups)
    for r in range(n):
        for w, (woff, wsz) in enumerate(_slices(S, nworkgroups)):
            b.copy(r, w, ("input", woff), ("output", r * S + woff), wsz)
            if protocol == "get":
                for k in range(1, n):
                    peer = (r + k) % n
                    b.get(r, w, ("input", woff),
                          ("output", peer * S + woff), wsz, remote=peer)
            elif protocol == "put":
                for k in range(1, n):
                    peer = (r + k) % n
                    b.put(r, w, ("input", woff),
                          ("output", r * S + woff), wsz, remote=peer)
                b.flush(r, w)
                for k in range(1, n):
                    peer = (r + k) % n
                    b.signal(r, w, remote=peer, sem=b.sem_id(peer, f"ag.{w}"))
                b.wait(r, w, sem=b.sem_id(r, f"ag.{w}"), expected=n - 1)
            else:
                raise ValueError(protocol)
    return b.build()


# ---------------------------------------------------------------------------
# Reduce-Scatter
# ---------------------------------------------------------------------------

def ring_reduce_scatter(nranks: int, shard_bytes: int, nworkgroups: int = 1,
                        protocol: str = "put") -> Program:
    """Ring RS over input of n*S bytes; rank ``r`` ends with reduced shard
    ``r``.  Scratch has one slot per step (no overwrite races).

    put: push my partial into the right neighbor's step slot + signal; the
    receiver reduces slot + its own input chunk.
    get: announce partial readiness rightward; pull the left neighbor's
    partial with a *fused load-reduce* (cache-line-granularity overlap —
    the paper's §5.2 insight).
    """
    n, S = nranks, shard_bytes
    b = ProgramBuilder(f"ring_reduce_scatter_{protocol}", "reduce_scatter", n,
                       {"input": n * S, "output": S,
                        "scratch": (n - 1) * S}, nworkgroups)
    for r in range(n):
        right, left = (r + 1) % n, (r - 1) % n
        for w, (woff, wsz) in enumerate(_slices(S, nworkgroups)):
            if protocol == "put":
                for s in range(n - 1):
                    c_send = (r - s - 1) % n
                    src = ("input", c_send * S + woff) if s == 0 else \
                          ("scratch", (s - 1) * S + woff)
                    if s > 0:
                        b.flush(r, w)   # prior reduce stores must land
                    b.put(r, w, src, ("scratch", s * S + woff), wsz,
                          remote=right)
                    b.flush(r, w)
                    b.signal(r, w, remote=right, sem=b.sem_id(right, f"rs.{w}"))
                    c_recv = (r - s - 2) % n
                    b.wait(r, w, sem=b.sem_id(r, f"rs.{w}"), expected=s + 1)
                    dst = ("output", woff) if s == n - 2 else \
                          ("scratch", s * S + woff)
                    b.reduce(r, w, [("scratch", s * S + woff),
                                    ("input", c_recv * S + woff)], dst, wsz)
            elif protocol == "get":
                for s in range(n - 1):
                    # announce partial chunk (r-s-1): raw input when s == 0,
                    # else the reduce of step s-1 (fence inside signal).
                    b.signal(r, w, remote=right, sem=b.sem_id(right, f"rdy.{w}"))
                    b.wait(r, w, sem=b.sem_id(r, f"rdy.{w}"), expected=s + 1)
                    c_recv = (r - s - 2) % n
                    remote_src = ("input", c_recv * S + woff, left) if s == 0 \
                        else ("scratch", (s - 1) * S + woff, left)
                    dst = ("output", woff) if s == n - 2 else \
                          ("scratch", s * S + woff)
                    b.reduce(r, w, [("input", c_recv * S + woff), remote_src],
                             dst, wsz)
            else:
                raise ValueError(protocol)
    return b.build()


def direct_reduce_scatter(nranks: int, shard_bytes: int, nworkgroups: int = 1,
                          protocol: str = "get") -> Program:
    """All-pairs RS (the paper's Fig. 10 case study).

    get: rank ``r`` fuse-reduces chunk ``r`` straight out of every peer's
    immutable input — **no synchronization at all**, reduction overlaps the
    remote loads at cache-line granularity.
    put: every rank pushes chunk ``k`` into rank ``k``'s scratch slot and
    signals; the receiver must collect n-1 signals before reducing (the
    synchronization the paper blames for put's large-buffer loss).
    """
    n, S = nranks, shard_bytes
    b = ProgramBuilder(f"direct_reduce_scatter_{protocol}", "reduce_scatter",
                       n, {"input": n * S, "output": S,
                           "scratch": (n - 1) * S}, nworkgroups)
    for r in range(n):
        for w, (woff, wsz) in enumerate(_slices(S, nworkgroups)):
            if protocol == "get":
                srcs = [("input", r * S + woff)] + \
                       [("input", r * S + woff, peer)
                        for peer in range(n) if peer != r]
                b.reduce(r, w, srcs, ("output", woff), wsz)
            elif protocol == "put":
                for k in range(1, n):
                    peer = (r + k) % n
                    slot = r if r < peer else r - 1      # my slot at peer
                    b.put(r, w, ("input", peer * S + woff),
                          ("scratch", slot * S + woff), wsz, remote=peer)
                b.flush(r, w)
                for k in range(1, n):
                    peer = (r + k) % n
                    b.signal(r, w, remote=peer, sem=b.sem_id(peer, f"rs.{w}"))
                b.wait(r, w, sem=b.sem_id(r, f"rs.{w}"), expected=n - 1)
                srcs = [("input", r * S + woff)] + \
                       [("scratch", i * S + woff) for i in range(n - 1)]
                b.reduce(r, w, srcs, ("output", woff), wsz)
            else:
                raise ValueError(protocol)
    return b.build()


# ---------------------------------------------------------------------------
# All-Reduce
# ---------------------------------------------------------------------------

def ring_all_reduce(nranks: int, size_bytes: int, nworkgroups: int = 1,
                    protocol: str = "put") -> Program:
    """Ring AR = pipelined ring RS + ring AG in a single kernel."""
    n = nranks
    chunks = _slices(size_bytes, n)
    maxc = max(sz for _, sz in chunks)
    b = ProgramBuilder(f"ring_all_reduce_{protocol}", "all_reduce", n,
                       {"input": size_bytes, "output": size_bytes,
                        "scratch": (n - 1) * maxc}, nworkgroups)
    for r in range(n):
        right = (r + 1) % n
        for w in range(nworkgroups):
            # ---------------- reduce-scatter phase (chunk r lands on rank r)
            for s in range(n - 1):
                c_send = (r - s - 1) % n
                coff, csz = chunks[c_send]
                woff, wsz = _slices(csz, nworkgroups)[w]
                src = ("input", coff + woff) if s == 0 else \
                      ("scratch", (s - 1) * maxc + woff)
                if s > 0:
                    b.flush(r, w)
                b.put(r, w, src, ("scratch", s * maxc + woff), wsz,
                      remote=right)
                b.flush(r, w)
                b.signal(r, w, remote=right, sem=b.sem_id(right, f"ar.{w}"))
                c_recv = (r - s - 2) % n
                roff, rsz = chunks[c_recv]
                rwoff, rwsz = _slices(rsz, nworkgroups)[w]
                b.wait(r, w, sem=b.sem_id(r, f"ar.{w}"), expected=s + 1)
                dst = ("output", roff + rwoff) if s == n - 2 else \
                      ("scratch", s * maxc + rwoff)
                b.reduce(r, w, [("scratch", s * maxc + rwoff),
                                ("input", roff + rwoff)], dst, rwsz)
            # ---------------- all-gather phase: forward reduced chunks
            for s in range(n - 1):
                c = (r - s) % n
                coff, csz = chunks[c]
                woff, wsz = _slices(csz, nworkgroups)[w]
                if s == 0:
                    b.flush(r, w)   # final RS reduce stores must land
                else:
                    b.wait(r, w, sem=b.sem_id(r, f"ag.{w}"), expected=s)
                b.put(r, w, ("output", coff + woff), ("output", coff + woff),
                      wsz, remote=right)
                b.flush(r, w)
                b.signal(r, w, remote=right, sem=b.sem_id(right, f"ag.{w}"))
            b.wait(r, w, sem=b.sem_id(r, f"ag.{w}"), expected=n - 1)
    return b.build()


def double_binary_tree_all_reduce(nranks: int, size_bytes: int,
                                  nworkgroups: int = 1) -> Program:
    """Double binary tree AR (NCCL 2.4, paper ref [22]).

    Two complementary in-order binary trees each reduce-then-broadcast half
    the buffer; tree B is tree A shifted by one rank, so internal nodes of
    one tree are (mostly) leaves of the other, balancing per-rank work.
    Scratch layout: 4 slots of half-size: (2*half + child_idx).
    """
    halves = _slices(size_bytes, 2)
    hmax = max(sz for _, sz in halves)
    b = ProgramBuilder("dbtree_all_reduce", "all_reduce", nranks,
                       {"input": size_bytes, "output": size_bytes,
                        "scratch": 4 * hmax}, nworkgroups)

    def tree(shift: int) -> Tuple[int, Dict[int, List[int]], Dict[int, int]]:
        kids: Dict[int, List[int]] = {}

        def build(lo: int, hi: int) -> Optional[int]:
            if lo > hi:
                return None
            mid = (lo + hi) // 2
            node = (mid + shift) % nranks
            children = [k for k in (build(lo, mid - 1), build(mid + 1, hi))
                        if k is not None]
            kids[node] = children
            return node

        root = build(0, nranks - 1)
        parent = {c: p for p, cs in kids.items() for c in cs}
        return root, kids, parent  # type: ignore[return-value]

    for half, (hoff, hsz) in enumerate(halves):
        root, kids, parent = tree(shift=0 if half == 0 else 1)
        for r in range(nranks):
            my_kids = kids.get(r, [])
            for w, (woff, wsz) in enumerate(_slices(hsz, nworkgroups)):
                off = hoff + woff
                tag = f"t{half}.{w}"
                # --- reduce up
                if my_kids:
                    b.wait(r, w, sem=b.sem_id(r, f"up.{tag}"),
                           expected=len(my_kids))
                    srcs = [("input", off)] + \
                           [("scratch", (2 * half + i) * hmax + woff)
                            for i in range(len(my_kids))]
                    b.reduce(r, w, srcs, ("output", off), wsz)
                else:
                    b.copy(r, w, ("input", off), ("output", off), wsz)
                if r != root:
                    p = parent[r]
                    slot = kids[p].index(r)
                    b.flush(r, w)
                    b.put(r, w, ("output", off),
                          ("scratch", (2 * half + slot) * hmax + woff), wsz,
                          remote=p)
                    b.flush(r, w)
                    b.signal(r, w, remote=p, sem=b.sem_id(p, f"up.{tag}"))
                    # --- wait for the fully-reduced half from the parent
                    b.wait(r, w, sem=b.sem_id(r, f"dn.{tag}"), expected=1)
                for c in my_kids:
                    b.put(r, w, ("output", off), ("output", off), wsz,
                          remote=c)
                    b.flush(r, w)
                    b.signal(r, w, remote=c, sem=b.sem_id(c, f"dn.{tag}"))
    return b.build()


def halving_doubling_all_reduce(nranks: int, size_bytes: int,
                                nworkgroups: int = 1) -> Program:
    """Recursive halving-doubling AR (paper ref [44]); power-of-two ranks.

    RS phase round ``k``: partner = r XOR 2^k; send the half of the active
    range the partner keeps, reduce the half I keep.  AG phase mirrors the
    rounds in reverse.  Scratch ranges across rounds are nested-disjoint,
    so one scratch buffer of full size suffices.

    Unlike the ring algorithms — whose per-chunk workgroup slicing keeps all
    intra-rank data dependencies workgroup-aligned — HD's active range halves
    every round, so workgroup slices of different rounds overlap arbitrarily.
    Rank-level ``barrier`` ops between rounds make those cross-workgroup
    dependencies explicit (this is what real HD kernels need too; cross-rank
    dependencies stay on per-workgroup semaphores because a rank's send range
    equals its partner's keep range, which *is* slice-aligned).
    """
    if nranks & (nranks - 1):
        raise ValueError("halving-doubling requires power-of-two ranks")
    rounds = int(math.log2(nranks))
    # scratch is per-round: round k+1's partner is NOT ordered against my
    # round-k reduce, and its incoming range nests inside round k's — a
    # single shared scratch region would race.
    b = ProgramBuilder("hd_all_reduce", "all_reduce", nranks,
                       {"input": size_bytes, "output": size_bytes,
                        "scratch": rounds * size_bytes}, nworkgroups)
    for r in range(nranks):
        for w in range(nworkgroups):
            woff0, wsz0 = _w(0, size_bytes, w, nworkgroups)
            b.copy(r, w, ("input", woff0), ("output", woff0), wsz0)
            b.flush(r, w)
            b.barrier(r, w)
            lo, hi = 0, size_bytes
            ranges: List[Tuple[int, int]] = []
            for k in range(rounds):
                partner = r ^ (1 << k)
                mid = (lo + hi) // 2
                mine_hi = (r >> k) & 1
                keep = (mid, hi) if mine_hi else (lo, mid)
                send = (lo, mid) if mine_hi else (mid, hi)
                soff, ssz = _w(send[0], send[1], w, nworkgroups)
                b.put(r, w, ("output", soff),
                      ("scratch", k * size_bytes + soff), ssz,
                      remote=partner)
                b.flush(r, w)
                # per-round semaphores: partners differ every round, so a
                # cumulative count cannot tell WHICH partner signaled
                b.signal(r, w, remote=partner,
                         sem=b.sem_id(partner, f"hd.{k}.{w}"))
                b.wait(r, w, sem=b.sem_id(r, f"hd.{k}.{w}"), expected=1)
                koff, ksz = _w(keep[0], keep[1], w, nworkgroups)
                b.reduce(r, w,
                         [("output", koff),
                          ("scratch", k * size_bytes + koff)],
                         ("output", koff), ksz)
                b.flush(r, w)
                b.barrier(r, w)
                ranges.append((lo, hi))
                lo, hi = keep
            for k in reversed(range(rounds)):
                partner = r ^ (1 << k)
                plo, phi = ranges[k]
                mid = (plo + phi) // 2
                mine_hi = (r >> k) & 1
                mine = (mid, phi) if mine_hi else (plo, mid)
                moff, msz = _w(mine[0], mine[1], w, nworkgroups)
                b.put(r, w, ("output", moff), ("output", moff), msz,
                      remote=partner)
                b.flush(r, w)
                b.signal(r, w, remote=partner,
                         sem=b.sem_id(partner, f"hdag.{k}.{w}"))
                b.wait(r, w, sem=b.sem_id(r, f"hdag.{k}.{w}"), expected=1)
                b.barrier(r, w)
    return b.build()


def _w(lo: int, hi: int, w: int, nwg: int) -> Tuple[int, int]:
    """Workgroup ``w``'s (absolute_off, size) slice of byte range [lo, hi)."""
    offs = _slices(hi - lo, nwg)
    return lo + offs[w][0], offs[w][1]


# ---------------------------------------------------------------------------
# All-to-All
# ---------------------------------------------------------------------------

def direct_all_to_all(nranks: int, shard_bytes: int, nworkgroups: int = 1,
                      protocol: str = "put") -> Program:
    """Direct A2A: rank ``r`` sends input chunk ``k`` to rank ``k``'s output
    slot ``r`` (paper Fig. 12's workload)."""
    n, S = nranks, shard_bytes
    b = ProgramBuilder(f"direct_all_to_all_{protocol}", "all_to_all", n,
                       {"input": n * S, "output": n * S}, nworkgroups)
    for r in range(n):
        for w, (woff, wsz) in enumerate(_slices(S, nworkgroups)):
            b.copy(r, w, ("input", r * S + woff), ("output", r * S + woff),
                   wsz)
            for k in range(1, n):
                peer = (r + k) % n
                if protocol == "put":
                    b.put(r, w, ("input", peer * S + woff),
                          ("output", r * S + woff), wsz, remote=peer)
                else:
                    b.get(r, w, ("input", r * S + woff),
                          ("output", peer * S + woff), wsz, remote=peer)
            if protocol == "put":
                b.flush(r, w)
                for k in range(1, n):
                    peer = (r + k) % n
                    b.signal(r, w, remote=peer, sem=b.sem_id(peer, f"a2a.{w}"))
                b.wait(r, w, sem=b.sem_id(r, f"a2a.{w}"), expected=n - 1)
    return b.build()


# ---------------------------------------------------------------------------
# Point-to-point transfer (serving KV-cache handoff)
# ---------------------------------------------------------------------------

def p2p_transfer(nranks: int, size_bytes: int, nworkgroups: int = 1,
                 protocol: str = "put", src: int = 0, dst: int = 1) -> Program:
    """Stream ``size_bytes`` from ``src``'s input to ``dst``'s output.

    The serving layer's KV-cache handoff between a prefill rank and a
    decode rank.  Every other rank is a *pure bystander*: it carries no
    workgroups at all (``gpus[r] == []``), so executors must complete it
    without running anything — the shape that exposed the
    empty-workgroup-rank completion bug in ``ProgramInterpreter``.
    """
    for role, r in (("src", src), ("dst", dst)):
        if not (0 <= r < nranks):
            raise ValueError(f"p2p {role} rank {r} outside 0..{nranks - 1}")
    if src == dst:
        raise ValueError(f"p2p src == dst ({src})")
    b = ProgramBuilder(f"p2p_transfer_{protocol}", "p2p", nranks,
                       {"input": size_bytes, "output": size_bytes},
                       nworkgroups)
    for w, (woff, wsz) in enumerate(_slices(size_bytes, nworkgroups)):
        if protocol == "put":
            b.put(src, w, ("input", woff), ("output", woff), wsz, remote=dst)
            b.flush(src, w)
            b.signal(src, w, remote=dst, sem=b.sem_id(dst, f"kv.{w}"))
            b.wait(dst, w, sem=b.sem_id(dst, f"kv.{w}"), expected=1)
        else:  # get: dst pulls once src announces its input is ready
            b.signal(src, w, remote=dst, sem=b.sem_id(dst, f"kv.{w}"))
            b.wait(dst, w, sem=b.sem_id(dst, f"kv.{w}"), expected=1)
            b.get(dst, w, ("input", woff), ("output", woff), wsz, remote=src)
    p = b.build()
    for r in range(nranks):
        if r not in (src, dst):
            p.gpus[r] = []                     # true bystanders: no program
    return p


# registry used by the system layer and benchmarks
ALGORITHMS = {
    ("all_gather", "ring"): ring_all_gather,
    ("all_gather", "direct"): direct_all_gather,
    ("reduce_scatter", "ring"): ring_reduce_scatter,
    ("reduce_scatter", "direct"): direct_reduce_scatter,
    ("all_reduce", "ring"): ring_all_reduce,
    ("all_reduce", "dbtree"): lambda n, s, w=1, protocol=None:
        double_binary_tree_all_reduce(n, s, w),
    ("all_reduce", "halving_doubling"): lambda n, s, w=1, protocol=None:
        halving_doubling_all_reduce(n, s, w),
    ("all_to_all", "direct"): direct_all_to_all,
    ("p2p", "direct"): p2p_transfer,
}
